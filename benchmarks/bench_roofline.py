"""Deliverable (g): roofline table from the dry-run JSON artifacts.

Reads dryrun_results/*.json (produced by ``python -m repro.launch.dryrun
--all``) and emits one CSV row per (arch x shape x mesh) cell with the
three terms, the dominant bottleneck, and the useful-flops ratio.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "dryrun_results")


def run():
    files = sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))
    if not files:
        emit("roofline.missing", 0.0,
             f"no dry-run artifacts in {RESULTS_DIR}; run "
             "`python -m repro.launch.dryrun --all --mesh both`")
        return
    for path in files:
        with open(path) as f:
            d = json.load(f)
        if d.get("mesh") != "single":
            continue  # roofline table is single-pod per the assignment
        name = f"roofline.{d['arch']}.{d['shape']}"
        lb = d["step_time_lower_bound_s"]
        emit(name, lb * 1e6,
             f"compute={d['compute_s']*1e3:.2f}ms "
             f"memory={d['memory_s']*1e3:.2f}ms "
             f"collective={d['collective_s']*1e3:.2f}ms "
             f"dominant={d['dominant'].replace('_s','')} "
             f"useful_ratio={d.get('useful_flops_ratio', 0):.2f} "
             f"mfu_bound={d.get('mfu_upper_bound', 0)*100:.1f}%")
    n_multi = sum(1 for p in files if "__multi" in p)
    n_single = sum(1 for p in files if "__single" in p)
    emit("roofline.dryrun_coverage", 0.0,
         f"single_pod_cells={n_single} multi_pod_cells={n_multi}")


if __name__ == "__main__":
    run()
