"""Paper fig. 4: base-52 RLE compression of the refinement (red) and
ownership (blue) arrays vs a bitfield, per domain (paper: 63.4 % / 99.3 %
average; ~1M cells -> 1.5 KB in 0.5 ms)."""
from __future__ import annotations

import numpy as np

from repro.core import boolcodec

from .common import emit, orion_domains, timeit


def run(n_domains: int = 16):
    _, _, pruned = orion_domains(n_domains)
    ref_rates, own_rates = [], []
    enc_dt = 0.0
    for d, t in enumerate(pruned):
        (enc_r, dt_r) = timeit(boolcodec.encode, t.refine)
        (enc_o, dt_o) = timeit(boolcodec.encode, t.owner)
        enc_dt = max(enc_dt, dt_r + dt_o)
        r = 1.0 - len(enc_r) / boolcodec.bitfield_bytes(t.refine.size)
        o = 1.0 - len(enc_o) / boolcodec.bitfield_bytes(t.owner.size)
        ref_rates.append(r)
        own_rates.append(o)
        emit(f"fig4.boolcodec.domain{d:02d}", (dt_r + dt_o) * 1e6,
             f"refine={r*100:.1f}% ownership={o*100:.1f}% "
             f"cells={t.n_nodes} refine_bytes={len(enc_r)}")
    emit("fig4.boolcodec.summary", enc_dt * 1e6,
         f"avg_refine={np.mean(ref_rates)*100:.1f}% "
         f"avg_ownership={np.mean(own_rates)*100:.1f}% "
         f"paper=63.4%/99.3%")
    return ref_rates, own_rates


if __name__ == "__main__":
    run()
