"""Beyond-paper: ML train-state checkpoint throughput with the Hercule
HProt flow — raw vs temporal-delta vs pyramid codecs, save + restore,
plus the NCF file-count effect on a sharded state."""
from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.hercule.checkpoint import CheckpointManager

from .common import emit, timeit


def _state(mb: float = 32.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = int(mb * 1e6 / 4 / 4)
    mk = lambda: jnp.asarray(rng.standard_normal((4, n)) * 1e-2, jnp.float32)
    return {"params": {"w": mk()}, "mu": {"w": mk() * 0.1},
            "nu": {"w": jnp.abs(mk()) * 1e-4}, "step": jnp.int32(1)}


def run(mb: float = 32.0):
    base = tempfile.mkdtemp(prefix="hx_ckpt_bench_")
    try:
        state = _state(mb)
        state2 = jax.tree.map(
            lambda x: x + 1e-5 if x.dtype.kind == "f" else x, state)
        total_mb = sum(x.nbytes for x in jax.tree.leaves(state)) / 1e6
        for mode in ("raw", "delta", "pyramid", "auto"):
            root = os.path.join(base, mode)
            mgr = CheckpointManager(root, ncf=4, mode=mode, async_write=False)
            _, dt1 = timeit(lambda: mgr.save(1, state), reps=1)
            _, dt2 = timeit(lambda: mgr.save(2, state2), reps=1)
            nbytes = sum(
                os.path.getsize(os.path.join(root, "data", f))
                for f in os.listdir(os.path.join(root, "data")))
            dev = jax.devices()[0]
            template = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    jnp.shape(x), jnp.result_type(x),
                    sharding=jax.sharding.SingleDeviceSharding(dev)), state)
            (restored, _), dtr = timeit(lambda: mgr.restore(template, step=2),
                                        reps=1)
            ok = jax.tree.all(jax.tree.map(
                lambda a, b: bool(jnp.array_equal(a, b)), restored, state2))
            mgr.close()
            emit(f"ckpt.save.{mode}", dt2 * 1e6,
                 f"save1={total_mb/dt1:.0f}MB/s save2={total_mb/dt2:.0f}MB/s "
                 f"stored={nbytes/1e6:.1f}MB of {2*total_mb:.0f}MB "
                 f"ratio={nbytes/(2*total_mb*1e6):.3f} "
                 f"restore={total_mb/dtr:.0f}MB/s bitwise={ok}")
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    run()
