"""Beyond-paper: ML train-state checkpoint throughput with the Hercule
HProt flow — raw vs temporal-delta vs pyramid codecs, save + restore —
plus the PR-7 headline: train-step *stall* under the async staged-lane
manager vs a fully synchronous save, and the delta-checkpoint byte
ratio. ``run()`` returns the stall ratio (sync/async); CI floors it at
2.0, i.e. async stall must be at most half the sync save wall time."""
from __future__ import annotations

import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointManager
from repro.hercule.checkpoint import CheckpointManager

from .common import emit, scratch_dir, timeit


def _state(mb: float = 32.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = int(mb * 1e6 / 4 / 4)
    mk = lambda: jnp.asarray(rng.standard_normal((4, n)) * 1e-2, jnp.float32)
    return {"params": {"w": mk()}, "mu": {"w": mk() * 0.1},
            "nu": {"w": jnp.abs(mk()) * 1e-4}, "step": jnp.int32(1)}


def _drift(state, k: int):
    """k small SGD-like updates: temporally correlated, delta-friendly."""
    return jax.tree.map(
        lambda x: x + k * 1e-5 if x.dtype.kind == "f" else x, state)


def _template(state):
    dev = jax.devices()[0]
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            jnp.shape(x), jnp.result_type(x),
            sharding=jax.sharding.SingleDeviceSharding(dev)), state)


def _assert_bitwise(a, b, what: str) -> None:
    ok = jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.array_equal(x, y)), a, b))
    assert ok, f"{what}: restored state is not bit-exact"


def _codec_modes(base: str, state, state2, total_mb: float) -> None:
    """Historical record set: sync save/restore across codec modes."""
    for mode in ("raw", "delta", "pyramid", "auto"):
        root = os.path.join(base, mode)
        mgr = CheckpointManager(root, ncf=4, mode=mode, async_write=False)
        _, dt1 = timeit(lambda: mgr.save(1, state), reps=1)
        _, dt2 = timeit(lambda: mgr.save(2, state2), reps=1)
        nbytes = sum(
            os.path.getsize(os.path.join(root, "data", f))
            for f in os.listdir(os.path.join(root, "data")))
        (restored, _), dtr = timeit(lambda: mgr.restore(_template(state),
                                                        step=2), reps=1)
        ok = jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.array_equal(a, b)), restored, state2))
        mgr.close()
        emit(f"ckpt.save.{mode}", dt2 * 1e6,
             f"save1={total_mb/dt1:.0f}MB/s save2={total_mb/dt2:.0f}MB/s "
             f"stored={nbytes/1e6:.1f}MB of {2*total_mb:.0f}MB "
             f"ratio={nbytes/(2*total_mb*1e6):.3f} "
             f"restore={total_mb/dtr:.0f}MB/s bitwise={ok}")


def run(mb: float = 32.0, saves: int = 4):
    base = scratch_dir("hx_ckpt_bench_")
    try:
        state = _state(mb)
        state2 = _drift(state, 1)
        total_mb = sum(x.nbytes for x in jax.tree.leaves(state)) / 1e6
        _codec_modes(base, state, state2, total_mb)

        # ---- stall accounting: what the train thread pays per save.
        # Durability must reach *persistent* storage, so this section
        # runs on the default tempdir (a real filesystem with a real
        # fsync), not the tmpfs scratch — on tmpfs a write is just a
        # memcpy and there is no I/O to hide. Sync = snapshot + encode
        # + write + fsync inline; async = the donation-safe device-side
        # snapshot cut only, with fsync+commit behind the lanes (each
        # save is followed by wait(), so backpressure never pollutes
        # the stall sample; min-of-N filters scheduler noise).
        import tempfile
        disk = tempfile.mkdtemp(prefix="hx_ckpt_stall_")
        drifted = [_drift(state, i) for i in range(saves)]
        jax.block_until_ready(drifted)
        sync = CheckpointManager(os.path.join(disk, "stall_sync"), ncf=4,
                                 mode="raw", async_write=False)
        sync_best = float("inf")
        for i in range(saves):
            _, dt = timeit(lambda: sync.save(i + 1, drifted[i]), reps=1)
            sync_best = min(sync_best, dt)
        sync.close()

        amgr = AsyncCheckpointManager(os.path.join(disk, "stall_async"),
                                      ncf=4, lane_backend="thread")
        async_best = float("inf")
        for i in range(saves):
            _, dt = timeit(lambda: amgr.save(i + 1, drifted[i]), reps=1)
            async_best = min(async_best, dt)
            amgr.wait()
        restored, _ = amgr.restore(_template(state), step=saves)
        _assert_bitwise(restored, drifted[saves - 1], "async full")
        stall_hidden = amgr.stall_seconds_total
        amgr.close()
        shutil.rmtree(disk, ignore_errors=True)

        ratio = sync_best / async_best
        emit("ckpt.stall_sync", sync_best * 1e6,
             f"{total_mb/sync_best:.0f}MB/s write+fsync inline",
             repeats=saves)
        emit("ckpt.stall_async", async_best * 1e6,
             f"snapshot-only; total_stall={stall_hidden*1e3:.1f}ms "
             f"over {saves} saves", repeats=saves)
        emit("ckpt.stall_ratio", ratio,
             f"sync/async stall; floor=2.0 (async <= 0.5x sync)",
             unit="x", repeats=saves)

        # ---- delta checkpoints: bytes of a delta context vs its full
        # rebase, and bit-exact chain restore through the verifier.
        dmgr = AsyncCheckpointManager(os.path.join(base, "delta"), ncf=4,
                                      delta_every=8, lane_backend="thread")
        for i in range(3):
            dmgr.save(i + 1, _drift(state, i))
        dmgr.wait()
        bytes_full = sum(r.nbytes for r in dmgr.db.view(1).records)
        bytes_delta = sum(r.nbytes for r in dmgr.db.view(3).records)
        restored, _ = dmgr.restore(_template(state), step=3)
        _assert_bitwise(restored, _drift(state, 2), "delta chain")
        dmgr.close()
        emit("ckpt.delta_bytes_ratio", bytes_delta / bytes_full,
             f"delta_ctx={bytes_delta/1e6:.1f}MB "
             f"full_ctx={bytes_full/1e6:.1f}MB chain_restore=bitexact",
             unit="ratio")
        return ratio
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    run()
