"""Diff two ``bench-record/v1`` trajectory files: warn on regressions.

CI downloads the previous PR's ``BENCH_<PR>.json`` artifact and compares
the new run record by record::

    python -m benchmarks.diff_records prev/BENCH_PR3.json BENCH_PR4.json

Policy (mirrors the ISSUE/CI contract): a named record whose value got
worse by more than ``--warn-pct`` (default 20%) prints a ``REGRESSION``
warning — it does *not* fail the job (container benchmarks are noisy;
only the explicit floors in ``benchmarks/run.py`` fail a build). Exit
code is non-zero only for unusable inputs, or with ``--strict`` when a
warning fired (for local use).

Record semantics: values are costs (µs per call & friends) — higher is
worse — except units whose last ``_``-separated token is exactly
``x``/``ratio``/``speedup``/``qps``, which are benefits — lower is
worse. The match is on whole tokens, not suffixes: ``bytes_per_step_max``
is a cost even though it *ends* with ``x``, and ``frac`` (fractions such
as per-device residency) is a cost too. Records present on only one side
are listed as added/removed, never warned.
"""
from __future__ import annotations

import argparse
import json
import sys

BENEFIT_UNITS = ("x", "ratio", "speedup", "qps")


def load_records(path: str) -> dict[str, dict]:
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") != "bench-record/v1":
        raise ValueError(f"{path}: not a bench-record/v1 file "
                         f"(schema={data.get('schema')!r})")
    out = {}
    for rec in data.get("records", []):
        out.setdefault(rec["name"], rec)   # first occurrence wins
    return out


def _is_benefit(rec: dict) -> bool:
    # whole-token match: "max".endswith("x") must NOT make a cost unit a
    # benefit, and "frac" stays a cost (smaller residency = better)
    unit = str(rec.get("unit") or "")
    return unit.rsplit("_", 1)[-1] in BENEFIT_UNITS


def diff(old: dict[str, dict], new: dict[str, dict], warn_pct: float
         ) -> tuple[list[str], list[str]]:
    """Returns (report lines, regression warnings)."""
    lines, warnings = [], []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None:
            lines.append(f"  + {name}: {n['value']:.1f} {n.get('unit')}"
                         f" (new record)")
            continue
        if n is None:
            lines.append(f"  - {name}: removed (was {o['value']:.1f})")
            continue
        ov, nv = float(o["value"]), float(n["value"])
        if ov == 0:
            lines.append(f"    {name}: {ov:.1f} -> {nv:.1f} (zero baseline)")
            continue
        change = (nv - ov) / abs(ov) * 100.0
        worse = change if not _is_benefit(n) else -change
        tag = ""
        if worse > warn_pct:
            tag = f"  <-- REGRESSION (> {warn_pct:g}% worse)"
            warnings.append(
                f"{name}: {ov:.1f} -> {nv:.1f} {n.get('unit')} "
                f"({change:+.1f}%)")
        lines.append(f"    {name}: {ov:.1f} -> {nv:.1f} {n.get('unit')} "
                     f"({change:+.1f}%){tag}")
    return lines, warnings


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("old", help="previous BENCH_*.json (artifact)")
    p.add_argument("new", help="this run's BENCH_*.json")
    p.add_argument("--warn-pct", type=float, default=20.0,
                   help="warn when a record got worse by more than this")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 when any regression warning fired")
    args = p.parse_args(argv)

    try:
        old, new = load_records(args.old), load_records(args.new)
    except (OSError, ValueError, KeyError) as e:
        print(f"diff_records: unusable input: {e}", file=sys.stderr)
        return 2

    lines, warnings = diff(old, new, args.warn_pct)
    print(f"== bench trajectory: {args.old} -> {args.new} "
          f"({len(old)} -> {len(new)} records)")
    for line in lines:
        print(line)
    if warnings:
        print(f"\n::warning::{len(warnings)} bench record(s) regressed "
              f">{args.warn_pct:g}%:")
        for w in warnings:
            print(f"::warning::  {w}")
    else:
        print(f"\nno record regressed more than {args.warn_pct:g}%")
    return 1 if (args.strict and warnings) else 0


if __name__ == "__main__":
    raise SystemExit(main())
