"""Regenerate the EXPERIMENTS.md §Roofline markdown table from dry-run
artifacts:  PYTHONPATH=src python -m benchmarks.make_roofline_table
[baseline_dir] [optimized_dir]"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname):
    out = {}
    for p in glob.glob(os.path.join(dirname, "*.json")):
        d = json.load(open(p))
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_row(d, base=None):
    def ms(x):
        return f"{x*1e3:,.0f}"
    delta = ""
    if base is not None:
        b = max(base["compute_s"], base["memory_s"], base["collective_s"])
        n = max(d["compute_s"], d["memory_s"], d["collective_s"])
        if b > 0 and abs(n / b - 1) > 0.02:
            delta = f" ({b/n:.1f}x)"
    return (f"| {d['arch']} | {d['shape']} | {ms(d['compute_s'])} | "
            f"{ms(d['memory_s'])} | {ms(d['collective_s'])}{delta} | "
            f"{d['dominant'].replace('_s', '')} | "
            f"{d.get('useful_flops_ratio', 0):.2f} | "
            f"{d.get('mfu_upper_bound', 0)*100:.1f}% | "
            f"{(d['memory']['argument_bytes'] + d['memory']['temp_bytes'])/1e9:.1f} |")


def main():
    opt_dir = sys.argv[2] if len(sys.argv) > 2 else "dryrun_results"
    base_dir = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_baseline"
    opt = load(opt_dir)
    base = load(base_dir) if os.path.isdir(base_dir) else {}
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | useful | MFU bound | HBM GB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for key in sorted(opt):
        if key[2] != "single":
            continue
        print(fmt_row(opt[key], base.get(key)))
    n_multi = sum(1 for k in opt if k[2] == "multi")
    n_single = sum(1 for k in opt if k[2] == "single")
    print(f"\nCells compiled: {n_single} single-pod (16x16) + "
          f"{n_multi} multi-pod (2x16x16).")


if __name__ == "__main__":
    main()
