"""In-transit engine: compute-loop overhead (engine on vs off) and
reduction-query throughput vs post-hoc assembly of the same slice.

The paper's argument in numbers: a viewer hitting the reduced catalog
should beat re-assembling the global tree from full HDep objects by a
large factor, while the compute flow pays ~nothing for staging.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.hercule import HerculeDB, analysis, api
from repro.insitu import Catalog, InTransitEngine, SliceReducer

from .common import emit, orion_domains, timeit

RESOLUTION = 256


def _compute_step(tree):
    """Stand-in compute work per step: touch the fields like a solver."""
    v = tree.fields["density"]
    return float(v.sum() + np.abs(v).max())


def run(n_domains: int = 16, steps: int = 8):
    tree, _, pruned = orion_domains(n_domains)
    slicer = SliceReducer(field="density", axis=2, position=0.5,
                          resolution=RESOLUTION)

    # ---------------- compute loop, engine OFF
    t0 = time.perf_counter()
    for _ in range(steps):
        _compute_step(tree)
    t_off = time.perf_counter() - t0

    # ---------------- compute loop, engine ON (drop-oldest, never blocks)
    red_root = tempfile.mkdtemp(prefix="hx_bench_insitu_")
    eng = InTransitEngine(red_root, [slicer], policy="drop-oldest",
                          queue_capacity=2).start()
    t0 = time.perf_counter()
    for s in range(1, steps + 1):
        _compute_step(tree)
        eng.submit(s, tree)
    t_on = time.perf_counter() - t0
    eng.drain()
    stats = eng.staging.stats
    overhead = (t_on - t_off) / steps
    emit("insitu.compute_overhead", overhead * 1e6,
         f"loop_off={t_off*1e3:.1f}ms loop_on={t_on*1e3:.1f}ms "
         f"accepted={stats.accepted} evicted={stats.evicted} "
         f"staged={stats.bytes_staged/1e6:.1f}MB policy=drop-oldest")
    eng.close()

    # ---------------- post-hoc baseline: full HDep objects -> assemble -> slice
    full_root = tempfile.mkdtemp(prefix="hx_bench_posthoc_")
    db = HerculeDB.create(full_root, kind="hdep", ncf=4)
    ctx = db.begin_context(0)
    for d, pt in enumerate(pruned):
        api.write_object(ctx, "amr_tree", d, pt)
    ctx.finalize()

    def posthoc_slice():
        g = analysis.load_global_tree(db, 0)
        return analysis.slice_image(g, "density", axis=2, position=0.5,
                                    resolution=RESOLUTION)
    ref, t_posthoc = timeit(posthoc_slice, reps=2)

    # ---------------- in-transit catalog: cold read, then cached
    cat = Catalog(red_root)
    step = cat.steps()[-1]
    _, t_cold = timeit(lambda: cat.query(step, slicer.name), reps=1)
    img = cat.query(step, slicer.name)["image"]
    _, t_warm = timeit(lambda: cat.query(step, slicer.name), reps=5)
    assert img.shape == ref.shape
    emit("insitu.query_cold", t_cold * 1e6,
         f"vs_posthoc={t_posthoc*1e6:.0f}us "
         f"speedup={t_posthoc/max(t_cold,1e-9):.1f}x")
    emit("insitu.query_cached", t_warm * 1e6,
         f"speedup_vs_posthoc={t_posthoc/max(t_warm,1e-9):.0f}x "
         f"cache={cat.cache_info()}")
    shutil.rmtree(red_root, ignore_errors=True)
    shutil.rmtree(full_root, ignore_errors=True)


if __name__ == "__main__":
    run()
