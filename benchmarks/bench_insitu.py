"""In-transit engine: compute-loop overhead (engine on vs off),
reduction-query throughput vs post-hoc assembly, multi-domain
contributor-group scaling with merge-at-read verification, and the
device-reduce transfer ratio (staged-on-accelerator reduction vs the
host path's full-snapshot device→host copy).

The paper's argument in numbers: a viewer hitting the reduced catalog
should beat re-assembling the global tree from full HDep objects by a
large factor, the compute flow should pay ~nothing for staging, and
per-producer reduction+write should scale with contributor groups while
merged reads return exactly the single-producer answer.

The multi-domain mode emulates the paper's producers with OS processes
(one per contributor group, like MPI ranks — threads would share the
GIL and measure the interpreter, not the I/O path): each producer runs
the reducer DAG on its own partition and lands its reduced objects as
its own Hercule domain; the parent commits one manifest per context and
verifies a 4-domain ``read_merged`` against the 1-domain reference.
Workers are spawned (not forked — earlier bench modules may hold live
XLA/pool threads) and receive the partitions once, at pool startup,
outside every timed region.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import shutil
import time

import numpy as np

from repro.hercule import HerculeDB, analysis, api
from repro.hercule.database import Record
from repro.insitu import Catalog, InTransitEngine, SliceReducer

from .common import emit, orion_domains, scratch_dir, timeit

RESOLUTION = 256

# ---------------------------------------------------- multi-domain mode

GROUPS = (1, 2, 4)
MD_STEPS = 5
MD_REPS = 6     # best-of: rides out noisy-neighbor CPU steal windows

#: per-producer state installed into each spawned worker by _md_init
_MD: dict = {}


def _md_init(roots: dict, parts: dict) -> None:
    _MD["roots"], _MD["parts"] = roots, parts


def _md_reducers():
    from repro.insitu import (LevelHistogramReducer, LODCutReducer,
                              ProjectionReducer)
    # fixed histogram bounds: per-partition auto bounds cannot merge
    return [LODCutReducer(max_level=12),
            ProjectionReducer(field="density", resolution=RESOLUTION),
            LevelHistogramReducer(field="density", bins=64,
                                  lo=0.0, hi=50.0)]


def _md_land(args):
    """One producer's task: reduce + write its domain for a step batch."""
    from repro.insitu.reducers import ReducerDAG
    from repro.insitu.staging import Snapshot
    n_groups, g, steps = args
    root, parts = _MD["roots"][n_groups], _MD["parts"][n_groups]
    dag = ReducerDAG(_md_reducers())
    db = HerculeDB.open(root)
    out = []
    for step in steps:
        outputs = dag.run(Snapshot(step=step, kind="amr", arrays=parts[g],
                                   domain=g, n_domains=n_groups))
        ctx = db.begin_context(step)
        for rname, arrays in outputs.items():
            api.write_object(ctx, "reduced", g, arrays, reducer=rname,
                             compress=False)
        out.append((step, [r.to_json() for r in ctx.records]))
        ctx.abort()   # records travel back to the parent, which commits
    db.close()
    return out


def _md_commit(root: str, results, merge_map: dict) -> None:
    """Commit one manifest per context from the producers' records."""
    by_step: dict[int, list] = {}
    for batch in results:
        for step, recs in batch:
            by_step.setdefault(step, []).extend(recs)
    db = HerculeDB.open(root)
    for step, recs in sorted(by_step.items()):
        ctx = db.begin_context(step)
        ctx.records.extend(Record.from_json(r) for r in recs)
        ctx.finalize(attrs={"insitu": {
            "merge": merge_map, "n_domains": len(results),
            "domains": sorted({r["domain"] for r in recs})}})
    db.close()


def run_multidomain() -> float:
    """Contributor-group scaling + merge-at-read equality. Returns the
    4-group vs 1-group write-throughput ratio."""
    tree, _, _ = orion_domains(16)
    merge_map = {r.name: r.merge for r in _md_reducers()}
    roots, parts_by_n, part_ms = {}, {}, {}
    for n in GROUPS:
        t0 = time.perf_counter()
        from repro.insitu.partition import partition_snapshot
        parts_by_n[n] = partition_snapshot(tree.to_arrays(), "amr", n)
        part_ms[n] = (time.perf_counter() - t0) * 1e3
        roots[n] = scratch_dir(f"hx_bench_md{n}_")
        HerculeDB.create(roots[n], kind="hdep", ncf=1)
    emit("insitu.partition_g4", part_ms[4] * 1e3,
         f"hilbert split+closure into 4 groups, "
         f"{tree.n_nodes} nodes", unit="us_per_call", repeats=1)

    # one OS process per producer, capped at the cores we actually have
    procs = min(max(GROUPS), os.cpu_count() or 1)
    best = {n: float("inf") for n in GROUPS}
    with mp.get_context("spawn").Pool(processes=procs, initializer=_md_init,
                                      initargs=(roots, parts_by_n)) as pool:
        for n in GROUPS:   # warm page caches, allocators, imports
            pool.map(_md_land, [(n, g, [0]) for g in range(n)])
        for rep in range(MD_REPS):      # interleave G's so drift hits all
            for n in GROUPS:
                steps = [1000 * rep + s for s in range(1, MD_STEPS + 1)]
                t0 = time.perf_counter()
                results = pool.map(_md_land,
                                   [(n, g, steps) for g in range(n)])
                best[n] = min(best[n], time.perf_counter() - t0)
                if rep == 0:
                    _md_commit(roots[n], results, merge_map)

    nbytes = {}
    for n in GROUPS:
        db = HerculeDB.open(roots[n])
        nbytes[n] = sum(sum(r.nbytes for r in db.view(s).records)
                        for s in range(1, MD_STEPS + 1))
        db.close()
    thr = {n: nbytes[n] / best[n] for n in GROUPS}
    for n in GROUPS:
        emit(f"insitu.multidomain_write_g{n}",
             best[n] / MD_STEPS * 1e6,
             f"{thr[n]/1e6:.0f}MB/s reduce+write scaling="
             f"{thr[n]/thr[1]:.2f}x producers={min(n, procs)}proc "
             f"{nbytes[n]/MD_STEPS/1e6:.1f}MB/ctx",
             repeats=MD_REPS)

    # merge-at-read: the 4-domain merged object must equal the 1-domain
    # reference (counts exactly; float images to fp-roundoff)
    cat1, cat4 = Catalog(roots[1]), Catalog(roots[4])
    checked = mismatched = 0
    t0 = time.perf_counter()
    for reducer in cat1.reducers(1):
        ref, merged = cat1.query(1, reducer), cat4.query(1, reducer)
        for k, a in ref.items():
            b = merged[k]
            checked += 1
            ok = np.array_equal(a, b, equal_nan=True) if a.dtype.kind != "f" \
                else bool(np.allclose(a, b, equal_nan=True, rtol=1e-12,
                                      atol=0) or np.array_equal(
                              a, b, equal_nan=True))
            if not ok:
                mismatched += 1
    t_merge = time.perf_counter() - t0
    emit("insitu.read_merged_g4", t_merge * 1e6,
         f"arrays_checked={checked} mismatched={mismatched} "
         f"domains={cat4.domains(1, cat4.reducers(1)[0])}", repeats=1)
    cat1.db.close()
    cat4.db.close()
    for root in roots.values():
        shutil.rmtree(root, ignore_errors=True)
    if mismatched:
        raise AssertionError(
            f"merge-at-read mismatch: {mismatched}/{checked} arrays")
    return thr[4] / thr[1]


# --------------------------------------------------- device-reduce mode

DEVICE_STEPS = 3
DEVICE_REPS = 3
DEVICE_MAX_LEVEL = 9     # a deeper tree: full snapshots are ~43 MB/step


def run_device() -> float:
    """Device-resident staging + on-device reduction vs the host path.

    Both engines run the 512-res reduction-bound DAG
    (:func:`_live_reducers`) on identical snapshots of a deep Orion
    tree. The host path stages every snapshot through a device→host
    full-resolution copy before reducing; the device path
    (``device_reduce=True``) stages on the accelerator and transfers
    only the reduced objects, accounted by the engine's
    ``device_stats``. Records the per-step bytes of both paths plus
    their ratio (``insitu.device_transfer_ratio``, acceptance floor 5x)
    and verifies the reduced catalogs are bit-identical. Returns the
    transfer ratio.
    """
    tree, _, _ = orion_domains(16, max_level=DEVICE_MAX_LEVEL)
    arrays = tree.to_arrays()
    snap_bytes = sum(v.nbytes for v in arrays.values())

    roots, times, bytes_per_step = {}, {}, {}
    for mode in ("host", "device"):
        root = scratch_dir(f"hx_bench_dev_{mode}_")
        roots[mode] = root
        eng = InTransitEngine(root, _live_reducers(), policy="block",
                              queue_capacity=4,
                              device_reduce=(mode == "device")).start()
        eng.submit(DEVICE_STEPS + 1, arrays)      # warm lanes/compiles
        eng.drain(timeout=300.0)
        best, step = float("inf"), DEVICE_STEPS + 1
        for _ in range(DEVICE_REPS):
            t0 = time.perf_counter()
            for _ in range(DEVICE_STEPS):
                step += 1
                eng.submit(step, arrays)
            eng.drain(timeout=300.0)
            best = min(best, time.perf_counter() - t0)
        times[mode] = best
        n_steps = len(eng.written_steps)
        if mode == "device":
            ds = eng.device_stats
            bytes_per_step[mode] = ds["bytes_to_host"] / max(1, n_steps)
            assert not ds["fallback_runs"], ds   # all three run on device
        else:
            stats = eng.staging.stats
            bytes_per_step[mode] = stats.bytes_staged / max(1, n_steps)
        eng.close()

    # correctness: the device catalog must be bit-identical to the host
    cat_h, cat_d = Catalog(roots["host"]), Catalog(roots["device"])
    step = cat_h.steps()[-1]
    checked = mismatched = 0
    for reducer in cat_h.reducers(step):
        a, b = cat_h.query(step, reducer), cat_d.query(step, reducer)
        for k, v in a.items():
            checked += 1
            if not np.array_equal(v, b[k], equal_nan=True):
                mismatched += 1
    cat_h.db.close()
    cat_d.db.close()
    for root in roots.values():
        shutil.rmtree(root, ignore_errors=True)
    if mismatched:
        raise AssertionError(
            f"device-reduce mismatch: {mismatched}/{checked} arrays")

    ratio = bytes_per_step["host"] / bytes_per_step["device"]
    emit("insitu.device_bytes_transferred", bytes_per_step["device"],
         f"device->host per step (reduced objects only), snapshot="
         f"{snap_bytes/1e6:.1f}MB, arrays_checked={checked} "
         f"mismatched={mismatched}", unit="bytes_per_step",
         repeats=DEVICE_REPS)
    emit("insitu.host_bytes_transferred", bytes_per_step["host"],
         "host-path staging: full snapshot crosses per step",
         unit="bytes_per_step", repeats=DEVICE_REPS)
    emit("insitu.device_transfer_ratio", ratio,
         f"host full-snapshot / device reduced bytes per step "
         f"(acceptance floor 5x), 512-res DAG on "
         f"{tree.n_nodes} nodes", unit="x", repeats=DEVICE_REPS)
    emit("insitu.device_reduce_step", times["device"] / DEVICE_STEPS * 1e6,
         f"{snap_bytes * DEVICE_STEPS / times['device'] / 1e6:.0f}MB/s "
         f"device reduce throughput vs host "
         f"{snap_bytes * DEVICE_STEPS / times['host'] / 1e6:.0f}MB/s "
         f"(host step {times['host']/DEVICE_STEPS*1e6:.0f}us)",
         repeats=DEVICE_REPS)
    return ratio


# --------------------------------------------------- sharded mesh mode

MESH_DEVICES = 4
MESH_REPS = 3


def _mesh_child() -> None:
    """Child body for :func:`run_mesh` (fresh process: the forced host
    device count must be in XLA_FLAGS before jax initializes).

    Runs the 512-res reduction DAG on the deep Orion tree through
    ``MeshDAGRunner`` at 1 device and at the full forced mesh, checks
    parity against the host reducers (slice/hist bitwise at the
    collision-free resolution; projection to the read-side 1e-12 fold
    contract), and prints one tagged JSON line the parent parses.
    """
    import json

    import jax

    from repro.insitu.mesh_reduce import MeshDAGRunner
    from repro.insitu.reducers import ReducerDAG
    from repro.insitu.staging import Snapshot

    ndev = len(jax.devices())
    assert ndev == MESH_DEVICES, ndev
    tree, _, _ = orion_domains(16, max_level=DEVICE_MAX_LEVEL)
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    dag = ReducerDAG(_live_reducers())
    host = dag.run(snap)
    out = {}
    for devices in (1, ndev):
        runner = MeshDAGRunner(dag, devices=devices)
        res = runner.run(snap)                 # warm compiles + upload
        best = float("inf")
        for _ in range(MESH_REPS):
            t0 = time.perf_counter()
            res = runner.run(snap)
            best = min(best, time.perf_counter() - t0)
        checked = mismatched = 0
        for name, o in host.items():
            for k, v in o.items():
                got = np.asarray(res[name][k])
                if name.startswith("proj-"):
                    ok = bool(np.allclose(got, v, rtol=1e-12, atol=0))
                else:
                    ok = np.array_equal(got, v, equal_nan=True)
                checked += 1
                mismatched += not ok
        st = runner.stats.as_dict()
        out[str(devices)] = {
            "t": best, "checked": checked, "mismatched": mismatched,
            "peak_leaf_frac": st["peak_leaf_frac"],
            "leaf_rows": st["leaf_rows"],
            "peak_table_mb": st["peak_device_table_bytes"] / 1e6,
            "fallback_snapshots": st["fallback_snapshots"]}
    print("MESH-JSON " + json.dumps(out), flush=True)


def run_mesh() -> float:
    """Sharded multi-device reduction vs the single-device path.

    Spawns a child with ``XLA_FLAGS=--xla_force_host_platform_device_
    count=4`` (the flag must precede jax init, hence the subprocess) and
    records per-device leaf-table residency
    (``insitu.mesh_peak_leaf_frac``, CI ceiling 0.6 — the proof that no
    device ever holds more than ~1/N of the leaf table) and the
    mesh-vs-single wall-time ratio. On one physical CPU the forced
    devices timeshare cores, so the ratio documents overhead, not
    speedup; residency is the acceptance metric. Returns the residency
    fraction.
    """
    import json
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=%d"
                        % MESH_DEVICES,
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": os.pathsep.join(
               p for p in (os.path.join(root, "src"), root,
                           os.environ.get("PYTHONPATH")) if p)}
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_insitu", "--mesh-child"],
        env=env, cwd=root, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh bench child failed:\n{proc.stderr[-3000:]}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("MESH-JSON "))
    data = json.loads(line[len("MESH-JSON "):])
    bad = {k: v for k, v in data.items()
           if v["mismatched"] or v["fallback_snapshots"]}
    if bad:
        raise AssertionError(f"mesh parity/fallback failure: {bad}")
    single, mesh = data["1"], data[str(MESH_DEVICES)]
    frac = mesh["peak_leaf_frac"]
    ratio = single["t"] / mesh["t"]
    emit("insitu.mesh_peak_leaf_frac", frac,
         f"per-device leaf-table residency at {MESH_DEVICES} forced host "
         f"devices ({mesh['leaf_rows']} leaf rows, "
         f"{mesh['peak_table_mb']:.1f}MB/device table), 512-res DAG, "
         f"arrays_checked={mesh['checked']} mismatched=0 (ceiling 0.6)",
         unit="frac", repeats=MESH_REPS)
    emit("insitu.mesh_vs_single_x", ratio,
         f"single {single['t']*1e3:.0f}ms vs {MESH_DEVICES}-device mesh "
         f"{mesh['t']*1e3:.0f}ms per snapshot (forced host devices "
         f"timeshare one CPU: documents shard_map+merge overhead, "
         f"not parallel speedup)", unit="x", repeats=MESH_REPS)
    emit("insitu.mesh_reduce_step", mesh["t"] * 1e6,
         f"{MESH_DEVICES}-device shard_map reduce wall per snapshot, "
         f"merges: psum(hist) ordered-fold(proj) depth-resolve(slice)",
         repeats=MESH_REPS)
    return frac


# ------------------------------------------------- ref fusion trajectory

FUSE_REPS = 5


def run_ref_fuse() -> float:
    """CPU ``ref`` slice raster: fused single-traversal vs the pre-PR-9
    per-level pyramid. Returns the fuse speedup (unfused/fused wall)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref as ref_kernels

    tree, _, _ = orion_domains(16)
    arrays = tree.to_arrays()
    with jax.experimental.enable_x64():
        offsets = arrays["level_offsets"]
        n = arrays["refine"].shape[0]
        levels = jnp.asarray(
            (np.searchsorted(offsets, np.arange(n), side="right") - 1)
            .astype(np.int32))
        coords = arrays["coords"].astype(np.int32)
        coords2 = jnp.asarray(coords[:, :2])
        c_axis = jnp.asarray(coords[:, 2])
        values = jnp.asarray(arrays["field:density"])
        ok = jnp.asarray(~arrays["refine"])
        kw = dict(position=0.5, resolution=LIVE_RESOLUTION,
                  n_levels=int(offsets.shape[0]) - 1)
        variants = {}
        for label, fn in (("fused", ref_kernels.slice_raster_ref),
                          ("unfused", ref_kernels.slice_raster_ref_unfused)):
            jitted = jax.jit(lambda *a, _f=fn: _f(*a, **kw))
            img = jax.block_until_ready(
                jitted(coords2, c_axis, levels, values, ok))  # compile
            _, t = timeit(lambda: jax.block_until_ready(
                jitted(coords2, c_axis, levels, values, ok)), reps=FUSE_REPS)
            variants[label] = (np.asarray(img), t)
    np.testing.assert_array_equal(variants["fused"][0],
                                  variants["unfused"][0], err_msg="fuse")
    t_fused, t_unfused = variants["fused"][1], variants["unfused"][1]
    emit("insitu.ref_slice_unfused", t_unfused * 1e6,
         f"per-level pyramid slice raster, {LIVE_RESOLUTION}^2, "
         f"{tree.n_nodes} nodes", repeats=FUSE_REPS)
    emit("insitu.ref_slice_fused", t_fused * 1e6,
         "single-traversal fused slice raster (bitwise-equal image)",
         repeats=FUSE_REPS)
    speedup = t_unfused / max(t_fused, 1e-9)
    emit("insitu.ref_slice_fuse_x", speedup,
         f"unfused {t_unfused*1e3:.1f}ms / fused {t_fused*1e3:.1f}ms",
         unit="x", repeats=FUSE_REPS)
    return speedup


# ------------------------------------------------ live lane-backend mode

LIVE_STEPS = 4
LIVE_REPS = 4
LIVE_RESOLUTION = 512


def _live_reducers():
    """Reduction-bound DAG for the lane-scaling measurement.

    Deliberately no LOD pass-through: its whole-tree write is
    GIL-released file I/O that thread lanes already parallelize (and
    the write trajectory is covered by insitu.multidomain_write_*).
    Rasterization at a viz-realistic resolution is where lanes spend
    GIL-held CPU (np.add.at, per-node paint loops, per-level
    histograms) — the work a process lane actually takes off the
    producer's interpreter.
    """
    from repro.insitu import (LevelHistogramReducer, ProjectionReducer,
                              SliceReducer)
    return [SliceReducer(field="density", axis=2, position=0.5,
                         resolution=LIVE_RESOLUTION),
            ProjectionReducer(field="density",
                              resolution=LIVE_RESOLUTION),
            LevelHistogramReducer(field="density", bins=64,
                                  lo=0.0, hi=50.0)]


def run_live_backends() -> float:
    """Live-pipeline lane scaling: the engine's thread vs process
    backends on identical pre-partitioned steps (block policy with a
    deep queue, nothing drops — both backends do exactly the same
    reduce+write work end to end, staging included). Thread lanes share
    the GIL; process lanes run over shared-memory staging. Returns the
    process/thread throughput ratio at ``max(GROUPS)`` contributor
    groups (the PR-4 acceptance bar: >1.3x on the CI runner)."""
    from repro.insitu.partition import partition_snapshot
    tree, _, _ = orion_domains(16)
    arrays = tree.to_arrays()
    parts = {n: partition_snapshot(arrays, "amr", n) for n in GROUPS}
    configs = [("thread", max(GROUPS))] + [("process", n) for n in GROUPS]
    times, sizes = {}, {}
    for backend, n in configs:
        root = scratch_dir(f"hx_bench_live_{backend}{n}_")
        eng = InTransitEngine(root, _live_reducers(), domains=n,
                              backend=backend, policy="block",
                              queue_capacity=4, ncf=1).start()
        eng.submit_parts(LIVE_STEPS + 1, parts[n])   # warm lanes/imports
        eng.drain(timeout=300.0)
        best, step = float("inf"), LIVE_STEPS + 1
        for _ in range(LIVE_REPS):
            t0 = time.perf_counter()
            for _ in range(LIVE_STEPS):
                step += 1
                eng.submit_parts(step, parts[n])
            eng.drain(timeout=300.0)
            best = min(best, time.perf_counter() - t0)
        eng.close()
        db = HerculeDB.open(root)
        ctxs = db.contexts()
        assert len(ctxs) == LIVE_REPS * LIVE_STEPS + 1, ctxs
        sizes[(backend, n)] = sum(r.nbytes for s in ctxs[-LIVE_STEPS:]
                                  for r in db.view(s).records)
        db.close()
        times[(backend, n)] = best
        shutil.rmtree(root, ignore_errors=True)
    thr = {k: sizes[k] / times[k] for k in times}
    for backend, n in configs:
        # step speedup = wall-time ratio at equal step count (bytes/ctx
        # grow with n — every domain rasters its own full-res part — so
        # MB/s does not compare across group counts, only backends)
        speedup = times[(backend, 1)] / times[(backend, n)] \
            if (backend, 1) in times else float("nan")
        emit(f"insitu.live_{backend}_g{n}",
             times[(backend, n)] / LIVE_STEPS * 1e6,
             f"{thr[(backend, n)]/1e6:.0f}MB/s live reduce+write "
             f"step_speedup={speedup:.2f}x lanes={n} "
             f"{sizes[(backend, n)]/LIVE_STEPS/1e6:.1f}MB/ctx policy=block",
             repeats=LIVE_REPS)
    g = max(GROUPS)
    ratio = thr[("process", g)] / thr[("thread", g)]
    emit(f"insitu.live_process_vs_thread_g{g}", ratio,
         f"process lanes over shm staging vs GIL-shared thread lanes "
         f"at {g} groups (acceptance floor 1.3x)", unit="ratio",
         repeats=LIVE_REPS)
    return ratio


# --------------------------------------------- observability overhead

OBS_STEPS = 5
OBS_REPS = 8


def run_obs_overhead() -> float:
    """Telemetry cost on the live pipeline: instrumented vs bare.

    One thread-backend engine runs identical step batches with the
    metrics kill switch (``repro.obs.metrics.ENABLED``) flipped each
    rep — interleaved best-of, so machine drift hits both arms alike.
    Tracing stays off in both arms (it is opt-in at runtime); what's
    measured is the always-on cost: histogram observes on the submit /
    reduce / write / commit paths plus the staging stat words. Emits
    ``insitu.obs_overhead_pct`` (CI ceiling: 2%).
    """
    from repro.obs import metrics as obs_metrics
    tree, _, _ = orion_domains(16)
    slicer = SliceReducer(field="density", axis=2, position=0.5,
                          resolution=RESOLUTION)
    root = scratch_dir("hx_bench_obs_")
    eng = InTransitEngine(root, [slicer], policy="block",
                          queue_capacity=4).start()
    step = 0
    for _ in range(OBS_STEPS):          # warm lanes, page caches
        step += 1
        eng.submit(step, tree)
    eng.drain(timeout=300.0)
    best = {False: float("inf"), True: float("inf")}
    try:
        for rep in range(OBS_REPS):
            # alternate which arm goes first: a drifting machine (cache
            # warmth, turbo decay) must not bias one arm systematically
            order = (False, True) if rep % 2 == 0 else (True, False)
            for enabled in order:
                obs_metrics.set_enabled(enabled)
                t0 = time.perf_counter()
                for _ in range(OBS_STEPS):
                    step += 1
                    eng.submit(step, tree)
                eng.drain(timeout=300.0)
                best[enabled] = min(best[enabled],
                                    time.perf_counter() - t0)
    finally:
        obs_metrics.set_enabled(True)
        eng.close()
    shutil.rmtree(root, ignore_errors=True)
    pct = max(0.0, 100.0 * (best[True] - best[False]) / best[False])
    emit("insitu.obs_overhead_pct", pct,
         f"instrumented {best[True]/OBS_STEPS*1e3:.2f}ms/step vs bare "
         f"{best[False]/OBS_STEPS*1e3:.2f}ms/step, thread backend, "
         f"best-of-{OBS_REPS} interleaved (ceiling 2%)",
         unit="pct", repeats=OBS_REPS)
    _run_ledger_arm(tree, slicer, best[True])
    return pct


def _run_ledger_arm(tree, slicer, instrumented_best: float) -> None:
    """Third arm: the run ledger on top of the instrumented pipeline.

    Tracing + events + a manual-cadence :class:`RunLedger` bound to the
    engine — the full flight-recorder stack, flushed once per step
    batch (a far hotter cadence than the default 2 s interval, so the
    measured cost is an upper bound). Emits the wall overhead vs the
    instrumented arm (informational) and ``obs.ledger_bytes_per_step``
    — the durable telemetry footprint per pipeline step, which gets a
    CI ceiling: a ledger that silently bloats its flushes would blow a
    run's storage budget long before it blows its time budget.
    """
    from repro.obs import RunLedger, TRACER
    root = scratch_dir("hx_bench_ledger_")
    ledger = RunLedger(root, "trainer", interval=0)
    eng = InTransitEngine(root, [slicer], policy="block",
                          queue_capacity=4, ledger=ledger).start()
    prev_traced = TRACER.enabled
    TRACER.enabled = True
    step = 0
    best = float("inf")
    try:
        for _ in range(OBS_STEPS):      # warm lanes, page caches
            step += 1
            eng.submit(step, tree)
        eng.drain(timeout=300.0)
        ledger.flush()
        steps_before = step
        bytes_before = ledger.bytes_written
        for _ in range(OBS_REPS):
            t0 = time.perf_counter()
            for _ in range(OBS_STEPS):
                step += 1
                eng.submit(step, tree)
            eng.drain(timeout=300.0)
            ledger.flush()
            best = min(best, time.perf_counter() - t0)
        bytes_per_step = (ledger.bytes_written - bytes_before) \
            / (step - steps_before)
    finally:
        TRACER.enabled = prev_traced
        eng.close()
        ledger.close()
    shutil.rmtree(root, ignore_errors=True)
    pct = max(0.0, 100.0 * (best - instrumented_best) / instrumented_best)
    emit("insitu.ledger_overhead_pct", pct,
         f"ledger+trace {best/OBS_STEPS*1e3:.2f}ms/step vs instrumented "
         f"{instrumented_best/OBS_STEPS*1e3:.2f}ms/step, one flush per "
         f"{OBS_STEPS}-step batch (informational)",
         unit="pct", repeats=OBS_REPS)
    emit("obs.ledger_bytes_per_step", bytes_per_step,
         f"durable telemetry footprint: spans+events+attribution+health "
         f"per pipeline step at per-batch flush cadence",
         unit="bytes", repeats=OBS_REPS)


# ------------------------------------------------------- serving mode

SERVE_VIEWERS = 64


def run_serve(n_viewers: int = SERVE_VIEWERS) -> float:
    """Concurrent-viewer serving: coalescing ratio, sustained QPS, p99.

    The paper's many-viewers scenario at benchmark scale. Arm one is
    the uncoalesced path the issue names — every request pays its own
    decode+merge (a no-cache catalog: the LRU only helps once an
    object is *warm*, and on a cold storm concurrent misses race past
    it nondeterministically). Arm two routes the same storm through
    ``ServeEngine.fetch`` over a cold cache: single-flight coalescing
    collapses the herd onto one backend read, deterministically. Their
    read-count ratio is ``insitu.serve_coalesce_ratio_c64`` (CI floor:
    5x; acceptance: ≥5x at 64 viewers).

    The HTTP leg then measures end-to-end serving through
    ``CatalogServer`` + ``RemoteCatalog`` — ``insitu.serve_qps``
    (sustained, warm cache: the dashboard steady state) and
    ``insitu.serve_p99_ms`` (per-request wall time incl. connection
    setup) at the same concurrency.
    """
    import threading

    from repro.insitu import CatalogServer, RemoteCatalog, ServeEngine

    tree, _, _ = orion_domains(4)
    root = scratch_dir("hx_bench_serve_")
    eng = InTransitEngine(root, _live_reducers(), domains=2,
                          policy="block", queue_capacity=4).start()
    eng.submit(1, tree)
    eng.drain(timeout=300.0)
    eng.close()

    def storm(call):
        bar = threading.Barrier(n_viewers)
        errs = []

        def go(i):
            bar.wait()
            try:
                call(i)
            except Exception as exc:        # noqa: BLE001 — surfaced below
                errs.append(exc)
        ts = [threading.Thread(target=go, args=(i,))
              for i in range(n_viewers)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        if errs:
            raise RuntimeError(f"serve storm errors: {errs[:3]}")
        return time.perf_counter() - t0

    cat = Catalog(root)
    name = cat.reducers(1)[0]

    # -- arm 1: per-request decode+merge -> n_viewers reads
    uncached = Catalog(root, cache_entries=0)
    t_direct = storm(lambda i: uncached.query(1, name))
    reads_direct = uncached.cache_info()["misses"]
    uncached.close()

    # -- arm 2: the same herd through the serving engine -> 1 read
    cat.clear_cache()
    serve = ServeEngine(cat, workers=4)
    t_engine = storm(lambda i: serve.fetch(1, name, client=f"v{i}"))
    st = serve.stats()
    serve.close()
    reads_engine = max(1, st["backend_reads"])
    ratio = reads_direct / reads_engine
    emit("insitu.serve_coalesce_ratio_c64", ratio,
         f"{reads_direct} direct reads vs {reads_engine} coalesced "
         f"({st['coalesced']} joined flights, {st['cache_serves']} "
         f"cache-served) at {n_viewers} viewers; "
         f"storm {t_direct*1e3:.0f}ms -> {t_engine*1e3:.0f}ms "
         f"(floor 5x)", unit="x")

    # -- HTTP leg: sustained QPS + p99 at the same concurrency
    srv = CatalogServer(cat, port=0).start()
    lat: list[float] = []
    lock = threading.Lock()
    per_viewer = 8
    regions = [None, ((0, 128), (0, 128)), ((64, 192), (64, 192))]
    RemoteCatalog(srv.url).query(1, name)   # warm the server cache

    def viewer(i):
        rc = RemoteCatalog(srv.url, client_id=f"v{i}")
        mine = []
        for q in range(per_viewer):
            t0 = time.perf_counter()
            rc.query(1, name, region=regions[(i + q) % len(regions)])
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    elapsed = storm(viewer)
    srv.close()
    cat.close()
    shutil.rmtree(root, ignore_errors=True)
    qps = len(lat) / elapsed
    p99 = float(np.percentile(np.asarray(lat) * 1e3, 99))
    emit("insitu.serve_qps", qps,
         f"{len(lat)} requests over {n_viewers} viewers in "
         f"{elapsed:.2f}s, warm cache, region mix", unit="qps")
    emit("insitu.serve_p99_ms", p99,
         f"p50={np.percentile(np.asarray(lat)*1e3, 50):.1f}ms "
         f"mean={np.mean(lat)*1e3:.1f}ms", unit="ms")
    return ratio


# ------------------------------------------------- single-writer mode

def _compute_step(tree):
    """Stand-in compute work per step: touch the fields like a solver."""
    v = tree.fields["density"]
    return float(v.sum() + np.abs(v).max())


def run(n_domains: int = 16, steps: int = 8):
    tree, _, pruned = orion_domains(n_domains)
    slicer = SliceReducer(field="density", axis=2, position=0.5,
                          resolution=RESOLUTION)

    # -------- multi-domain contributor-group scaling + merge-at-read
    scaling = run_multidomain()

    # -------- live pipeline: thread vs process lane backends
    run_live_backends()

    # -------- device-resident staging + on-device reduction
    run_device()

    # -------- sharded multi-device reduction (subprocess: forced mesh)
    run_mesh()

    # -------- CPU ref raster fusion trajectory
    run_ref_fuse()

    # -------- telemetry overhead: instrumented vs bare, same engine
    run_obs_overhead()

    # -------- concurrent-viewer serving: coalescing, QPS, p99
    run_serve()

    # ---------------- compute loop, engine OFF
    t0 = time.perf_counter()
    for _ in range(steps):
        _compute_step(tree)
    t_off = time.perf_counter() - t0

    # ---------------- compute loop, engine ON (drop-oldest, never blocks)
    red_root = scratch_dir("hx_bench_insitu_")
    eng = InTransitEngine(red_root, [slicer], policy="drop-oldest",
                          queue_capacity=2).start()
    t0 = time.perf_counter()
    for s in range(1, steps + 1):
        _compute_step(tree)
        eng.submit(s, tree)
    t_on = time.perf_counter() - t0
    eng.drain()
    stats = eng.staging.stats
    overhead = (t_on - t_off) / steps
    emit("insitu.compute_overhead", overhead * 1e6,
         f"loop_off={t_off*1e3:.1f}ms loop_on={t_on*1e3:.1f}ms "
         f"accepted={stats.accepted} evicted={stats.evicted} "
         f"staged={stats.bytes_staged/1e6:.1f}MB policy=drop-oldest")
    eng.close()

    # ---------------- post-hoc baseline: full HDep objects -> assemble -> slice
    full_root = scratch_dir("hx_bench_posthoc_")
    db = HerculeDB.create(full_root, kind="hdep", ncf=4)
    ctx = db.begin_context(0)
    for d, pt in enumerate(pruned):
        api.write_object(ctx, "amr_tree", d, pt)
    ctx.finalize()

    def posthoc_slice():
        g = analysis.load_global_tree(db, 0)
        return analysis.slice_image(g, "density", axis=2, position=0.5,
                                    resolution=RESOLUTION)
    ref, t_posthoc = timeit(posthoc_slice, reps=2)

    # ---------------- in-transit catalog: cold read, then cached
    cat = Catalog(red_root)
    step = cat.steps()[-1]
    _, t_cold = timeit(lambda: cat.query(step, slicer.name), reps=1)
    img = cat.query(step, slicer.name)["image"]
    _, t_warm = timeit(lambda: cat.query(step, slicer.name), reps=5)
    assert img.shape == ref.shape
    emit("insitu.query_cold", t_cold * 1e6,
         f"vs_posthoc={t_posthoc*1e6:.0f}us "
         f"speedup={t_posthoc/max(t_cold,1e-9):.1f}x")
    emit("insitu.query_cached", t_warm * 1e6,
         f"speedup_vs_posthoc={t_posthoc/max(t_warm,1e-9):.0f}x "
         f"cache={cat.cache_info()}")
    shutil.rmtree(red_root, ignore_errors=True)
    shutil.rmtree(full_root, ignore_errors=True)
    return scaling


if __name__ == "__main__":
    import sys as _sys
    if "--mesh-child" in _sys.argv:
        _mesh_child()
    else:
        run()
