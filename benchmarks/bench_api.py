"""Unified-API read path: indexed ContextView vs the seed's per-read
manifest re-parse on a many-record context.

Before the api layer, every ``HerculeDB.read`` re-opened and re-parsed
``MANIFEST.json`` and linearly scanned the record list. ``ContextView``
parses the manifest once and serves point reads as hash lookups; this
benchmark shows the repeated-read speedup on a 1000-record context and
the additional win of batched reads on the ``io_threads`` pool.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.hercule import HerculeDB
from repro.hercule.database import Record, decode_record

from .common import emit

N_RECORDS = 1000
N_READS = 200
STEP = 0


def _seed_read(db: HerculeDB, step: int, domain: int, name: str):
    """The pre-api read path, verbatim: parse manifest, scan linearly."""
    with open(os.path.join(db._ctx_dir(step), "MANIFEST.json")) as f:
        raw = json.load(f)
    for r in raw["records"]:
        if r["domain"] == domain and r["name"] == name:
            return decode_record(db, Record.from_json(r))
    raise KeyError(f"({domain}, {name}) not in context {step}")


def _build(root: str) -> HerculeDB:
    db = HerculeDB.create(root, kind="hdep", ncf=4)
    ctx = db.begin_context(STEP)
    rng = np.random.default_rng(0)
    for i in range(N_RECORDS):
        ctx.write_array(i % 4, f"analysis/t{i:04d}",
                        rng.standard_normal(32).astype(np.float32))
    ctx.finalize()
    return db


def run() -> float:
    root = tempfile.mkdtemp(prefix="hx_bench_api_")
    db = _build(root)
    rng = np.random.default_rng(1)
    targets = [(int(i % 4), f"analysis/t{i:04d}")
               for i in rng.integers(0, N_RECORDS, N_READS)]

    t0 = time.perf_counter()
    for d, n in targets:
        _seed_read(db, STEP, d, n)
    t_seed = time.perf_counter() - t0

    t0 = time.perf_counter()
    for d, n in targets:
        db.read(STEP, d, n)  # routes through the cached ContextView
    t_view = time.perf_counter() - t0

    speedup = t_seed / t_view
    emit("api.point_read_seed", t_seed / N_READS * 1e6,
         f"records={N_RECORDS} reads={N_READS} reparse-per-read")
    emit("api.point_read_view", t_view / N_READS * 1e6,
         f"records={N_RECORDS} reads={N_READS} speedup={speedup:.1f}x")

    # batched read_many on heavy records: the io_threads pool engages once
    # the aggregate payload clears ContextView.PARALLEL_MIN_BYTES
    ctx = db.begin_context(1)
    rng = np.random.default_rng(2)
    heavy = [(d, f"analysis/big{d}") for d in range(16)]
    for d, n in heavy:
        ctx.write_array(d, n, rng.standard_normal((512, 512)))
    ctx.finalize()
    view = db.view(1)
    t0 = time.perf_counter()
    seq = [view.read(d, n) for d, n in heavy]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = view.read_many(heavy)
    t_batch = time.perf_counter() - t0
    assert len(batched) == len(seq) == len(heavy)
    emit("api.batched_read_many", t_batch / len(heavy) * 1e6,
         f"records=16x2MB io_threads={db.io_threads} "
         f"vs_sequential={t_seq / max(t_batch, 1e-9):.1f}x")
    db.close()
    return speedup


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    s = run()
    print(f"# indexed vs reparse speedup: {s:.1f}x "
          f"({'OK' if s >= 5 else 'BELOW TARGET'} — acceptance floor 5x)")
    sys.exit(0 if s >= 5 else 1)
