"""Paper figs. 5/6: father-son delta compression rate and speed per
domain for the density and velocity_y fields (paper: 16.26 % @ 1321 MB/s
and 17.91 % @ 1286 MB/s, sequential C on a laptop i5).

Two speed paths are reported:
  * host codec (numpy orchestration; compile-cached via shape bucketing)
  * jit'd XLA pipeline (kernels/ops.compress_bits — the TPU-bound path,
    measured here on 1 CPU core)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream as bs, fpdelta
from repro.kernels import ops

from .common import emit, orion_domains, timeit


def _tree_groups(tree, field):
    """Concatenate all father/son groups of a tree field."""
    v = tree.fields[field]
    cs = tree.child_start()
    preds, sons = [], []
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        fathers = np.flatnonzero(tree.refine[sl]) + sl.start
        if fathers.size == 0:
            continue
        preds.append(v[fathers])
        sons.append(v[(cs[fathers][:, None] + np.arange(8)[None, :])])
    return np.concatenate(preds), np.concatenate(sons)


def run(n_domains: int = 16):
    _, _, pruned = orion_domains(n_domains)
    for field, paper in (("density", "16.26%@1321MB/s"),
                         ("velocity_y", "17.91%@1286MB/s")):
        rates, speeds = [], []
        for d, t in enumerate(pruned):
            tc, dt = timeit(fpdelta.encode_tree_field, t, field, reps=1)
            rate = fpdelta.tree_field_rate(t, tc)
            mb = t.n_nodes * 8 / 1e6
            rates.append(rate)
            speeds.append(mb / dt)
            emit(f"fig{5 if field == 'density' else 6}.fpdelta.domain{d:02d}",
                 dt * 1e6, f"field={field} rate={rate*100:.2f}% "
                 f"speed={mb/dt:.0f}MB/s")
        emit(f"fig{5 if field == 'density' else 6}.fpdelta.summary", 0.0,
             f"field={field} avg_rate={np.mean(rates)*100:.2f}% "
             f"avg_speed={np.mean(speeds):.0f}MB/s paper={paper}")

    # amortized host-codec speed on a paper-scale tree (~10x bigger)
    from repro.sim import amrgen, fields
    gt = amrgen.generate_tree(fields.orion(seed=7), min_level=3, max_level=9,
                              threshold=1.0, level_factor=1.6)
    fpdelta.encode_tree_field(gt, "density")  # warm jit buckets
    tc, dt = timeit(fpdelta.encode_tree_field, gt, "density", reps=2)
    _, ddt = timeit(fpdelta.decode_tree_field, gt, tc, reps=2)
    mb_g = gt.n_nodes * 8 / 1e6
    emit("fig5.fpdelta.global_tree", dt * 1e6,
         f"encode={mb_g/dt:.0f}MB/s decode={mb_g/ddt:.0f}MB/s "
         f"rate={fpdelta.tree_field_rate(gt, tc)*100:.2f}% "
         f"nodes={gt.n_nodes} (1 CPU core; paper: seq C, i5)")

    # jit'd pipeline speed on one big padded group set (TPU-bound path)
    big = max(pruned, key=lambda t: t.n_nodes)
    pred, sons = _tree_groups(big, "density")
    g = (pred.shape[0] // ops.BLOCK_G) * ops.BLOCK_G
    pred, sons = pred[:g], sons[:g]
    ph, plo = bs.f64_to_pair(np.broadcast_to(pred[:, None], (g, 8)))
    sh, slo = bs.f64_to_pair(sons)
    args = [jnp.asarray(a.T.copy()) for a in (ph, plo, sh, slo)]
    fn = lambda: jax.block_until_ready(
        ops.compress_bits(*args, zbits=4, width=64, backend="ref"))
    fn()  # compile
    _, dt = timeit(fn, reps=5)
    mb = g * 8 * 8 / 1e6
    emit("fig5.fpdelta.jit_pipeline", dt * 1e6,
         f"speed={mb/dt:.0f}MB/s groups={g} (XLA path, 1 CPU core)")


if __name__ == "__main__":
    run()
