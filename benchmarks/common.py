"""Shared benchmark utilities: Orion-like dataset cache, record emission.

Every measurement flows through :func:`emit`, which both prints the
historical ``name,value,derived`` CSV line and appends a machine-readable
record to :data:`RECORDS`. The record schema — ``name`` / ``value`` /
``unit`` / ``repeats`` / ``derived`` — is shared by ``benchmarks/run.py
--json`` and the CI-archived ``BENCH_*.json`` trajectory files, so every
PR's bench artifact is comparable to every other's.
"""
from __future__ import annotations

import functools
import os
import tempfile
import time

#: machine-readable benchmark records accumulated by :func:`emit`
RECORDS: list[dict] = []


def scratch_dir(prefix: str) -> str:
    """mkdtemp on a local tmpfs when one exists.

    Containers often mount ``/tmp`` on a network filesystem (9p,
    overlay), whose serialization artifacts would drown the I/O effects
    the benchmarks measure; ``/dev/shm`` is reliably local.
    ``BENCH_TMPDIR`` overrides the choice.
    """
    for cand in (os.environ.get("BENCH_TMPDIR"), "/dev/shm"):
        if cand and os.path.isdir(cand) and os.access(cand, os.W_OK):
            return tempfile.mkdtemp(prefix=prefix, dir=cand)
    return tempfile.mkdtemp(prefix=prefix)


@functools.lru_cache(maxsize=2)
def orion_domains(n_domains: int = 16, max_level: int = 8, seed: int = 7):
    """(global tree, per-domain local trees, pruned trees) — cached."""
    from repro.core import decompose, prune
    from repro.sim import amrgen, fields
    f = fields.orion(seed=seed)
    tree = amrgen.generate_tree(f, min_level=3, max_level=max_level,
                                threshold=1.0, level_factor=1.6)
    dom = decompose.assign_domains(tree, n_domains)
    idx = decompose._LevelIndex(tree)
    locals_, pruned = [], []
    for d in range(n_domains):
        lt = decompose.local_tree(tree, dom, d, coarse_level=3, index=idx)
        locals_.append(lt)
        pruned.append(prune.prune(lt))
    return tree, locals_, pruned


def emit(name: str, value: float, derived: str = "", *,
         unit: str = "us_per_call", repeats: int | None = None) -> dict:
    """Record one measurement and print the CSV line.

    ``value`` keeps the historical meaning (µs per call unless ``unit``
    says otherwise); ``derived`` is the free-text context column.
    """
    rec = {"name": name, "value": float(value), "unit": unit,
           "repeats": repeats, "derived": derived}
    RECORDS.append(rec)
    print(f"{name},{value:.1f},{derived}")
    return rec


def timeit(fn, *args, reps: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
