"""Shared benchmark utilities: Orion-like dataset cache, CSV emission."""
from __future__ import annotations

import functools
import time

import numpy as np


@functools.lru_cache(maxsize=2)
def orion_domains(n_domains: int = 16, max_level: int = 8, seed: int = 7):
    """(global tree, per-domain local trees, pruned trees) — cached."""
    from repro.core import decompose, prune
    from repro.sim import amrgen, fields
    f = fields.orion(seed=seed)
    tree = amrgen.generate_tree(f, min_level=3, max_level=max_level,
                                threshold=1.0, level_factor=1.6)
    dom = decompose.assign_domains(tree, n_domains)
    idx = decompose._LevelIndex(tree)
    locals_, pruned = [], []
    for d in range(n_domains):
        lt = decompose.local_tree(tree, dom, d, coarse_level=3, index=idx)
        locals_.append(lt)
        pruned.append(prune.prune(lt))
    return tree, locals_, pruned


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *args, reps: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
