"""Paper fig. 3: % of cells removed per domain by the tree pruning
algorithm on Orion-like data (paper: avg 31.3 %, worst 17.2 %, best
47.3 %)."""
from __future__ import annotations

import numpy as np

from repro.core import prune

from .common import emit, orion_domains, timeit


def run(n_domains: int = 16):
    tree, locals_, pruned = orion_domains(n_domains)
    fracs = [prune.removed_fraction(l, p) for l, p in zip(locals_, pruned)]
    # time one prune pass on the largest domain
    biggest = max(locals_, key=lambda t: t.n_nodes)
    _, dt = timeit(prune.prune, biggest)
    for d, f in enumerate(fracs):
        emit(f"fig3.pruning.domain{d:02d}", dt * 1e6,
             f"removed={f*100:.1f}%")
    emit("fig3.pruning.summary", dt * 1e6,
         f"avg={np.mean(fracs)*100:.1f}% worst={np.min(fracs)*100:.1f}% "
         f"best={np.max(fracs)*100:.1f}% paper_avg=31.3% "
         f"paper_worst=17.2% paper_best=47.3% "
         f"global_nodes={tree.n_nodes}")
    return fracs


if __name__ == "__main__":
    run()
