"""Paper fig. 7: strong-scaling write throughput, RAMSES-legacy
one-file-per-process vs Hercule NCF aggregation, + file-count table.

Scaled to the container (threads stand in for MPI ranks; /tmp stands in
for Lustre — absolute GB/s is NOT comparable to the paper's 300 GB/s
scratch, the *trend* and the file-count reduction are the reproduction).
Writers within a contributor group serialize through the group's file
(Hercule's aggregation semantics); distinct groups write concurrently
(stripe_count=NCF analogue).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import shutil
import tempfile
import time

import numpy as np

from .common import emit


def _legacy_write(root: str, n_writers: int, payload: bytes) -> float:
    """One file per process (AMR file + heavier HYDRO file, like RAMSES)."""
    os.makedirs(root, exist_ok=True)

    def one(i):
        for suffix, frac in (("amr", 0.25), ("hydro", 1.0)):
            with open(os.path.join(root, f"out_{suffix}.{i:05d}"), "wb") as f:
                f.write(payload[: int(len(payload) * frac)])
                f.flush()
                os.fsync(f.fileno())
    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=min(16, n_writers)) as pool:
        list(pool.map(one, range(n_writers)))
    return time.perf_counter() - t0


def _hercule_write(root: str, n_writers: int, ncf: int, payload: bytes) -> float:
    from repro.hercule import HerculeDB
    db = HerculeDB.create(root, kind="hprot", ncf=ncf)
    ctx = db.begin_context(0)
    groups = {}
    for d in range(n_writers):
        groups.setdefault(db.group_of(d), []).append(d)

    def one(group_domains):
        for d in group_domains:
            ctx.write_bytes(d, "data", payload)
    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=min(16, len(groups))) as pool:
        list(pool.map(one, groups.values()))
    ctx.finalize()
    t = time.perf_counter() - t0
    nf = db.n_files()
    db.close()
    return t, nf


def run(writers=(16, 32, 64), mb_per_writer: float = 8.0):
    payload = np.random.default_rng(0).bytes(int(mb_per_writer * 1e6))
    base = tempfile.mkdtemp(prefix="hx_io_")
    try:
        for n in writers:
            total_gb = n * 1.25 * mb_per_writer / 1e3  # legacy writes 1.25x
            dt = _legacy_write(os.path.join(base, f"legacy{n}"), n, payload)
            emit(f"fig7.io.legacy.n{n}", dt * 1e6,
                 f"bw={total_gb/dt:.2f}GB/s files={2*n}")
            for ncf in (4, 8, 16):
                root = os.path.join(base, f"hx{n}_{ncf}")
                (dt, nf) = _hercule_write(root, n, ncf, payload)
                gb = n * mb_per_writer / 1e3
                emit(f"fig7.io.hercule.n{n}.ncf{ncf}", dt * 1e6,
                     f"bw={gb/dt:.2f}GB/s files={nf} "
                     f"file_reduction={2*n/max(nf,1):.1f}x")
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    run()
