"""Benchmark harness entry point: ``python -m benchmarks.run``.

One function per paper table/figure (DESIGN.md §9). Output format:
``name,us_per_call,derived`` CSV on stdout.
"""
from __future__ import annotations

import sys
import traceback


def main() -> int:
    print("name,us_per_call,derived")
    failures = []
    from . import (bench_api, bench_boolcodec, bench_checkpoint,
                   bench_fpdelta, bench_insitu, bench_io_scaling,
                   bench_pruning, bench_roofline)
    for mod in (bench_pruning, bench_boolcodec, bench_fpdelta,
                bench_io_scaling, bench_api, bench_checkpoint,
                bench_insitu, bench_roofline):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
