"""Benchmark harness entry point: ``python -m benchmarks.run``.

One function per paper table/figure (DESIGN.md §9). Output format:
``name,us_per_call,derived`` CSV on stdout; ``--json PATH`` additionally
writes every record (schema: ``benchmarks/common.py``) plus floor
verdicts — the file CI archives as the ``BENCH_<PR>.json`` trajectory
artifact. ``--only a,b`` restricts to a subset of bench modules.

Floors: a module listed in :data:`FLOORS` must ``run()``-return at least
its floor value (today: the unified-API indexed-read speedup ≥5x); a
shortfall is a regression and fails the harness like an exception would.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback

from . import common

#: module name -> minimum acceptable ``run()`` return value
FLOORS = {"bench_api": 5.0,
          # async checkpoint stall must be <= 0.5x the sync save wall
          # (bench_checkpoint returns sync_stall / async_stall)
          "bench_checkpoint": 2.0}

#: record name -> maximum acceptable emitted value (checked when the
#: record exists; an absent record means its module was deselected or
#: already failed with a traceback)
CEILINGS = {"insitu.obs_overhead_pct": 2.0,
            # sharded mesh reduction: no device may hold more than ~1/N
            # (+ padding slack) of the leaf table at the 4-device bench
            "insitu.mesh_peak_leaf_frac": 0.6,
            # durable telemetry footprint (measures ~3 kB/step at the
            # bench's per-batch flush cadence; 4x headroom): a ledger
            # that silently bloats its flushes fails here, not in prod
            "obs.ledger_bytes_per_step": 12288.0}

#: record name -> minimum acceptable emitted value, same existence
#: semantics as CEILINGS (today: the serving engine must coalesce a
#: 64-viewer herd down by at least 5x vs per-request decode+merge)
RECORD_FLOORS = {"insitu.serve_coalesce_ratio_c64": 5.0}


def _modules():
    from . import (bench_api, bench_boolcodec, bench_checkpoint,
                   bench_fpdelta, bench_insitu, bench_io_scaling,
                   bench_pruning, bench_roofline)
    return [bench_pruning, bench_boolcodec, bench_fpdelta,
            bench_io_scaling, bench_api, bench_checkpoint,
            bench_insitu, bench_roofline]


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write machine-readable records + floor verdicts")
    p.add_argument("--only", default=None, metavar="A,B",
                   help="comma-separated bench module names "
                        "(e.g. bench_api,bench_insitu)")
    args = p.parse_args(argv)

    modules = _modules()
    if args.only:
        want = {w if w.startswith("bench_") else f"bench_{w}"
                for w in args.only.split(",") if w}
        names = {m.__name__.rsplit(".", 1)[-1] for m in modules}
        unknown = want - names
        if unknown:
            print(f"unknown bench module(s) {sorted(unknown)}; "
                  f"available: {sorted(names)}", file=sys.stderr)
            return 2
        modules = [m for m in modules
                   if m.__name__.rsplit(".", 1)[-1] in want]

    print("name,us_per_call,derived")
    failures, floors = [], {}
    for mod in modules:
        name = mod.__name__.rsplit(".", 1)[-1]
        try:
            result = mod.run()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            continue
        floor = FLOORS.get(name)
        if floor is not None:
            ok = result is not None and float(result) >= floor
            floors[name] = {"floor": floor,
                            "value": None if result is None
                            else float(result),
                            "ok": ok}
            if not ok:
                failures.append(f"{name}<floor {floor}")

    ceilings, record_floors = {}, {}
    by_name = {r["name"]: r for r in common.RECORDS}
    for rname, cap in CEILINGS.items():
        rec = by_name.get(rname)
        if rec is None:
            continue
        ok = float(rec["value"]) <= cap
        ceilings[rname] = {"ceiling": cap, "value": float(rec["value"]),
                           "ok": ok}
        if not ok:
            failures.append(f"{rname}>ceiling {cap}")
    for rname, floor in RECORD_FLOORS.items():
        rec = by_name.get(rname)
        if rec is None:
            continue
        ok = float(rec["value"]) >= floor
        record_floors[rname] = {"floor": floor,
                                "value": float(rec["value"]), "ok": ok}
        if not ok:
            failures.append(f"{rname}<floor {floor}")

    if args.json:
        payload = {
            "schema": "bench-record/v1",
            "python": platform.python_version(),
            "platform": platform.platform(),
            "records": common.RECORDS,
            "floors": floors,
            "record_floors": record_floors,
            "ceilings": ceilings,
            "failures": failures,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(common.RECORDS)} records -> {args.json}",
              file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
