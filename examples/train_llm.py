"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack (WSD schedule, async Hercule checkpoints at one
frequency, HDep analysis dumps at another — the paper's fig. 1 dual flow).

    PYTHONPATH=src python examples/train_llm.py [--steps 300] [--tiny]

On this 1-core CPU container ~100M x 300 steps takes a while; --tiny
(default steps/size used by CI) keeps it minutes.
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.train import optim
from repro.train.trainer import Trainer

CKPT = "/tmp/hx_train_llm"


def model_100m() -> ModelConfig:
    """~100M params, stablelm-family layout."""
    return ModelConfig(
        name="hx-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=2048, vocab_size=32768,
        mlp_act="swiglu", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced width/steps for CI")
    args = ap.parse_args()

    shutil.rmtree(CKPT, ignore_errors=True)
    shutil.rmtree(CKPT + "_hdep", ignore_errors=True)
    if args.tiny:
        cfg = dataclasses.replace(get_smoke_config("stablelm_1_6b"),
                                  name="hx-tiny")
        steps, seq, gbs = min(args.steps, 60), 128, 8
    else:
        cfg = model_100m()
        steps, seq, gbs = args.steps, 512, 8

    lm = LM(cfg)
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps x {gbs}x{seq} tokens")
    trainer = Trainer(
        lm,
        opt_cfg=optim.OptConfig(lr=6e-4, warmup_steps=steps // 10,
                                stable_steps=int(steps * 0.7),
                                decay_steps=max(1, steps // 5)),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                            global_batch=gbs),
        ckpt_dir=CKPT, ckpt_every=max(10, steps // 5), ckpt_mode="auto",
        ncf=8, log_every=max(1, steps // 20),
        hdep_dir=CKPT + "_hdep", hdep_every=max(20, steps // 3))
    trainer.run(steps)

    losses = [m["loss"] for m in trainer.metrics_log]
    k = max(1, len(losses) // 10)
    print(f"loss: first-{k}-avg {sum(losses[:k])/k:.4f} -> "
          f"last-{k}-avg {sum(losses[-k:])/k:.4f}")
    print(f"HProt contexts: {trainer.ckpt.db.contexts()} in "
          f"{trainer.ckpt.db.n_files()} files")
    if trainer.hdep is not None:
        print(f"HDep analysis contexts: {trainer.hdep.contexts()}")
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k


if __name__ == "__main__":
    main()
