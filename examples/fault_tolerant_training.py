"""Fault tolerance demo: supervisor + induced crash + elastic resume.

    PYTHONPATH=src python examples/fault_tolerant_training.py

1. Launch training under the supervisor with TRAIN_CRASH_AT=7 — the child
   hard-exits mid-run (simulated node failure).
2. The supervisor relaunches; the new process restores the latest complete
   HProt context and finishes.
3. Verify the final state matches an uninterrupted run bit for bit.
"""
import os
import shutil
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.train.supervisor import run_supervised

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CKPT = "/tmp/hx_ft_demo"


def train_cmd(ckpt_dir, steps=14):
    return [sys.executable, "-m", "repro.launch.train", "--arch",
            "stablelm_1_6b", "--smoke", "--steps", str(steps),
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-every", "5", "--ckpt-dir", ckpt_dir]


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    shutil.rmtree(CKPT + "_ref", ignore_errors=True)
    env = {"PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}

    print("== supervised run with induced (one-off) crash at step 7")
    rc, restarts = run_supervised(train_cmd(CKPT), max_restarts=3, env=env,
                                  env_first={"TRAIN_CRASH_AT": "7"})
    print(f"   supervisor: rc={rc} restarts={restarts}")
    assert rc == 0 and restarts >= 1

    print("== uninterrupted reference run")
    subprocess.run(train_cmd(CKPT + "_ref"),
                   env={**os.environ, **env}, check=True)

    print("== compare final checkpoints bit for bit")
    from repro.hercule.checkpoint import CheckpointManager
    import numpy as np
    a = CheckpointManager(CKPT)
    b = CheckpointManager(CKPT + "_ref")
    assert a.latest_step() == b.latest_step() == 14
    # indexed views: each manifest is parsed once for the whole comparison
    va = a.db.view(14)
    vb = b.db.view(14)
    keys = {(r.name, r.domain) for r in va.records}
    assert keys == {(r.name, r.domain) for r in vb.records}
    for rec in va.records:
        wa = va.read_record(rec)
        wb = vb.read(rec.domain, rec.name)
        assert np.array_equal(wa, wb), (rec.name, rec.domain)
    print(f"   {len(keys)} tensors identical after crash+restart. OK")


if __name__ == "__main__":
    main()
