"""Batched serving demo across architecture families: prefill + decode
with per-family caches (KV ring buffer / SSM state / RG-LRU state).

    PYTHONPATH=src python examples/serve_llm.py
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def main():
    env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
    for arch in ("stablelm_1_6b", "mamba2_1_3b", "recurrentgemma_2b",
                 "mixtral_8x22b"):
        print(f"== {arch} (smoke config)")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--smoke", "--batch", "4", "--prompt-len", "16",
             "--tokens", "16"],
            env=env, check=True)


if __name__ == "__main__":
    main()
