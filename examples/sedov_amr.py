"""The paper's pipeline end to end on Sedov3D (its benchmark test case):

  AMR generation -> Hilbert domain decomposition -> local trees with ghost
  zones -> tree pruning -> HDep write (RLE'd booleans + father-son delta
  compressed fields) -> PyMSES-style read-back -> global assembly ->
  threshold filter + slice "visualization" (paper fig. 8 analogue).

    PYTHONPATH=src python examples/sedov_amr.py

With ``--insitu`` the same tree additionally flows through the in-transit
engine (compute -> staging -> reducers -> reduced HDep -> catalog), and
the catalog's slice is checked against the post-hoc one.
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import decompose, prune
from repro.hercule import HerculeDB, analysis, api
from repro.sim import amrgen, fields

ROOT = "/tmp/hx_sedov_hdep"
INSITU_ROOT = "/tmp/hx_sedov_insitu"
N_DOMAINS = 8


def run_insitu(tree, g):
    """Opt-in: drive the in-transit engine with the generated tree and
    check its catalog slice against the post-hoc assembly ``g`` — first
    single-writer, then partitioned over contributor groups with the
    reduced domains merged back at read."""
    from repro.insitu import Catalog, InTransitEngine, SliceReducer
    print("== in-transit flow (--insitu)")
    ref = analysis.slice_image(g, "density", axis=2, position=0.5,
                               resolution=128)
    for groups in (1, 2):
        root = INSITU_ROOT if groups == 1 else f"{INSITU_ROOT}_md{groups}"
        shutil.rmtree(root, ignore_errors=True)
        slicer = SliceReducer(field="density", axis=2, position=0.5,
                              resolution=128)
        engine = InTransitEngine(root, [slicer], policy="drop-oldest",
                                 domains=groups).start()
        engine.submit(0, tree)
        engine.close()
        cat = Catalog(root)
        img = cat.query(0, slicer.name)["image"]
        match = np.array_equal(img, ref, equal_nan=True)
        doms = cat.domains(0, slicer.name)
        print(f"   [domains={groups}] reduced contexts: {cat.steps()}, "
              f"written domains: {doms}, merged slice matches "
              f"post-hoc assembly: {match}")
        cat.query(0, slicer.name)
        print(f"   [domains={groups}] cache: {cat.cache_info()}")
        assert match, "in-transit slice diverged from post-hoc assembly"


def main():
    shutil.rmtree(ROOT, ignore_errors=True)
    print("== Sedov3D AMR generation")
    field = fields.sedov()
    tree = amrgen.generate_tree(field, min_level=3, max_level=7,
                                threshold=1.15, level_factor=1.05)
    tree.validate()
    print(f"   global tree: {tree.n_nodes} nodes, {tree.n_leaves} leaves, "
          f"{tree.n_levels} levels")

    print(f"== Hilbert decomposition over {N_DOMAINS} domains + pruning")
    dom = decompose.assign_domains(tree, N_DOMAINS)
    index = decompose._LevelIndex(tree)
    db = HerculeDB.create(ROOT, kind="hdep", ncf=4)
    ctx = db.begin_context(0)
    raw_bytes = comp_bytes = 0
    for d in range(N_DOMAINS):
        lt = decompose.local_tree(tree, dom, d, coarse_level=3, index=index)
        pt = prune.prune(lt)
        removed = prune.removed_fraction(lt, pt)
        api.write_object(ctx, "amr_tree", d, pt)
        raw_bytes += lt.n_nodes * (1 + 1 + 8 * len(lt.fields))
        print(f"   domain {d}: {lt.n_nodes} -> {pt.n_nodes} nodes "
              f"({removed*100:.1f} % pruned)")
    ctx.finalize(attrs={"case": "sedov3d"})
    data_dir = os.path.join(ROOT, "data")
    comp_bytes = sum(os.path.getsize(os.path.join(data_dir, f))
                     for f in os.listdir(data_dir))
    print(f"   HDep volume: {comp_bytes/1e6:.2f} MB "
          f"(~{raw_bytes/1e6:.2f} MB unpruned+uncompressed) in "
          f"{db.n_files()} files (NCF=4)")

    print("== PyMSES-style read-back + assembly")
    g = analysis.load_global_tree(db, 0)
    g.validate()
    print(f"   assembled: {g.n_nodes} nodes")

    print("== fig. 8 analogue: threshold filters on density")
    rho = g.fields["density"][~g.refine]
    hi = analysis.threshold(g, "density", lo=float(np.quantile(rho, 0.95)))
    lo = analysis.threshold(g, "density", hi=float(np.quantile(rho, 0.20)))
    print(f"   high-density cells (shock shell): {hi['coords'].shape[0]}")
    print(f"   low-density cells (evacuated interior): {lo['coords'].shape[0]}")

    img = analysis.slice_image(g, "density", axis=2, position=0.5,
                               resolution=128)
    out = os.path.join(ROOT, "density_slice.npy")
    np.save(out, img)
    # quick ASCII rendering of the blast shell
    q = np.nanquantile(img, [0.5, 0.8, 0.95])
    chars = np.full(img.shape, " ")
    chars[img > q[0]] = "."
    chars[img > q[1]] = "o"
    chars[img > q[2]] = "#"
    step = max(1, img.shape[0] // 32)
    for row in chars[::step]:
        print("   " + "".join(row[::step // 2 if step > 1 else 1]))
    print(f"   slice saved to {out}")

    if "--insitu" in sys.argv[1:]:
        run_insitu(tree, g)


if __name__ == "__main__":
    main()
