"""Quickstart: train a small LM with Hercule HProt checkpointing.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the public API end to end: pick an assigned architecture's
reduced config, train, checkpoint asynchronously (contexts in NCF-
aggregated files), restart, and verify the resume is bit-exact.
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.transformer import LM
from repro.train import optim
from repro.train.trainer import Trainer

CKPT = "/tmp/hx_quickstart"


def make_trainer():
    cfg = get_smoke_config("minicpm_2b")
    lm = LM(cfg)
    return Trainer(
        lm,
        opt_cfg=optim.OptConfig(lr=1e-3, warmup_steps=5, stable_steps=100,
                                decay_steps=20),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                            global_batch=8),
        ckpt_dir=CKPT, ckpt_every=10, ckpt_mode="auto", ncf=4, log_every=10)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    print("== phase 1: train 20 steps (checkpoints at 10, 20)")
    t1 = make_trainer()
    t1.run(20)

    print("== phase 2: new process resumes from context 20, trains to 40")
    t2 = make_trainer()
    state = t2.run(40)

    print("== phase 3: uninterrupted 40-step run for comparison")
    shutil.rmtree(CKPT + "_b", ignore_errors=True)
    t3 = make_trainer()
    t3.ckpt = type(t3.ckpt)(CKPT + "_b", ncf=4, mode="auto")
    ref = t3.run(40)

    same = jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.array_equal(a, b)), state, ref))
    print(f"resumed-vs-uninterrupted bitwise identical: {same}")
    db = t2.ckpt.db
    print(f"checkpoint db: contexts={db.contexts()} files={db.n_files()} "
          f"(NCF=4 aggregation)")
    assert same


if __name__ == "__main__":
    main()
