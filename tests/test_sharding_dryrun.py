"""Sharding rules resolution + small-mesh dry-run (subprocess: the forced
device count must be set before jax initializes)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro import sharding
from repro.launch import roofline as rl

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_spec_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = sharding.merge_rules()
    # kv_heads=8 not divisible by model=16 -> replicated
    spec = sharding.resolve_spec((1024, 8, 128),
                                 ("fsdp", "kv_heads", "head_dim"), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("data", None, None)
    # heads=48 divisible by 16 -> sharded
    spec = sharding.resolve_spec((1024, 48, 128),
                                 ("fsdp", "heads", "head_dim"), rules, mesh)
    assert spec[1] == "model"


def test_resolve_spec_multi_axis_batch():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = sharding.merge_rules()
    spec = sharding.resolve_spec((256, 4096), ("batch", "seq"), rules, mesh)
    assert spec[0] == ("pod", "data")
    # batch=1 (long_500k) -> replicated
    spec = sharding.resolve_spec((1, 524288), ("batch", "seq"), rules, mesh)
    assert spec[0] is None


def test_no_axis_reuse_within_tensor():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = sharding.merge_rules({"experts": "model", "mlp": "model"})
    spec = sharding.resolve_spec((32, 1024, 512),
                                 ("experts", "fsdp", "mlp"), rules, mesh)
    used = [a for part in spec for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert len(used) == len(set(used))


import jax  # noqa: E402  (after _FakeMesh definition on purpose)


def test_collective_stats_parsing():
    hlo = textwrap.dedent("""\
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%sum
      %ag.1 = bf16[512]{0} all-gather(bf16[128]{0} %y), replica_groups=[4,4]<=[16]
      %cp = u32[64]{0} collective-permute(u32[64]{0} %z), source_target_pairs={{0,1}}
    """)
    stats = rl.collective_stats(hlo, 16)
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["bytes"] == 128 * 256 * 4
    assert stats["all-gather"]["bytes"] == 512 * 2
    assert stats["collective-permute"]["bytes"] == 64 * 4
    # ring model: all-reduce 2(n-1)/n
    want = 2 * 128 * 256 * 4 * 3 / 4 / rl.ICI_BW
    assert abs(stats["all-reduce"]["seconds"] - want) < 1e-12


_DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, dataclasses, jax
import repro.configs.registry as reg
from repro.launch.mesh import make_test_mesh
from repro.launch import dryrun
from repro.launch.specs import input_specs, build_callable
from repro import sharding as shlib
from repro.configs import get_smoke_config

# shrink the cell so it compiles fast, keep the machinery identical
reg.SHAPES["train_4k"].update(batch=8, seq=128)
reg.SHAPES["decode_32k"].update(batch=8, seq=64)

arch = "{arch}"
shape = "{shape}"
cfg = get_smoke_config(arch)
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rules = shlib.merge_rules()
kind, kwargs, axes = input_specs(arch, shape, cfg=cfg)
in_sh = {{k: shlib.tree_shardings(kwargs[k], axes[k], rules, mesh)
          for k in kwargs}}
kwargs = {{k: jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
               s.shape, s.dtype, sharding=sh), kwargs[k], in_sh[k])
           for k in kwargs}}
fn = build_callable(arch, shape, cfg=cfg)
with mesh:
    with shlib.use_rules(rules, mesh):
        compiled = jax.jit(fn).lower(**kwargs).compile()
cost = compiled.cost_analysis()
print("RESULT", json.dumps({{"flops": float(cost.get("flops", 0))}}))
"""


@pytest.mark.parametrize("arch,shape", [
    ("internlm2_20b", "train_4k"),
    ("mixtral_8x22b", "decode_32k"),
    ("mamba2_1_3b", "decode_32k"),
    ("whisper_medium", "train_4k"),
    ("recurrentgemma_2b", "decode_32k"),
])
def test_dryrun_machinery_small_mesh(arch, shape):
    """lower+compile on a (pod,data,model) test mesh for every family."""
    code = _DRYRUN_SNIPPET.format(arch=arch, shape=shape)
    out = subprocess.run([sys.executable, "-c", code],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT" in out.stdout


def test_production_mesh_requires_512_devices():
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError, match="512"):
        make_production_mesh(multi_pod=True)  # tests run with 1 device
