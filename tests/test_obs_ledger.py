"""Run ledger: flight recorder, attribution, health (PR 10 acceptance).

Covers: the bounded event ring (capacity, exactly-once incremental
drains, dump hooks), the tracer's bounded span window, critical-path
attribution over unions of overlapping stage intervals (incl. partial
steps), the declarative health-rule engine (parse, burn windows,
edge-triggered alerts, verdict), the RunLedger <-> LedgerReader
roundtrip through a real ``telemetry/`` Hercule database (multi-writer
slots, foreign lane domains, crash-dump flushes, seq resume), the
SIGKILL acceptance path (a dead process lane leaves a readable ledger
with the crash event and partial-step attribution), the standalone
``/metrics`` endpoint, and the ``launch/obs`` CLI surface.
"""
import json
import os
import signal
import time
import urllib.error
import urllib.request

import pytest

from repro.hercule import api
from repro.hercule.database import DomainWriter, HerculeDB
from repro.insitu import (InTransitEngine, LevelHistogramReducer,
                          SliceReducer)
from repro.launch import obs as obs_cli
from repro.obs import TRACER, metrics, serve_metrics
from repro.obs import events as obs_events
from repro.obs.attrib import Attributor, attribute, union_seconds
from repro.obs.events import EventRing
from repro.obs.health import HealthEngine, Rule, default_rules
from repro.obs.ledger import (SEQ_STRIDE, LedgerReader, RunLedger,
                              lane_domain, ledger_dir)
from repro.obs.trace import Tracer
from repro.sim import amrgen, fields


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test starts with empty global rings and leaves them empty
    (the ledger drains the process-global TRACER/EVENTS)."""
    obs_events.EVENTS.clear()
    TRACER.clear()
    prev = TRACER.enabled
    yield
    TRACER.enabled = prev
    TRACER.clear()
    obs_events.EVENTS.clear()
    metrics.set_enabled(True)


@pytest.fixture(scope="module")
def sedov_tree():
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=4,
                             threshold=1.2)
    t.validate()
    return t


def _reducers():
    return [SliceReducer(field="density", axis=2, position=0.5,
                         resolution=32),
            LevelHistogramReducer(field="density", bins=16, lo=0.0,
                                  hi=8.0)]


def _span(name, step, t0, t1, cat="insitu", **args):
    return {"name": name, "cat": cat, "ph": "X", "ts": float(t0),
            "dur": float(t1 - t0), "pid": os.getpid(), "tid": 1,
            "trace_id": "t", "span_id": f"{name}-{step}-{t0}",
            "parent_id": None, "args": {"step": step, **args}}


# ------------------------------------------------------------ event ring

def test_event_ring_bounded_and_drained_exactly_once():
    ring = EventRing(capacity=8)
    for i in range(20):
        ring.emit(obs_events.STEP_BEGIN, step=i)
    assert ring.count == 20
    assert ring.dropped == 12
    mark, evs = ring.drain_since(0)
    assert [e["fields"]["step"] for e in evs] == list(range(12, 20))
    # nothing new: the same mark drains nothing
    mark2, evs2 = ring.drain_since(mark)
    assert (mark2, evs2) == (mark, [])
    ring.emit(obs_events.STEP_COMMIT, step=20)
    _, evs3 = ring.drain_since(mark2)
    assert [e["type"] for e in evs3] == [obs_events.STEP_COMMIT]
    # foreign events keep their identity but get local arrival order
    foreign = {"ts_us": 1.0, "type": obs_events.LANE_ERROR,
               "pid": 99999, "seq": 3, "fields": {"group": 1}}
    mark4, _ = ring.drain_since(0)
    ring.ingest([foreign])
    _, evs4 = ring.drain_since(mark4)
    assert evs4 == [foreign]


def test_event_ring_taxonomy_and_kill_switch():
    ring = EventRing()
    with pytest.raises(ValueError, match="unknown event type"):
        ring.emit("made.up", step=1)
    metrics.set_enabled(False)
    try:
        assert ring.emit(obs_events.STEP_BEGIN, step=1) is None
        assert ring.count == 0
    finally:
        metrics.set_enabled(True)
    assert ring.emit(obs_events.STEP_BEGIN, step=1) is not None


def test_event_ring_dump_hooks_never_raise():
    ring = EventRing()
    calls = []

    def good(reason, r):
        calls.append((reason, len(r.snapshot())))

    def broken(reason, r):
        raise RuntimeError("sink down")

    ring.register_dump_hook(good)
    ring.register_dump_hook(broken)
    ring.emit(obs_events.LANE_ERROR, group=0, stage="reduce")
    errors = ring.dump("unit.test", group=0)
    assert len(errors) == 1 and "sink down" in str(errors[0])
    # the dump marker itself is in the ring the hook saw
    assert calls == [("unit.test", 2)]
    types = [e["type"] for e in ring.snapshot()]
    assert obs_events.CRASH_DUMP in types
    ring.unregister_dump_hook(broken)
    ring.unregister_dump_hook(good)
    assert ring.dump("again") == []


# --------------------------------------------------------------- tracer

def test_tracer_bounded_window_counts_drops():
    t = Tracer(enabled=True, max_spans=16)
    for i in range(40):
        with t.span("submit", args={"step": i}):
            pass
    assert t.spans_dropped == 24
    assert len(t.spans()) == 16
    mark, spans = t.drain_since(0)
    assert [s["args"]["step"] for s in spans] == list(range(24, 40))
    _, again = t.drain_since(mark)
    assert again == []
    with t.span("submit", args={"step": 40}):
        pass
    _, fresh = t.drain_since(mark)
    assert [s["args"]["step"] for s in fresh] == [40]


# ---------------------------------------------------------- attribution

def test_union_seconds_merges_overlaps():
    assert union_seconds([]) == 0.0
    # [0,10] + [5,15] + [20,30] us -> 25 us of coverage
    got = union_seconds([(0.0, 10.0), (5.0, 15.0), (20.0, 30.0)])
    assert got == pytest.approx(25e-6)


def test_attribute_parallel_lanes_count_once():
    # two lanes reduce concurrently: 2x CPU, 1x wall
    spans = [_span("submit", 1, 0, 100),
             _span("reduce", 1, 100, 900, group=0),
             _span("reduce", 1, 150, 900, group=1),
             _span("manifest.commit", 1, 900, 1000)]
    a = attribute(1, spans)
    assert a["step"] == 1 and not a["partial"]
    assert a["total_s"] == pytest.approx(1000e-6)
    assert a["stages"]["reduce"] == pytest.approx(800e-6)
    assert a["critical"] == "reduce"
    assert a["idle_s"] == pytest.approx(0.0, abs=1e-9)


def test_attributor_terminal_completion_and_partial_flush():
    at = Attributor()
    assert at.ingest([_span("submit", 1, 0, 50),
                      _span("submit", 2, 0, 50)]) == []
    assert at.pending_steps == [1, 2]
    done = at.ingest([_span("reduce", 1, 50, 90, group=0),
                      _span("manifest.commit", 1, 90, 100)])
    assert [a["step"] for a in done] == [1]
    assert not done[0]["partial"] and at.pending_steps == [2]
    pending = at.flush_pending()
    assert [(a["step"], a["partial"]) for a in pending] == [(2, True)]
    assert at.pending_steps == []


# --------------------------------------------------------------- health

def test_rule_parse_roundtrip_and_validation():
    r = Rule.parse("staging_pressure > 0.9 for 3/5 : crit")
    assert (r.signal, r.op, r.threshold) == ("staging_pressure", ">", 0.9)
    assert (r.window, r.need, r.severity) == (5, 3, "crit")
    assert Rule.parse("lane_crashes >= 1").window == 1
    with pytest.raises(ValueError, match="unparsable"):
        Rule.parse("pressure !! 3")
    with pytest.raises(ValueError, match="K must be <="):
        Rule.parse("x > 1 for 4/3")
    with pytest.raises(ValueError, match="severity"):
        Rule(signal="x", op=">", threshold=1, severity="meh")
    assert {r.severity for r in default_rules()} == {"warn", "crit"}


def test_health_burn_window_edge_triggered():
    eng = HealthEngine([Rule.parse("p > 0.5 for 2/3 : warn")])
    assert eng.observe({"p": 0.9}) == []        # window not full
    assert eng.observe({"p": 0.1}) == []
    fired = eng.observe({"p": 0.8})             # 2 of last 3 violate
    assert [a["rule"] for a in fired] == ["p>0.5"]
    assert eng.observe({"p": 0.8}) == []        # still burning: no re-fire
    eng.observe({"p": 0.1})
    eng.observe({"p": 0.1})                     # burn ends -> clear
    assert "cleared_sample" in eng.alerts[0]
    assert eng.state()["active"] == []
    assert eng.verdict() == "degraded"          # history keeps the warn


def test_health_verdict_severity_order():
    eng = HealthEngine([Rule.parse("crashes >= 1 : crit")])
    assert eng.verdict() == "healthy"
    assert eng.observe({"unrelated": 5.0}) == []     # absent signal: idle
    eng.observe({"crashes": 1.0})
    assert eng.verdict() == "critical"
    state = eng.state()
    assert state["verdict"] == "critical" and state["samples"] == 2


# ----------------------------------------------------- ledger roundtrip

def test_ledger_roundtrip_merges_domains_and_slots(tmp_path):
    root = str(tmp_path / "run")
    TRACER.enable()
    led = RunLedger(root, "trainer", interval=0)
    obs_events.EVENTS.emit(obs_events.STEP_BEGIN, step=1, parts=2)
    TRACER.ingest([_span("submit", 1, 0, 100),
                   _span("reduce", 1, 100, 900, group=0),
                   _span("manifest.commit", 1, 900, 1000)])
    obs_events.EVENTS.emit(obs_events.STEP_COMMIT, step=1, domains=[0])
    lane_ev = {"ts_us": 5.0, "type": obs_events.LANE_ERROR, "pid": 424242,
               "seq": 1, "fields": {"group": 2, "stage": "reduce"}}
    led.ingest_domain(lane_domain(2), {"events": [lane_ev]})
    step0 = led.flush()
    assert step0 == 0 * SEQ_STRIDE + 0
    step1 = led.flush()                 # nothing new: still commits meta
    assert step1 == 1 * SEQ_STRIDE + 0
    # a second writer slot in the same run (the catalog server's)
    srv = RunLedger(root, "server", interval=0)
    assert srv.flush() % SEQ_STRIDE == 1
    srv.close()
    led.close()

    reader = LedgerReader(root)
    try:
        flushes = reader.flushes()
        assert {f["proc"] for f in flushes} == {"trainer", "server"}
        # exactly-once: the step events appear once despite 3+ flushes
        events = reader.events(flushes)
        begin = [e for e in events if e["type"] == obs_events.STEP_BEGIN]
        assert len(begin) == 1 and begin[0]["fields"]["step"] == 1
        assert lane_ev in events        # foreign lane domain merged in
        assert sum(1 for e in events
                   if e["type"] == obs_events.RUN_END) == 2
        attribs = reader.attribs(flushes)
        assert attribs[1]["critical"] == "reduce"
        assert not attribs[1]["partial"]
        assert reader.verdict(flushes) == "healthy"
        out = str(tmp_path / "trace.json")
        n = reader.export_perfetto(out)
        assert n == 3
        doc = json.load(open(out))
        assert [e["ph"] for e in doc["traceEvents"]] == ["X"] * 3
        assert doc["traceEvents"][0]["args"]["step"] == 1
    finally:
        reader.close()


def test_ledger_reader_requires_a_ledger(tmp_path):
    with pytest.raises(FileNotFoundError, match="no run ledger"):
        LedgerReader(str(tmp_path / "nope"))
    assert ledger_dir("/a/run") == "/a/run/telemetry"
    assert ledger_dir("/a/run/telemetry") == "/a/run/telemetry"


def test_ledger_seq_resumes_after_restart(tmp_path):
    root = str(tmp_path / "run")
    led = RunLedger(root, "trainer", interval=0)
    led.flush()
    led.close()                                     # + final flush
    led2 = RunLedger(root, "trainer", interval=0)   # simulated restart
    step = led2.flush()
    led2.close()
    assert step == 2 * SEQ_STRIDE                   # continues, no clobber
    reader = LedgerReader(root)
    try:
        assert [f["seq"] for f in reader.flushes()] == [0, 1, 2, 3]
    finally:
        reader.close()


def test_ledger_dump_flush_carries_partial_attribution(tmp_path):
    root = str(tmp_path / "run")
    TRACER.enable()
    led = RunLedger(root, "trainer", interval=0)
    TRACER.ingest([_span("submit", 7, 0, 100),
                   _span("stage.push", 7, 100, 300, domain=0)])
    obs_events.EVENTS.dump("unit.crash", group=0)   # hook -> flush(dump)
    assert led.flushes == 1
    # the step later completes: the complete record must win on read
    TRACER.ingest([_span("submit", 7, 0, 100),
                   _span("reduce", 7, 300, 900, group=0),
                   _span("manifest.commit", 7, 900, 1000)])
    led.flush()
    # ...and a *later* partial (e.g. relayed by a lane) must not clobber
    led.ingest_domain(lane_domain(0), {"attrib": {
        "7": attribute(7, [_span("submit", 7, 0, 50)], partial=True)}})
    led.close()
    reader = LedgerReader(root)
    try:
        a = reader.attribs()[7]
        assert not a["partial"]
        assert a["critical"] == "reduce"
        dumps = reader.crash_dumps()
        assert any(e["fields"].get("reason") == "unit.crash"
                   for e in dumps)
    finally:
        reader.close()


def test_ledger_signals_feed_health_and_alert_lands_in_flush(tmp_path):
    led = RunLedger(str(tmp_path / "run"), "trainer", interval=0,
                    rules=[Rule.parse("pressure > 0.9 : warn")])
    led.add_signal("pressure", lambda: 0.97)
    led.add_signal("broken", lambda: 1 / 0)         # must not crash flush
    led.flush()
    led.close()
    reader = LedgerReader(str(tmp_path / "run"))
    try:
        alerts = reader.alerts()
        assert len(alerts) == 1
        assert alerts[0]["fields"]["signal"] == "pressure"
        assert alerts[0]["fields"]["value"] == pytest.approx(0.97)
        assert reader.verdict() == "degraded"
        meta = next(iter(
            reader.flushes()[0]["parts"]["meta"].values()))
        assert meta["signals"]["pressure"] == pytest.approx(0.97)
        assert "broken" not in meta["signals"]
    finally:
        reader.close()


# ----------------------------------------------- telemetry Hercule kind

def test_telemetry_kind_concatenates_span_domains(tmp_path):
    db = HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=1)
    kind = api.KINDS["telemetry"]
    w = DomainWriter(db, 0)
    kind.write(w, 0, {"spans": [_span("submit", 1, 200, 300)],
                      "meta": {"proc": "trainer"}})
    kind.write(w, 8, {"spans": [_span("reduce", 1, 100, 150)]})
    db.commit_context(0, w.records)
    parts = kind.assemble(db.view(0))
    # span streams concatenate across domains, time-ordered
    assert [s["name"] for s in parts["spans"]] == ["reduce", "submit"]
    assert [s["ts"] for s in parts["spans"]] == [100.0, 200.0]
    # keyed parts stay per-domain
    assert parts["meta"][0]["proc"] == "trainer"
    db.close()


# ------------------------------------------------ engine mesh telemetry

def test_engine_mesh_telemetry_includes_ledger_and_trace(tmp_path,
                                                         sedov_tree):
    TRACER.enable()
    led = RunLedger(str(tmp_path / "run"), "trainer", interval=0)
    eng = InTransitEngine(str(tmp_path / "run"), _reducers(),
                          device_reduce="mesh", policy="block",
                          ledger=led).start()
    assert eng.submit(0, sedov_tree)
    eng.drain()
    led.flush()
    tel = eng.telemetry()
    assert tel["device"]["mesh_devices"] >= 1
    assert tel["trace"]["max_spans"] == TRACER.max_spans
    assert tel["trace"]["spans_dropped"] == 0
    assert tel["ledger"]["proc"] == "trainer"
    assert tel["ledger"]["flushes"] >= 1
    assert tel["ledger"]["verdict"] == "healthy"
    assert tel["ledger"]["steps_attributed"] >= 1
    eng.close()
    led.close()
    reader = LedgerReader(str(tmp_path / "run"))
    try:
        assert 0 in reader.attribs()
        types = {e["type"] for e in reader.events()}
        assert {obs_events.STEP_BEGIN, obs_events.STEP_COMMIT} <= types
    finally:
        reader.close()


# -------------------------------------------- SIGKILL acceptance path

def test_killed_lane_leaves_readable_ledger(tmp_path, sedov_tree):
    """A SIGKILLed process lane must leave a postmortem on disk: the
    lane-crash event, a crash-dump flush, partial attribution for the
    step it stranded, and a critical verdict."""
    root = str(tmp_path / "run")
    TRACER.enable()
    led = RunLedger(root, "trainer", interval=0)
    eng = InTransitEngine(root, _reducers(), domains=2,
                          backend="process", ledger=led).start()
    assert eng.submit(1, sedov_tree)
    eng.drain()
    # step 2 only ever gets its domain-1 part: it can never commit, so
    # its attribution is guaranteed partial regardless of kill timing
    assert eng.submit_part(2, 1, sedov_tree)
    victim = eng._backend._procs[0]
    os.kill(victim.pid, signal.SIGKILL)
    victim.join(timeout=30)
    deadline = time.monotonic() + 30
    while not eng._errors and time.monotonic() < deadline:
        time.sleep(0.05)
    assert eng._errors, "collector never noticed the dead lane"
    with pytest.raises(RuntimeError, match="in-transit reduction failed"):
        eng.close()
    led.close()

    reader = LedgerReader(root)
    try:
        events = reader.events()
        crashes = [e for e in events
                   if e["type"] == obs_events.LANE_CRASH]
        assert crashes and crashes[0]["fields"]["group"] == 0
        assert crashes[0]["fields"]["exitcode"] == -signal.SIGKILL
        assert any(e["type"] == obs_events.CRASH_DUMP for e in events)
        attribs = reader.attribs()
        assert 1 in attribs and not attribs[1]["partial"]
        assert attribs[2]["partial"]
        assert "submit" in attribs[2]["stages"]
        assert reader.verdict() == "critical"
        # the crash registered as a health signal, not just an event
        flushes = reader.flushes()
        last_meta = next(iter(flushes[-1]["parts"]["meta"].values()))
        assert last_meta["signals"]["lane_crashes"] >= 1
    finally:
        reader.close()


# ------------------------------------------------------ /metrics httpd

def test_serve_metrics_endpoint():
    reg = metrics.MetricsRegistry()
    c = reg.counter("ledger_test_scrapes_total", "unit test counter")
    c.inc(3)
    srv = serve_metrics(0, registry=reg)
    try:
        assert srv.port > 0
        body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "ledger_test_scrapes_total 3" in body
        base = srv.url.rsplit("/", 1)[0]
        snap = json.loads(urllib.request.urlopen(
            base + "/snapshot", timeout=10).read())
        assert snap["ledger_test_scrapes_total"]["samples"][0]["value"] == 3
        ok = urllib.request.urlopen(base + "/healthz", timeout=10)
        assert ok.status == 200
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope", timeout=10)
    finally:
        srv.close()
    with pytest.raises(OSError):
        urllib.request.urlopen(srv.url, timeout=2)


# ---------------------------------------------------------- launch CLI

def _mini_ledger(root):
    TRACER.enable()
    led = RunLedger(root, "trainer", interval=0)
    TRACER.ingest([_span("submit", 1, 0, 100),
                   _span("reduce", 1, 100, 900, group=0),
                   _span("manifest.commit", 1, 900, 1000)])
    obs_events.EVENTS.emit(obs_events.STEP_COMMIT, step=1, domains=[0])
    led.flush()
    led.close()


def test_obs_cli_report_tail_export(tmp_path, capsys):
    root = str(tmp_path / "run")
    _mini_ledger(root)
    assert obs_cli.main(["report", root]) == 0
    out = capsys.readouterr().out
    assert "verdict: HEALTHY" in out
    assert "critical=reduce" in out
    assert obs_cli.main(["tail", root, "--once"]) == 0
    assert "step.commit" in capsys.readouterr().out
    trace = str(tmp_path / "t.json")
    dump = str(tmp_path / "d.json")
    assert obs_cli.main(["export", root, "--perfetto", trace,
                         "--json", dump]) == 0
    assert len(json.load(open(trace))["traceEvents"]) == 3
    doc = json.load(open(dump))
    assert doc["verdict"] == "healthy" and doc["attribs"]["1"]
    assert obs_cli.main(["export", root]) == 2


def test_obs_cli_empty_ledger_reports_cleanly(tmp_path):
    root = str(tmp_path / "run")
    # a ledger database that exists but has no committed flush yet
    HerculeDB.create(ledger_dir(root), kind="hdep", ncf=1,
                     io_threads=1).close()
    assert obs_cli.main(["report", root]) == 1
