"""End-to-end telemetry (PR 6 acceptance).

Covers: the metrics substrate (thread-sharded counters, histogram
quantile accuracy vs numpy, Prometheus text validity, registry
idempotence), span tracing with cross-process propagation through the
shm descriptor headers, the engine's unified ``telemetry()`` snapshot
for both lane backends, truthful shared-word staging stats across
attach, and the catalog server's ``/metrics`` + extended ``/v1/stats``
surface.
"""
import json
import math
import re
import threading

import numpy as np
import pytest

from repro.insitu import (Catalog, CatalogServer, InTransitEngine,
                          LevelHistogramReducer, ProjectionReducer,
                          RemoteCatalog, ShmStagingArea, SliceReducer)
from repro.insitu.staging import STAT_FIELDS
from repro.obs import TRACER, MetricsRegistry, metrics
from repro.sim import amrgen, fields


@pytest.fixture(scope="module")
def sedov_tree():
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=4,
                             threshold=1.2)
    t.validate()
    return t


def _reducers():
    return [SliceReducer(field="density", axis=2, position=0.5,
                         resolution=32),
            ProjectionReducer(field="density", axis=2, resolution=32),
            LevelHistogramReducer(field="density", bins=16, lo=0.0,
                                  hi=8.0)]


@pytest.fixture()
def tracing():
    """Enable the global tracer for one test, restore after."""
    TRACER.clear()
    TRACER.enable()
    yield TRACER
    TRACER.disable()
    TRACER.clear()


# ------------------------------------------------------------ instruments

def test_counter_thread_shards():
    reg = MetricsRegistry()
    c = reg.counter("test_total", "help text")
    n_threads, per_thread = 8, 5000

    def work():
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    c.inc(2.5)
    assert c.value == n_threads * per_thread + 2.5
    # shards per writing thread (idents may be reused after joins, so
    # the count is bounded, not exact); totals survive reuse regardless
    assert 1 <= len(c._children[()]._shards) <= n_threads + 1


def test_histogram_quantiles_vs_numpy():
    """Interpolated bucket quantiles land within one bucket width of
    the exact numpy percentiles."""
    rng = np.random.default_rng(42)
    samples = rng.lognormal(mean=-7.0, sigma=1.5, size=20_000)
    h = metrics.Histogram()
    for v in samples:
        h.observe(float(v))
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum(), rel=1e-9)
    bounds = [0.0, *h.bounds]
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.percentile(samples, 100 * q))
        i = int(np.searchsorted(h.bounds, exact))
        width = bounds[i + 1] - bounds[i] if i < len(h.bounds) \
            else bounds[-1]
        assert abs(est - exact) <= width, (q, est, exact, width)


def test_histogram_empty_and_overflow():
    h = metrics.Histogram(buckets=(1.0, 2.0))
    assert math.isnan(h.quantile(0.5))
    h.observe(100.0)      # +Inf bucket: quantile reports last bound
    assert h.quantile(0.5) == 2.0
    assert h.merged()[0] == [0, 0, 1]


def test_render_prometheus_valid():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", labels=("ep",)).labels("/q").inc(3)
    reg.gauge("depth", "queue depth").set(7)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.render_prometheus()
    assert '# TYPE req_total counter' in text
    assert 'req_total{ep="/q"} 3' in text
    assert 'depth 7' in text
    # histogram buckets are cumulative and end at +Inf == _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert 'lat_seconds_count 3' in text
    # every non-comment line is name{labels} value
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            assert re.match(r'^[a-zA-Z_:][\w:]*(\{.*\})? \S+$', line), line


def test_registry_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", labels=("k",))
    with pytest.raises(ValueError, match="label values"):
        reg.counter("y_total", labels=("k",)).labels("a", "b")


def test_registry_callback_runs_before_collect():
    reg = MetricsRegistry()

    def sync():
        reg.gauge("lazy").set(11)      # registered inside the callback

    reg.register_callback(sync)
    snap = reg.snapshot()
    assert snap["lazy"]["samples"][0]["value"] == 11


# ----------------------------------------------------------------- spans

def test_span_nesting_and_export(tracing):
    with tracing.span("outer", args={"step": 1}) as outer:
        with tracing.span("inner") as inner:
            inner.set(n=3)
    doc = tracing.export()
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert ev["inner"]["args"]["parent_id"] == outer.span_id
    assert ev["inner"]["args"]["trace_id"] == outer.trace_id
    assert ev["inner"]["args"]["n"] == 3
    assert ev["outer"]["ph"] == "X" and ev["outer"]["dur"] >= 0
    json.dumps(doc)    # chrome-trace must be strict JSON


def test_noop_when_disabled():
    TRACER.clear()
    assert not TRACER.enabled
    with TRACER.span("nope") as sp:
        sp.set(a=1)
    assert TRACER.spans() == []


# ----------------------------------------------- shm stats shared words

def test_shm_stats_shared_across_attach():
    area = ShmStagingArea(capacity=4, policy="block")
    try:
        consumer = ShmStagingArea.attach(area.handle())
        arrays = {"x": np.arange(64, dtype=np.float64)}
        for s in (1, 2, 3):
            area.push(s, arrays, meta={"m": s})
        snap = consumer.pop(timeout=5.0)
        consumer.release(snap)
        # both ends read the same control words
        for view in (area.stats, consumer.stats):
            assert view.pushed == 3 and view.accepted == 3
            assert view.popped == 1 and view.released == 1
            assert view.bytes_staged > 0
        d = area.stats.as_dict()
        assert set(d) == set(STAT_FIELDS)
        consumer.detach()
        # consumer's frozen copy survives its detach; producer words live
        assert consumer.stats.popped == 1
        assert area.stats.accepted == 3
    finally:
        area.unlink()
    # frozen after unlink: plain attributes, no shm behind them
    assert area.stats.accepted == 3


# ------------------------------------------- engine telemetry + tracing

@pytest.mark.parametrize("backend", ["thread", "process"])
def test_engine_telemetry_merged(tmp_path, sedov_tree, backend):
    eng = InTransitEngine(str(tmp_path / backend), _reducers(), domains=2,
                          backend=backend, policy="block",
                          queue_capacity=2).start()
    for s in (1, 2):
        assert eng.submit(s, sedov_tree)
    eng.drain()
    tel = eng.telemetry()
    assert tel["backend"] == backend
    tot = tel["staging"]["totals"]
    # 2 steps x 2 groups staged, and the consumer-side counters are
    # visible from the producer (the PR-6 dead-stats fix)
    assert tot["accepted"] == 4
    assert tot["popped"] == 4 and tot["released"] == 4
    assert tel["lanes"]["written_steps"] == 2
    assert tel["lanes"]["kind"] == backend
    m = tel["metrics"]
    assert m["insitu_steps_written"]["samples"][0]["value"] == 2
    assert m["insitu_submit_seconds"]["samples"][0]["value"]["count"] == 2
    json.dumps(tel)     # the whole snapshot is JSON-able
    eng.close()
    # telemetry stays readable after close (frozen stats, no shm)
    tel2 = eng.telemetry()
    assert tel2["staging"]["totals"]["accepted"] == 4


def test_trace_propagates_across_process_lanes(tmp_path, sedov_tree,
                                               tracing):
    eng = InTransitEngine(str(tmp_path / "db"), _reducers(), domains=2,
                          backend="process", policy="block",
                          queue_capacity=2).start()
    assert eng.submit(1, sedov_tree)
    eng.close()
    spans = tracing.spans()
    by_name: dict = {}
    for sp in spans:
        by_name.setdefault(sp["name"], []).append(sp)
    assert {"submit", "stage.push", "reduce", "write",
            "manifest.commit"} <= set(by_name)
    submit = by_name["submit"][0]
    # lane spans were recorded in other OS processes...
    here = {submit["pid"]}
    lane_pids = {sp["pid"] for sp in by_name["reduce"]}
    assert lane_pids and not lane_pids & here
    # ...and still link to the producer's submit span
    for name in ("reduce", "write"):
        for sp in by_name[name]:
            assert sp["parent_id"] == submit["span_id"]
            assert sp["trace_id"] == submit["trace_id"]
    assert by_name["manifest.commit"][0]["parent_id"] == submit["span_id"]
    # the wire context never leaks into user-facing attrs
    cat = Catalog(str(tmp_path / "db"))
    assert "_trace" not in cat.attrs(1)
    cat.close()
    out = tmp_path / "trace.json"
    n = tracing.write_chrome_trace(str(out))
    assert n == len(spans)
    doc = json.loads(out.read_text())
    assert len(doc["traceEvents"]) == n


# ------------------------------------------------------- server surface

def test_server_metrics_and_stats(tmp_path, sedov_tree):
    root = str(tmp_path / "db")
    eng = InTransitEngine(root, _reducers(), domains=2,
                          policy="block", queue_capacity=2).start()
    assert eng.submit(1, sedov_tree)
    eng.close()

    srv = CatalogServer(root, port=0, token="t0k").start()
    try:
        rc = RemoteCatalog(srv.url, token="t0k")
        name = rc.reducers(1)[0]
        rc.query(1, name)
        rc.query(1, name)            # ETag revalidation -> 304
        with pytest.raises(KeyError):
            rc.query(1, "absent")

        info = rc.cache_info()
        # stable counter keys untouched, telemetry sections added
        assert {"entries", "hits", "misses", "io_reads",
                "timing", "server"} <= set(info)
        assert info["timing"]["query_miss"]["count"] >= 1
        sv = info["server"]
        assert sv["etag_304"] == 1
        q = sv["requests"]["/v1/query"]
        assert q["200"] == 1 and q["304"] == 1 and q["404"] == 1
        assert sv["request_seconds"]["/v1/query"]["count"] == 3
        assert sv["bytes_sent"]["/v1/query"] > 0

        text = rc.metrics()
        for fam in ("catalog_requests_total", "catalog_request_seconds",
                    "catalog_bytes_sent_total", "catalog_etag_304_total",
                    "catalog_cache_hits", "catalog_query_seconds"):
            assert f"# TYPE {fam} " in text, fam
        # cumulative +Inf bucket equals the count for the query endpoint
        inf = re.search(r'catalog_request_seconds_bucket\{endpoint='
                        r'"/v1/query",le="\+Inf"\} (\d+)', text)
        cnt = re.search(r'catalog_request_seconds_count\{endpoint='
                        r'"/v1/query"\} (\d+)', text)
        assert inf.group(1) == cnt.group(1) == "3"
        # /metrics sits behind the same bearer auth as the data routes
        with pytest.raises(PermissionError):
            RemoteCatalog(srv.url).metrics()
        # unknown paths fold into the bounded "other" endpoint label
        with pytest.raises(KeyError):
            rc._get("/v1/bogus")
        assert "other" in rc.cache_info()["server"]["requests"]
    finally:
        srv.close()


def test_obs_kill_switch(tmp_path, sedov_tree):
    """metrics.ENABLED=False stops observes on the full pipeline path
    (the overhead benchmark's bare arm)."""
    metrics.set_enabled(False)
    try:
        eng = InTransitEngine(str(tmp_path / "db"), _reducers(),
                              policy="block", queue_capacity=2).start()
        assert eng.submit(1, sedov_tree)
        eng.drain()
        m = eng.telemetry()["metrics"]
        assert m["insitu_submit_seconds"]["samples"][0]["value"]["count"] \
            == 0
        # gauges still sync: they read external stats, not the hot path
        assert m["insitu_steps_written"]["samples"][0]["value"] == 1
        eng.close()
    finally:
        metrics.set_enabled(True)
