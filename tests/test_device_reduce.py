"""On-accelerator reduction subsystem (DESIGN.md §14).

Kernel parity is the acceptance criterion: for slice / projection /
per-level histogram, ``pallas_interpret`` == ``ref`` == host-numpy
reducer outputs, bit for bit, on random AMR trees — including
owner-masked partitioned inputs. Plus device staging semantics
(device-resident snapshots, push-copy safety, backpressure parity) and
the end-to-end ``InTransitEngine(device_reduce=True)`` path (bit-equal
catalogs, host fallback for unregistered reducers, transfer accounting).
"""
import numpy as np
import pytest

from repro.insitu import Catalog, InTransitEngine, partition_snapshot
from repro.insitu.device import (DeviceDAGRunner, DeviceStagingArea,
                                 device_impl_for)
from repro.insitu.reducers import (LevelHistogramReducer, LODCutReducer,
                                   ProjectionReducer, ReducerDAG,
                                   SliceReducer)
from repro.insitu.staging import Snapshot
from repro.sim import amrgen, fields

SEEDS = (0, 7)
RESOLUTIONS = (16, 64)    # 16 < deepest level: exercises px==1 collisions


def random_tree(seed: int):
    """A Sedov AMR structure carrying random (sign-mixed) field values."""
    rng = np.random.default_rng(seed)
    tree = amrgen.generate_tree(fields.sedov(r_shock=0.2 + 0.1 * rng.random()),
                                min_level=2, max_level=5, threshold=1.2)
    tree.fields["density"] = rng.standard_normal(tree.n_nodes) * 4.0 + 1.0
    return tree


def host_outputs(snap, resolution):
    dag = ReducerDAG([
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=resolution),
        ProjectionReducer(field="density", axis=2, resolution=resolution),
        LevelHistogramReducer(field="density", bins=32),
    ])
    return dag, dag.run(snap)


def assert_tree_equal(a: dict, b: dict):
    assert set(a) == set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("resolution", RESOLUTIONS)
def test_kernel_parity_single_domain(seed, resolution):
    """pallas_interpret == ref == host reducers, bit for bit."""
    tree = random_tree(seed)
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    dag, host = host_outputs(snap, resolution)
    for backend in ("ref", "pallas_interpret"):
        runner = DeviceDAGRunner(dag, backend=backend)
        dev = runner.run(snap)
        assert not runner.stats.fallback_runs
        for rname in host:
            assert_tree_equal(host[rname], dev[rname])


@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_kernel_parity_owner_masked_partitions(backend):
    """Partitioned inputs: owner-masked kernels match the host reducers
    per contributor part (each owned leaf counted exactly once)."""
    tree = random_tree(3)
    parts = partition_snapshot(tree.to_arrays(), "amr", 3)
    dag = ReducerDAG([
        SliceReducer(field="density", axis=2, position=0.5, resolution=32),
        ProjectionReducer(field="density", axis=2, resolution=32),
        LevelHistogramReducer(field="density", bins=16, lo=-8.0, hi=8.0),
    ])
    runner = DeviceDAGRunner(dag, backend=backend)
    for g, part in enumerate(parts):
        snap = Snapshot(step=0, kind="amr", arrays=part, domain=g,
                        n_domains=len(parts))
        host = dag.run(snap)
        dev = runner.run(snap)
        for rname in host:
            assert_tree_equal(host[rname], dev[rname])


def test_device_impl_registry_fallback_configs():
    """Unsupported configs resolve to None -> host fallback."""
    assert device_impl_for(SliceReducer(resolution=64)) is not None
    # non-power-of-two resolution: integer pixel geometry doesn't apply
    assert device_impl_for(SliceReducer(resolution=100)) is None
    # upstream source: runs on host from the upstream's output
    assert device_impl_for(
        SliceReducer(resolution=64, source="lod2")) is None
    # the LOD cut is a BFS prefix slice: device impl since PR 9
    assert device_impl_for(LODCutReducer(max_level=2)) is not None
    assert device_impl_for(ProjectionReducer(resolution=48)) is None
    assert device_impl_for(LevelHistogramReducer()) is not None


# ----------------------------------------------------------- device staging

def test_device_staging_holds_jax_arrays_and_copies():
    """Staged snapshots are device-resident; compute may mutate its host
    arrays right after push (the upload is a real copy)."""
    import jax
    st = DeviceStagingArea(capacity=2)
    a = np.arange(8.0)
    assert st.push(1, {"a": a})
    a[:] = -1.0
    snap = st.pop(timeout=1.0)
    assert isinstance(snap.arrays["a"], jax.Array)
    assert snap.arrays["a"].dtype == np.float64   # x64 staging, no downcast
    np.testing.assert_array_equal(np.asarray(snap.arrays["a"]),
                                  np.arange(8.0))
    st.release(snap)
    st.close()


def test_device_staging_survives_donated_device_arrays():
    """A jax-array push restages device-side (counted as reuse) and the
    staged copy survives deletion of the producer's buffer — the
    trainer's train step *donates* its state, which deletes the
    original while the snapshot is still queued."""
    import jax.numpy as jnp
    st = DeviceStagingArea(capacity=2)
    x = jnp.arange(16.0)
    assert st.push(1, {"x": x})
    assert st.stats.buffer_reuses == 1      # device-resident: no upload
    assert st.stats.buffer_allocs == 0
    x.delete()                              # what donation does
    snap = st.pop(timeout=1.0)
    np.testing.assert_array_equal(np.asarray(snap.arrays["x"]),
                                  np.arange(16.0))
    st.release(snap)
    st.close()


def test_device_staging_drop_oldest_parity():
    st = DeviceStagingArea(capacity=2, policy="drop-oldest")
    for s in range(1, 6):
        assert st.push(s, {"a": np.full(4, float(s))})
    assert len(st) == 2
    assert st.stats.evicted == 3
    snaps = [st.pop(timeout=1.0), st.pop(timeout=1.0)]
    assert [s.step for s in snaps] == [4, 5]
    for s in snaps:
        st.release(s)
    st.close()


# ------------------------------------------------------------- engine e2e

def test_engine_device_reduce_bit_identical(tmp_path):
    """device_reduce=True writes a catalog bit-identical to the host
    path, transfers less than the full snapshot, and never materializes
    a full snapshot on host (every default reducer has a device impl
    since the PR 9 LOD cut)."""
    tree = random_tree(11)
    mk = lambda: [  # noqa: E731
        SliceReducer(field="density", resolution=64),
        ProjectionReducer(field="density", resolution=64),
        LevelHistogramReducer(field="density", bins=16),
        LODCutReducer(max_level=2),
    ]
    roots = {}
    for mode in (False, True):
        root = str(tmp_path / f"db_{mode}")
        roots[mode] = root
        eng = InTransitEngine(root, mk(), device_reduce=mode).start()
        for s in (1, 2):
            assert eng.submit(s, tree)
        eng.close()
        if mode:
            ds = eng.device_stats
            assert ds["snapshots"] == 2
            assert not ds["fallback_runs"]
            assert ds["fallback_snapshots"] == 0
            assert 0 < ds["bytes_to_host"]
        else:
            assert eng.device_stats is None
    ch, cd = Catalog(roots[False]), Catalog(roots[True])
    for s in (1, 2):
        assert ch.reducers(s) == cd.reducers(s)
        for r in ch.reducers(s):
            assert_tree_equal(ch.query(s, r), cd.query(s, r))
    ch.close()
    cd.close()


def test_engine_device_reduce_transfer_savings(tmp_path):
    """Without host-fallback reducers, device->host traffic is a small
    fraction of the staged snapshot bytes (the subsystem's raison
    d'etre)."""
    tree = amrgen.generate_tree(fields.sedov(), min_level=3, max_level=6,
                                threshold=1.1)
    eng = InTransitEngine(str(tmp_path / "db"), [
        SliceReducer(field="density", resolution=32),
        ProjectionReducer(field="density", resolution=32),
        LevelHistogramReducer(field="density", bins=16, lo=-8.0, hi=8.0),
    ], device_reduce=True).start()
    assert eng.submit(1, tree)
    eng.close()
    ds = eng.device_stats
    staged = sum(a.stats.bytes_staged for a in eng.stages)
    assert ds["fallback_snapshots"] == 0
    assert ds["bytes_to_host"] < staged / 4
    assert Catalog(str(tmp_path / "db")).steps() == [1]


def test_engine_device_reduce_multidomain_merge(tmp_path):
    """device_reduce composes with contributor groups: per-domain device
    parts are bit-identical to the host multi-domain path, and the
    merged answers agree with the single-domain reference."""
    tree = random_tree(9)
    mk = lambda: [  # noqa: E731
        ProjectionReducer(field="density", resolution=32),
        LevelHistogramReducer(field="density", bins=16, lo=-8.0, hi=8.0),
    ]
    roots = {}
    for name, domains, dev in (("ref", 1, True), ("md_host", 2, False),
                               ("md_dev", 2, True)):
        roots[name] = str(tmp_path / name)
        eng = InTransitEngine(roots[name], mk(), domains=domains,
                              device_reduce=dev).start()
        assert eng.submit(1, tree)
        eng.close()
    ref = Catalog(roots["ref"])
    md_host = Catalog(roots["md_host"])
    md_dev = Catalog(roots["md_dev"])
    pname, hname = "proj-density-ax2-r32", "hist-density-b16-lo-8-hi8"
    assert md_dev.domains(1, pname) == [0, 1]
    # device multi-domain == host multi-domain, bit for bit (merged and
    # per domain)
    for reducer in (pname, hname):
        assert_tree_equal(md_host.query(1, reducer),
                          md_dev.query(1, reducer))
        for d in (0, 1):
            assert_tree_equal(md_host.query(1, reducer, domain=d),
                              md_dev.query(1, reducer, domain=d))
    # and the merged answers recover the single-domain reference: the
    # projection to fp roundoff (sum-merge reorders adds), histogram
    # counts exactly (padded rows aside, every leaf counted once)
    a = ref.query(1, pname)["image"]
    b = md_dev.query(1, pname)["image"]
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)
    ha, hb = ref.query(1, hname)["hist"], md_dev.query(1, hname)["hist"]
    assert ha.sum() == hb.sum()
    rows = min(ha.shape[0], hb.shape[0])
    np.testing.assert_array_equal(ha[:rows], hb[:rows])
    for cat in (ref, md_host, md_dev):
        cat.close()


def test_engine_device_reduce_rejects_process_backend(tmp_path):
    with pytest.raises(ValueError, match="thread"):
        InTransitEngine(str(tmp_path / "db"),
                        [SliceReducer(resolution=32)],
                        device_reduce=True, backend="process")
