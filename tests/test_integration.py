"""End-to-end integration: training + crash/restart bit-exactness,
supervisor restarts, straggler monitor, HDep analysis flow, serving CLI."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.transformer import LM
from repro.train import optim
from repro.train.trainer import StragglerMonitor, Trainer

ARCH = "minicpm_2b"


def _mk_trainer(ckpt_dir, **kw):
    cfg = get_smoke_config(ARCH)
    lm = LM(cfg)
    return Trainer(
        lm, ckpt_dir=ckpt_dir, log_every=0,
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=4),
        opt_cfg=optim.OptConfig(lr=1e-3, warmup_steps=2, stable_steps=100,
                                decay_steps=10),
        **kw)


def test_loss_decreases(tmp_path):
    tr = _mk_trainer(str(tmp_path / "c"), ckpt_every=50)
    tr.run(24)
    losses = [m["loss"] for m in tr.metrics_log]
    # window means: single-step losses are noisy at this scale
    assert sum(losses[-6:]) / 6 < sum(losses[:6]) / 6


def test_crash_restart_bitwise_identical(tmp_path):
    """Interrupted-and-resumed run == uninterrupted run, bit for bit."""
    sA = _mk_trainer(str(tmp_path / "a"), ckpt_every=4).run(10)
    _mk_trainer(str(tmp_path / "b"), ckpt_every=4).run(8)   # "crash" at 8
    sB = _mk_trainer(str(tmp_path / "b"), ckpt_every=4).run(10)  # resume
    same = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)), sA, sB)
    assert jax.tree.all(same)


def test_restore_skips_incomplete_context(tmp_path):
    tr = _mk_trainer(str(tmp_path / "c"), ckpt_every=3)
    tr.run(6)
    # corrupt: fake a partial (unfinalized) newer context
    ctx_dir = os.path.join(str(tmp_path / "c"), "ctx_00000099")
    os.makedirs(ctx_dir)
    tr2 = _mk_trainer(str(tmp_path / "c"), ckpt_every=3)
    state, start = tr2.init_or_restore()
    assert start == 6  # ignored the bogus context


def test_supervisor_restarts_after_induced_crash(tmp_path):
    from repro.train.supervisor import run_supervised
    ckpt = str(tmp_path / "sv")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", ARCH,
           "--smoke", "--steps", "12", "--seq-len", "32",
           "--global-batch", "4", "--ckpt-every", "4",
           "--ckpt-dir", ckpt]
    env = {"PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
           "JAX_PLATFORMS": "cpu"}
    # the induced crash models a ONE-OFF node failure: trigger only on the
    # first attempt; the restart resumes from the step-4 checkpoint
    rc, restarts = run_supervised(cmd, max_restarts=3, env=env,
                                  env_first={"TRAIN_CRASH_AT": "6"})
    assert restarts >= 1
    assert rc == 0
    from repro.hercule.checkpoint import CheckpointManager
    assert CheckpointManager(ckpt).latest_step() == 12


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0, warmup=2)
    for i in range(6):
        assert not m.observe(i, 0.1)
    assert m.observe(6, 1.0)          # 10x slower -> straggler
    assert len(m.events) == 1
    assert not m.observe(7, 0.11)     # baseline not poisoned


def test_hdep_analysis_dump_flow(tmp_path):
    tr = _mk_trainer(str(tmp_path / "c"), ckpt_every=50,
                     hdep_dir=str(tmp_path / "hdep"), hdep_every=5)
    tr.run(5)
    from repro.hercule import HerculeDB, api
    db = HerculeDB.open(str(tmp_path / "hdep"))
    assert db.contexts() == [5]
    out = api.read_object(db, 5, "analysis", 0)
    assert out  # params dumped
    for v in out.values():
        assert np.isfinite(v).all()


def test_serve_cli_smoke():
    env = {**os.environ,
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "mamba2_1_3b",
         "--smoke", "--batch", "2", "--prompt-len", "8", "--tokens", "4"],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "decode:" in out.stdout
