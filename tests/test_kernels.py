"""Per-kernel tests: shape/dtype sweeps, Pallas(interpret) vs ref.py oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream as bs, fpdelta
from repro.kernels import ops, ref


def _mk(g, s, width, seed):
    rng = np.random.default_rng(seed)
    pred = rng.standard_normal(g)
    sons = pred[:, None] * (1 + 0.01 * rng.standard_normal((g, s)))
    if width == 64:
        ph, plo = bs.f64_to_pair(np.broadcast_to(pred[:, None], (g, s)))
        sh, slo = bs.f64_to_pair(sons)
    elif width == 32:
        ph = np.zeros((g, s), np.uint32)
        plo = bs.f32_to_u32(np.broadcast_to(pred[:, None], (g, s)).astype(np.float32))
        sh = np.zeros((g, s), np.uint32)
        slo = bs.f32_to_u32(sons.astype(np.float32))
    else:
        ph = np.zeros((g, s), np.uint32)
        plo = bs.bf16_to_u32(np.broadcast_to(pred[:, None], (g, s)))
        sh = np.zeros((g, s), np.uint32)
        slo = bs.bf16_to_u32(sons)
    return [jnp.asarray(a.T.copy()) for a in (ph, plo, sh, slo)]


@pytest.mark.parametrize("g", [8, 100, 1024, 5000])
@pytest.mark.parametrize("width", [64, 32, 16])
def test_encode_kernel_vs_oracle(g, width):
    s = 8
    args = _mk(g, s, width, seed=g + width)
    o_rh, o_rl, o_nlz = ref.group_residues_ref(*args, 4, width)
    rh, rl, nlz = ops.encode_groups_bits(*args, zbits=4, width=width,
                                         backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(rh), np.asarray(o_rh))
    np.testing.assert_array_equal(np.asarray(rl), np.asarray(o_rl))
    np.testing.assert_array_equal(np.asarray(nlz), np.asarray(o_nlz))


@pytest.mark.parametrize("zbits", [2, 4, 8])
def test_zbits_sweep(zbits):
    args = _mk(600, 8, 64, seed=zbits)
    o = ref.group_residues_ref(*args, zbits, 64)
    k = ops.encode_groups_bits(*args, zbits=zbits, width=64,
                               backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(k[2]), np.asarray(o[2]))


def test_decode_kernel_vs_oracle():
    args = _mk(777, 8, 64, seed=9)
    rh, rl, _ = ops.encode_groups_bits(*args, backend="ref")
    sh, slo = ops.decode_groups_bits(rh, rl, args[0], args[1],
                                     backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(sh), np.asarray(args[2]))
    np.testing.assert_array_equal(np.asarray(slo), np.asarray(args[3]))


def test_clz_kernel_formulation_matches_lax():
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.concatenate([
        [0, 1, 2, 3, 0xFFFFFFFF, 0x80000000],
        rng.integers(0, 2**32, 1000, dtype=np.uint64).astype(np.uint32)]),
        jnp.uint32)
    got = ref.clz32_ref(x)
    want = jax.lax.clz(x).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [1, 31, 32, 1000, 32 * 1024 + 17])
def test_bitfield_pack_kernel(n):
    rng = np.random.default_rng(n)
    bits = (rng.random(n) < 0.4).astype(np.uint32)
    for backend in ("ref", "pallas_interpret"):
        w = ops.bitfield_pack(bits, backend=backend)
        assert w.shape[0] == (n + 31) // 32
        back = ops.bitfield_unpack(w, n, backend=backend)
        np.testing.assert_array_equal(np.asarray(back), bits)


def test_compress_bits_matches_host_codec():
    """Jit'd pipeline byte counts == numpy host codec byte counts."""
    rng = np.random.default_rng(5)
    g = 2048
    pred = rng.lognormal(size=g)
    sons = pred[:, None] * (1 + 1e-3 * rng.standard_normal((g, 8)))
    blk = fpdelta.encode(pred, sons)
    host_bytes = blk.codes.nbytes + blk.payload.nbytes

    ph, plo = bs.f64_to_pair(np.broadcast_to(pred[:, None], (g, 8)))
    sh, slo = bs.f64_to_pair(sons)
    args = [jnp.asarray(a.T.copy()) for a in (ph, plo, sh, slo)]
    cw, pw, cb, pb = ops.compress_bits(*args, zbits=4, width=64, backend="ref")
    jit_bytes = ((int(cb) + 31) // 32) * 4 + ((int(pb) + 31) // 32) * 4
    assert jit_bytes == host_bytes
