"""Sharded multi-device reduction (insitu.mesh_reduce).

Parity contract: the shard_map path is bit-identical to the host
reducers wherever the arithmetic is order-free — slice painting (at the
collision-free resolution bound), integer level histograms, the LOD
prefix cut — and bit-identical to the read-side ascending-domain fold
(``hercule.api._merge_sum``) for float projection sums, which places it
within 1e-12 of the single-writer host reducer (the same contract
``test_merge`` established for multi-domain reduction). f32 tables get
tolerance parity (slice 1e-6, projection 1e-4, hist exact on the cast
values).

Multi-device cases run in subprocesses: the forced host device count
(``XLA_FLAGS=--xla_force_host_platform_device_count``) must be set
before jax initializes a backend, and the parent test process already
initialized the default single-CPU one.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.insitu import Catalog, InTransitEngine
from repro.insitu.mesh_reduce import MeshDAGRunner, mesh_impl_for
from repro.insitu.reducers import (LevelHistogramReducer, LODCutReducer,
                                   ProjectionReducer, ReducerDAG,
                                   SliceReducer)
from repro.insitu.staging import Snapshot
from repro.sim import amrgen, fields

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def tree():
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                             threshold=1.15, level_factor=1.05)
    t.validate()
    return t


def _dag(res=32, lod=3):
    return ReducerDAG([
        SliceReducer(field="density", axis=2, position=0.5, resolution=res),
        ProjectionReducer(field="density", axis=2, resolution=res),
        LevelHistogramReducer(field="density", bins=16),
        LODCutReducer(max_level=lod),
        SliceReducer(field="density", axis=2, position=0.5, resolution=res,
                     source=f"lod{lod}"),
    ])


def _host(dag, snap):
    out = {}
    for r in dag.order:
        o = r.reduce(snap, out)
        if o:
            out[r.name] = o
    return out


def _assert_same(got, want, *, proj_names=(), rtol=1e-12):
    assert sorted(got) == sorted(want)
    for name in want:
        for k, v in want[name].items():
            g = np.asarray(got[name][k])
            v = np.asarray(v)
            assert g.dtype == v.dtype, (name, k, g.dtype, v.dtype)
            if name in proj_names:
                np.testing.assert_allclose(g, v, rtol=rtol, err_msg=name)
            else:
                np.testing.assert_array_equal(g, v, err_msg=f"{name}/{k}")


# ------------------------------------------------------ registry / config

def test_mesh_impl_registry_fallback_configs():
    assert mesh_impl_for(SliceReducer(resolution=64)) is not None
    assert mesh_impl_for(SliceReducer(resolution=100)) is None
    assert mesh_impl_for(SliceReducer(resolution=64, source="lod2")) is None
    assert mesh_impl_for(ProjectionReducer(resolution=48)) is None
    assert mesh_impl_for(LODCutReducer(max_level=2)) is not None
    assert mesh_impl_for(LevelHistogramReducer()) is not None


def test_engine_validates_mesh_config(tmp_path):
    mk = lambda: [SliceReducer(resolution=32)]  # noqa: E731
    with pytest.raises(ValueError, match="device_reduce mode"):
        InTransitEngine(str(tmp_path / "a"), mk(), device_reduce="tpu")
    with pytest.raises(ValueError, match="mesh_devices"):
        InTransitEngine(str(tmp_path / "b"), mk(), mesh_devices=2)
    with pytest.raises(ValueError, match="thread"):
        InTransitEngine(str(tmp_path / "c"), mk(), device_reduce="mesh",
                        backend="process")


def test_mesh_runner_rejects_oversized_mesh(tree):
    import jax
    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        MeshDAGRunner(_dag(), devices=too_many)


# ------------------------------------------- single-device mesh (in-proc)

def test_mesh_single_device_bit_parity(tree):
    """S=1 degenerates to the single-device semantics: everything
    (projection included — one shard, no fold) is bit-identical."""
    dag = _dag()
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    host = _host(dag, snap)
    runner = MeshDAGRunner(dag, devices=1, backend="ref")
    _assert_same(runner.run(snap), host)
    st = runner.stats.as_dict()
    assert st["fallback_snapshots"] == 0
    assert st["peak_leaf_frac"] == 1.0
    assert st["mesh_devices"] == 1
    assert st["bytes_tables_to_device"] > 0


def test_mesh_tiled_gather_bit_identical(tree):
    """A tile budget far below the table size streams the shard through
    carry-seeded kernels — outputs must not change by a single bit."""
    dag = _dag()
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    whole = MeshDAGRunner(dag, devices=1, backend="ref").run(snap)
    for backend in ("ref", "pallas_interpret"):
        tiled = MeshDAGRunner(dag, devices=1, backend=backend,
                              tile_n=4096).run(snap)
        _assert_same(tiled, whole)


def test_mesh_f32_tolerance_parity(tree):
    """dtype='float32' casts the field tables: slice within 1e-6,
    projection within 1e-4, histogram exact for the cast values."""
    dag = _dag()
    arrays = tree.to_arrays()
    snap = Snapshot(step=0, kind="amr", arrays=arrays)
    host = _host(dag, snap)
    out = MeshDAGRunner(dag, devices=1, backend="ref",
                        dtype="float32").run(snap)
    sname = "slice-density-ax2-p0.5-r32"
    pname = "proj-density-ax2-r32"
    hname = "hist-density-b16"
    assert np.asarray(out[sname]["image"]).dtype == np.float32
    np.testing.assert_allclose(
        np.asarray(out[sname]["image"], np.float64), host[sname]["image"],
        rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out[pname]["image"], np.float64), host[pname]["image"],
        rtol=1e-4)
    # exact-on-cast-values: the host reducer over the f32-rounded field
    # must reproduce the f32 histogram bin by bin (edges included:
    # auto bounds come from the cast values, f32->f64 promotion exact)
    cast = dict(arrays)
    cast["field:density"] = (arrays["field:density"]
                             .astype(np.float32).astype(np.float64))
    cast_host = _host(dag, Snapshot(step=0, kind="amr", arrays=cast))
    np.testing.assert_array_equal(np.asarray(out[hname]["hist"]),
                                  cast_host[hname]["hist"])
    np.testing.assert_array_equal(np.asarray(out[hname]["edges"]),
                                  cast_host[hname]["edges"])


def test_mesh_lod_cut_and_chained_slice(tree):
    """The mesh LOD impl (host prefix slice) equals the host subset_tree
    cut, and the chained slice consumes it without any snapshot
    fallback."""
    dag = _dag()
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    host = _host(dag, snap)
    runner = MeshDAGRunner(dag, devices=1, backend="ref")
    out = runner.run(snap)
    for k, v in host["lod3"].items():
        np.testing.assert_array_equal(np.asarray(out["lod3"][k]), v,
                                      err_msg=k)
    assert runner.stats.fallback_snapshots == 0
    # the chained slice is the only host-run reducer, fed from upstream
    assert set(runner.stats.fallback_runs) == {
        "slice-density-ax2-p0.5-r32-of-lod3"}


def test_mesh_nonpow2_resolution_falls_back(tree):
    dag = ReducerDAG([SliceReducer(field="density", resolution=48)])
    snap = Snapshot(step=0, kind="amr", arrays=tree.to_arrays())
    runner = MeshDAGRunner(dag, devices=1, backend="ref")
    assert runner.impls[dag.order[0].name] is None
    out = runner.run(snap)
    np.testing.assert_array_equal(out[dag.order[0].name]["image"],
                                  dag.order[0].reduce(snap, {})["image"])
    # host arrays never left the host: the fallback moved zero bytes
    assert runner.stats.bytes_fallback_to_host == 0
    assert runner.stats.fallback_snapshots == 1


def test_engine_mesh_end_to_end_catalog(tree, tmp_path):
    """device_reduce='mesh' writes a catalog matching the host engine
    (bitwise except the documented 1e-12 projection fold)."""
    roots = {}
    for mode, kw in (("host", {}),
                     ("mesh", dict(device_reduce="mesh"))):
        roots[mode] = str(tmp_path / mode)
        eng = InTransitEngine(roots[mode], list(_dag()), policy="block",
                              **kw).start()
        assert eng.submit(0, tree)
        eng.close()
        if mode == "mesh":
            ds = eng.device_stats
            assert ds["mesh_devices"] == 1
            assert ds["fallback_snapshots"] == 0
        else:
            assert eng.device_stats is None
    ch, cm = Catalog(roots["host"]), Catalog(roots["mesh"])
    assert ch.reducers(0) == cm.reducers(0)
    for r in ch.reducers(0):
        a, b = ch.query(0, r), cm.query(0, r)
        for k in a:
            if r.startswith("proj-"):
                np.testing.assert_allclose(b[k], a[k], rtol=1e-12)
            else:
                np.testing.assert_array_equal(b[k], a[k], err_msg=f"{r}/{k}")
    ch.close()
    cm.close()


# --------------------------------------------- multi-device (subprocess)

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
assert len(jax.devices()) == 4

from repro.insitu.mesh_reduce import MeshDAGRunner
from repro.insitu.partition import leaf_shards, partition_snapshot
from repro.insitu.reducers import (LevelHistogramReducer, LODCutReducer,
                                   ProjectionReducer, ReducerDAG,
                                   SliceReducer)
from repro.insitu.staging import Snapshot
from repro.sim import amrgen, fields

tree = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                            threshold=1.15, level_factor=1.05)
arrays = tree.to_arrays()
R = 32
dag = ReducerDAG([
    SliceReducer(field="density", axis=2, position=0.5, resolution=R),
    ProjectionReducer(field="density", axis=2, resolution=R),
    LevelHistogramReducer(field="density", bins=16),
    LODCutReducer(max_level=3),
    SliceReducer(field="density", axis=2, position=0.5, resolution=R,
                 source="lod3"),
])
pname = "proj-density-ax2-r%d" % R
snap = Snapshot(step=0, kind="amr", arrays=arrays)
host = {}
for r in dag.order:
    o = r.reduce(snap, host)
    if o:
        host[r.name] = o

refine = np.asarray(arrays["refine"])
leaves = np.flatnonzero(~refine)
proj_r = next(r for r in dag.order if r.name == pname)

def md_fold(S):
    # read-side reference: per-Hilbert-domain host reduce, ascending fold
    shard = leaf_shards(arrays, S)
    acc = None
    for g in range(S):
        arr2 = dict(arrays)
        owner = np.zeros(refine.shape[0], bool)
        owner[leaves[shard == g]] = True
        arr2["owner"] = owner
        part = proj_r.reduce(Snapshot(step=0, kind="amr", arrays=arr2,
                                      n_domains=2), {})["image"]
        acc = part if acc is None else acc + part
    return acc

for S in (1, 2, 4, 3):          # 3: the all_gather+argmax merge branch
    runner = MeshDAGRunner(dag, devices=S, backend="ref")
    out = runner.run(snap)
    for name, o in host.items():
        for k, v in o.items():
            got = np.asarray(out[name][k])
            assert got.dtype == np.asarray(v).dtype, (S, name, k)
            if name == pname:
                assert np.array_equal(got, md_fold(S)), (S, name)
                np.testing.assert_allclose(got, v, rtol=1e-12)
            else:
                assert np.array_equal(got, np.asarray(v),
                                      equal_nan=True), (S, name, k)
    st = runner.stats.as_dict()
    assert st["fallback_snapshots"] == 0
    assert st["mesh_devices"] == S
    # residency proof: no device holds more than ~1/S of the leaf rows
    if S == 4:
        assert st["peak_leaf_frac"] <= 0.6, st["peak_leaf_frac"]
        assert st["peak_device_table_bytes"] * S <= \
            st["bytes_tables_to_device"] * 1.01
        assert st["peak_device_partial_bytes"] > 0
    print("PARITY-OK", S, round(st["peak_leaf_frac"], 4))

# tiled-gather under shard_map: bit-identical to the untiled mesh
whole = MeshDAGRunner(dag, devices=4, backend="ref").run(snap)
tiled = MeshDAGRunner(dag, devices=4, backend="ref", tile_n=4096).run(snap)
for name, o in whole.items():
    for k, v in o.items():
        assert np.array_equal(np.asarray(tiled[name][k]), np.asarray(v),
                              equal_nan=True), ("tiled", name, k)
print("TILED-OK")

# owner-masked contributor partitions compose with the mesh
parts = partition_snapshot(arrays, "amr", 2)
runner = MeshDAGRunner(dag, devices=4, backend="ref")
slice_img = None
proj_img = None
hist = None
sname = "slice-density-ax2-p0.5-r%d" % R
for d, pa in enumerate(parts):
    out = runner.run(Snapshot(step=0, kind="amr", arrays=pa, domain=d,
                              n_domains=2))
    s = np.asarray(out[sname]["image"])
    slice_img = s if slice_img is None else np.where(
        np.isnan(slice_img), s, slice_img)
    p = np.asarray(out[pname]["image"])
    proj_img = p if proj_img is None else proj_img + p
    h = np.asarray(out["hist-density-b16"]["hist"])
    hist = h if hist is None else None  # per-part auto edges differ; skip sum
assert np.array_equal(slice_img, host[sname]["image"], equal_nan=True)
np.testing.assert_allclose(proj_img, host[pname]["image"], rtol=1e-12)
print("PARTITION-OK")
"""


def test_mesh_forced_host_devices_subprocess(tmp_path):
    """1/2/4-device parity, the non-pow2 merge branch, tiling and
    owner-masked partitions — under 4 forced host devices."""
    out = subprocess.run([sys.executable, "-c", _CHILD],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    for marker in ("PARITY-OK 1", "PARITY-OK 2", "PARITY-OK 4",
                   "PARITY-OK 3", "TILED-OK", "PARTITION-OK"):
        assert marker in out.stdout, (marker, out.stdout)
