"""In-transit analysis engine: staging backpressure, reducer DAG,
reduced-HDep round trips, catalog caching, and end-to-end parity with
post-hoc analysis (the acceptance criteria of the in-situ subsystem)."""
import time

import numpy as np
import pytest

from repro.core import decompose, prune
from repro.hercule import HerculeDB, analysis, api
from repro.insitu import (Catalog, InTransitEngine, LevelHistogramReducer,
                          LODCutReducer, ProjectionReducer, Reducer,
                          ReducerDAG, SliceReducer, StagingArea,
                          TensorNormReducer)
from repro.sim import amrgen, fields


@pytest.fixture(scope="module")
def sedov_tree():
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                             threshold=1.2)
    t.validate()
    return t


# ------------------------------------------------------------------ staging

def test_staging_block_policy_roundtrip():
    st = StagingArea(capacity=2, policy="block")
    assert st.push(1, {"a": np.arange(5)})
    assert st.push(2, {"a": np.arange(5) * 2})
    snap = st.pop(timeout=1.0)
    assert snap.step == 1
    np.testing.assert_array_equal(snap.arrays["a"], np.arange(5))
    st.release(snap)
    st.close()


def test_staging_push_copies_arrays():
    """Compute may mutate its arrays right after push (staged copy)."""
    st = StagingArea(capacity=2)
    a = np.arange(8.0)
    st.push(1, {"a": a})
    a[:] = -1
    snap = st.pop(timeout=1.0)
    np.testing.assert_array_equal(snap.arrays["a"], np.arange(8.0))
    st.release(snap)
    st.close()


def test_staging_drop_oldest_keeps_freshest():
    st = StagingArea(capacity=2, policy="drop-oldest")
    for s in range(1, 6):
        assert st.push(s, {"a": np.full(4, s)})
    assert len(st) == 2
    assert st.stats.evicted == 3
    snaps = [st.pop(timeout=1.0), st.pop(timeout=1.0)]
    assert [s.step for s in snaps] == [4, 5]
    for s in snaps:
        st.release(s)
    st.close()


def test_staging_subsample_decimates_under_pressure():
    st = StagingArea(capacity=2, policy="subsample")
    accepted = [s for s in range(1, 41) if st.push(s, {"a": np.zeros(2)})]
    # queue never drained -> overflows double the stride; only a few land
    assert st.stats.dropped > 0
    assert len(accepted) < 10
    st.close()


def test_subsample_stride_converges_under_constant_load():
    """PID stride control: under a constant consumer service ratio the
    stride locks onto that ratio instead of hunting between extremes
    (the old halve-on-slack heuristic oscillated by design)."""
    for k in (3, 6, 12):     # consumer drains one snapshot every k pushes
        st = StagingArea(capacity=4, policy="subsample")
        strides = []
        for step in range(2400):
            st.push(step, {"a": np.zeros(8)})
            if step % k == 0:
                snap = st.pop(timeout=0)
                if snap is not None:
                    st.release(snap)
            strides.append(st.stride)
        tail = strides[-400:]
        # converged: the tail sits in a tight band around the service
        # ratio (quantization allows a one-step limit cycle)
        assert min(tail) >= max(1, k // 2), (k, sorted(set(tail)))
        assert max(tail) <= 2 * k, (k, sorted(set(tail)))
        assert len(set(tail)) <= 2, (k, sorted(set(tail)))
        st.close()


def test_staging_double_buffer_reuse():
    st = StagingArea(capacity=1, policy="drop-oldest")
    for s in range(10):
        st.push(s, {"a": np.zeros(100), "b": np.ones(50)})
    # stable shapes: allocations bounded by pool size, rest are reuses
    assert st.stats.buffer_allocs <= 2 * 3   # <= pool sets * arrays
    assert st.stats.buffer_reuses > 0
    st.close()


# --------------------------------------------------------------------- DAG

def test_dag_topo_order_and_validation():
    lod = LODCutReducer(max_level=3)
    s = SliceReducer(resolution=32, source="lod3")
    dag = ReducerDAG([s, lod])           # order given reversed on purpose
    assert dag.names().index("lod3") < dag.names().index(s.name)
    with pytest.raises(ValueError, match="unknown"):
        ReducerDAG([SliceReducer(resolution=16, source="nope")])
    with pytest.raises(ValueError, match="duplicate"):
        ReducerDAG([LODCutReducer(max_level=3), LODCutReducer(max_level=3)])


def test_lod_cut_is_valid_coarse_tree(sedov_tree):
    from repro.insitu.reducers import tree_of
    from repro.insitu.staging import Snapshot
    snap = Snapshot(step=0, kind="amr", arrays=sedov_tree.to_arrays())
    out = LODCutReducer(max_level=2).reduce(snap, {})
    cut = tree_of(out)
    cut.validate()
    assert cut.n_levels <= 3
    # the cut's coarse values are the restriction already present upstream
    np.testing.assert_array_equal(
        cut.fields["density"][:1], sedov_tree.fields["density"][:1])


# ------------------------------------------------------- reduced HDep flavor

def test_write_read_reduced_roundtrip(tmp_path):
    db = HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=2)
    ctx = db.begin_context(3)
    rng = np.random.default_rng(0)
    arrays = {"image": rng.standard_normal((64, 64)),
              "edges": np.linspace(0, 1, 33),
              "hist": rng.integers(0, 100, (5, 32))}
    api.write_object(ctx, "reduced", 0, arrays, reducer="myred")
    ctx.finalize()
    out = api.read_object(db, 3, "reduced", 0, reducer="myred")
    for k, v in arrays.items():
        np.testing.assert_array_equal(out[k], v)
    assert api.REDUCED.reducers_in(db.view(3)) == ["myred"]
    with pytest.raises(KeyError):
        api.read_object(db, 3, "reduced", 0, reducer="absent")


# ------------------------------------------------- acceptance criteria (a-c)

def test_compute_never_blocks_under_drop_oldest(tmp_path):
    """(a) slow reducers + drop-oldest: the compute loop keeps its pace."""
    sleep_s = 0.1

    class Slow(Reducer):
        name = "slow"

        def reduce(self, snap, upstream):
            time.sleep(sleep_s)
            return {"x": np.array([float(snap.step)])}

    eng = InTransitEngine(str(tmp_path / "db"), [Slow()],
                          queue_capacity=2, policy="drop-oldest").start()
    n = 30
    t0 = time.perf_counter()
    for s in range(1, n + 1):
        eng.submit(s, {"a": np.zeros(1000)}, kind="amr")
    push_time = time.perf_counter() - t0
    # reducing everything would take n * sleep_s; pushes must not wait
    assert push_time < n * sleep_s / 4, push_time
    stats = eng.staging.stats
    assert stats.accepted == n
    assert stats.evicted > 0                 # backpressure did engage
    eng.close()
    # freshest snapshot always survives drop-oldest
    assert n in eng.written_steps
    assert len(eng.written_steps) == stats.accepted - stats.evicted


def test_insitu_slice_matches_posthoc_and_cache(tmp_path, sedov_tree):
    """(b) in-transit slice == post-hoc slice over assembled domain trees;
    (c) repeated catalog query is served from cache, no re-read."""
    tree = sedov_tree
    # post-hoc path: domain-decomposed, pruned, written as full HDep objects
    dom = decompose.assign_domains(tree, 4)
    full_db = HerculeDB.create(str(tmp_path / "full"), kind="hdep", ncf=2)
    ctx = full_db.begin_context(7)
    for d in range(4):
        lt = decompose.local_tree(tree, dom, d, coarse_level=1)
        api.write_object(ctx, "amr_tree", d, prune.prune(lt))
    ctx.finalize()
    posthoc = analysis.slice_image(analysis.load_global_tree(full_db, 7),
                                   "density", axis=2, position=0.5,
                                   resolution=64)

    # in-transit path: the same state reduced at the staging node
    slicer = SliceReducer(field="density", axis=2, position=0.5,
                          resolution=64)
    eng = InTransitEngine(str(tmp_path / "red"),
                          [slicer, ProjectionReducer(resolution=32),
                           LevelHistogramReducer()],
                          policy="drop-oldest").start()
    assert eng.submit(7, tree)
    eng.close()

    cat = Catalog(str(tmp_path / "red"))
    assert cat.steps() == [7]
    img = cat.query(7, slicer.name)["image"]
    np.testing.assert_array_equal(img, posthoc)

    # (c) cache: second query (and a region crop of it) re-reads nothing
    reads_after_first = cat.io_reads
    again = cat.query(7, slicer.name)["image"]
    window = cat.query(7, slicer.name, region=((8, 24), (8, 24)))["image"]
    assert cat.io_reads == reads_after_first
    assert cat.cache_hits >= 2
    np.testing.assert_array_equal(again, img)
    np.testing.assert_array_equal(window, img[8:24, 8:24])


def test_engine_output_frequency_independent(tmp_path, sedov_tree):
    eng = InTransitEngine(str(tmp_path / "db"),
                          [LevelHistogramReducer()], output_every=3).start()
    for s in range(1, 10):
        eng.submit(s, sedov_tree)
    eng.close()
    assert eng.written_steps == [3, 6, 9]
    assert Catalog(str(tmp_path / "db")).steps() == [3, 6, 9]


def test_engine_dag_slice_of_lod(tmp_path, sedov_tree):
    """A dependent reducer (slice of the LOD cut) runs after its upstream
    and its coarse image agrees with slicing the cut directly."""
    from repro.insitu.reducers import tree_of
    lod = LODCutReducer(max_level=2)
    s_of = SliceReducer(field="density", resolution=32, source="lod2")
    eng = InTransitEngine(str(tmp_path / "db"), [s_of, lod]).start()
    assert eng.submit(1, sedov_tree)
    eng.close()
    cat = Catalog(str(tmp_path / "db"))
    cut = tree_of(cat.query(1, "lod2"))
    want = analysis.slice_image(cut, "density", axis=2, position=0.5,
                                resolution=32)
    got = cat.query(1, s_of.name)["image"]
    np.testing.assert_array_equal(got, want)


def test_engine_tensor_flow(tmp_path):
    import jax.numpy as jnp
    state = {"params": {"w": jnp.arange(256, dtype=jnp.float32
                                        ).reshape(16, 16) / 256.0,
                        "bias": jnp.ones(4)}}
    eng = InTransitEngine(str(tmp_path / "db"), [TensorNormReducer()]).start()
    assert eng.submit_state(2, state)
    eng.close()
    out = Catalog(str(tmp_path / "db")).query(2, "tnorm")
    assert list(out["names"]) == ["w"]       # bias is not matrix-shaped
    w = np.arange(256, dtype=np.float32).reshape(16, 16) / 256.0
    np.testing.assert_allclose(out["stats"][0, 0],
                               np.linalg.norm(w.ravel()), rtol=1e-6)


def test_engine_surfaces_reducer_errors(tmp_path):
    class Boom(Reducer):
        name = "boom"

        def reduce(self, snap, upstream):
            raise RuntimeError("kaput")

    eng = InTransitEngine(str(tmp_path / "db"), [Boom()]).start()
    eng.submit(1, {"a": np.zeros(4)}, kind="amr")
    with pytest.raises(RuntimeError, match="in-transit"):
        eng.close()
