"""Father-son FP delta codec: exactness (incl. specials), rates, trees."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to fixed-example replay (tests/_hypothesis_fallback.py)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import fpdelta, pyramid


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=64), min_size=8, max_size=256),
       st.integers(2, 6))
def test_encode_decode_exact_f64(vals, zbits):
    vals = np.array(vals)
    g = len(vals) // 8
    sons = vals[:g * 8].reshape(g, 8)
    pred = sons.mean(axis=1)
    blk = fpdelta.encode(pred, sons, zbits=zbits)
    assert np.array_equal(fpdelta.decode(blk, pred), sons)


def test_specials_roundtrip():
    sons = np.array([[np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-310, np.pi, -1.5]])
    pred = np.array([0.5])
    blk = fpdelta.encode(pred, sons)
    out = fpdelta.decode(blk, pred)
    assert np.array_equal(out, sons, equal_nan=True)
    assert np.signbit(out[0, 4])


@pytest.mark.parametrize("width", [64, 32, 16])
def test_widths(width):
    rng = np.random.default_rng(width)
    pred = rng.standard_normal(500)
    sons = pred[:, None] * (1 + 0.01 * rng.standard_normal((500, 8)))
    if width == 16:
        import ml_dtypes
        sons_cast = sons.astype(np.float32).astype(ml_dtypes.bfloat16)
    elif width == 32:
        sons_cast = sons.astype(np.float32)
    else:
        sons_cast = sons
    blk = fpdelta.encode(pred, sons_cast.astype(np.float64) if width == 64
                         else sons_cast, width=width)
    out = fpdelta.decode(blk, pred)
    assert np.array_equal(np.asarray(out), np.asarray(sons_cast))


def test_good_predictor_compresses():
    """Correlated sons -> leading zeros shared -> paper-regime rates."""
    rng = np.random.default_rng(1)
    pred = rng.lognormal(size=4096)
    sons = pred[:, None] * (1 + 1e-3 * rng.standard_normal((4096, 8)))
    blk = fpdelta.encode(pred, sons)
    assert blk.rate_vs_raw() > 0.15  # paper: 16-18 %


def test_random_data_no_compression():
    rng = np.random.default_rng(2)
    pred = rng.standard_normal(1024)
    sons = rng.standard_normal((1024, 8))
    blk = fpdelta.encode(pred, sons)
    assert blk.rate_vs_raw() < 0.05  # sign bit differences kill sharing


def test_tree_roundtrip_and_partial_decode():
    from repro.sim import amrgen, fields
    tree = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                                threshold=1.3)
    tc = fpdelta.encode_tree_field(tree, "density")
    dec = fpdelta.decode_tree_field(tree, tc)
    assert np.array_equal(dec, tree.fields["density"])
    # partial decode = paper's level-bounded visualization path
    d2 = fpdelta.decode_tree_field(tree, tc, to_level=2)
    upto = tree.level_offsets[3]
    assert np.array_equal(d2[:upto], tree.fields["density"][:upto])
    assert (d2[upto:] == 0).all()


def test_zbits_runtime_tunable():
    """Paper: the 4-bit default is runtime-tunable for locally-varying
    fields; more zbits must never break exactness."""
    rng = np.random.default_rng(3)
    pred = np.full(256, 1.0)
    sons = np.full((256, 8), 1.0)
    sons[:, 0] += 1e-15  # nearly-equal values -> deep leading zeros
    for zbits in (4, 6, 8):
        blk = fpdelta.encode(pred, sons, zbits=zbits)
        assert np.array_equal(fpdelta.decode(blk, pred), sons)
    r4 = fpdelta.encode(pred, sons, zbits=4).rate_vs_raw()
    r6 = fpdelta.encode(pred, sons, zbits=6).rate_vs_raw()
    assert r6 > r4  # more zero-budget pays off on smooth data


# ------------------------------------------------------------- ML pyramid

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3000), st.sampled_from(["float32", "float64"]))
def test_pyramid_roundtrip_property(n, dtype):
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n).astype(dtype)
    pc = pyramid.encode_pyramid(x)
    assert np.array_equal(pyramid.decode_pyramid(pc), x)


def test_temporal_delta_roundtrip_and_rate():
    rng = np.random.default_rng(4)
    prev = rng.standard_normal((64, 128)).astype(np.float32)
    cur = prev + 1e-5 * rng.standard_normal(prev.shape).astype(np.float32)
    dc = pyramid.encode_delta(cur, prev)
    assert np.array_equal(pyramid.decode_delta(dc, prev), cur)
    assert dc.nbytes < cur.nbytes * 0.8  # small updates compress
