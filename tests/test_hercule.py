"""Hercule database layer: contexts, NCF aggregation, rollover, crash
safety, codecs; checkpoint manager incl. async + delta-chain + elastic."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.hercule import HerculeDB, api
from repro.hercule.checkpoint import CheckpointManager


@pytest.fixture()
def tmpdb(tmp_path):
    return str(tmp_path / "db")


def test_context_roundtrip(tmpdb):
    db = HerculeDB.create(tmpdb, kind="hdep", ncf=4)
    ctx = db.begin_context(5)
    a = np.arange(100, dtype=np.float32).reshape(10, 10)
    ctx.write_array(2, "field/x", a)
    ctx.finalize(attrs={"note": "hi"})
    assert db.contexts() == [5]
    got = db.read(5, 2, "field/x")
    np.testing.assert_array_equal(got, a)
    assert db.load_index(5)["attrs"]["note"] == "hi"


def test_ncf_file_aggregation(tmpdb):
    """N domains, NCF=P -> ceil(N/P) files (paper's 16x file reduction)."""
    for ncf, want in ((1, 16), (4, 4), (16, 1)):
        root = f"{tmpdb}_{ncf}"
        db = HerculeDB.create(root, kind="hprot", ncf=ncf)
        ctx = db.begin_context(0)
        for d in range(16):
            ctx.write_array(d, "x", np.zeros(10))
        ctx.finalize()
        assert db.n_files() == want, (ncf, db.n_files())
        db.close()


def test_max_file_size_rollover(tmpdb):
    db = HerculeDB.create(tmpdb, kind="hprot", ncf=8, max_file_bytes=1000)
    for step in range(4):
        ctx = db.begin_context(step)
        ctx.write_array(0, "x", np.zeros(100))  # 800 B each
        ctx.finalize()
    # limit checked before each write: 2 contexts land per file
    assert db.n_files() == 2
    # every context still readable
    for step in range(4):
        np.testing.assert_array_equal(db.read(step, 0, "x"), np.zeros(100))
    db.close()


def test_multiple_contexts_share_file(tmpdb):
    """Hercule semantics: many time steps in ONE physical file."""
    db = HerculeDB.create(tmpdb, kind="hprot", ncf=8)
    for step in range(5):
        ctx = db.begin_context(step)
        ctx.write_array(0, "x", np.full(4, step, np.int32))
        ctx.finalize()
    assert db.n_files() == 1
    for step in range(5):
        np.testing.assert_array_equal(db.read(step, 0, "x"),
                                      np.full(4, step, np.int32))


def test_unfinalized_context_invisible(tmpdb):
    db = HerculeDB.create(tmpdb, kind="hprot", ncf=2)
    ctx = db.begin_context(1)
    ctx.write_array(0, "x", np.zeros(5))
    ctx.finalize()
    ctx2 = db.begin_context(2)  # never finalized = crash mid-write
    ctx2.write_array(0, "x", np.ones(5))
    assert db.contexts() == [1]
    assert db.latest_context() == 1


# ---------------------------------------------------------- checkpointing

def _state():
    return {"params": {"w": jnp.arange(512, dtype=jnp.float32).reshape(16, 32),
                       "scale": jnp.float32(2.5) * jnp.ones(8)},
            "step": jnp.int32(3)}


def _template(state):
    dev = jax.devices()[0]
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            jnp.shape(x), jnp.result_type(x),
            sharding=jax.sharding.SingleDeviceSharding(dev)), state)


@pytest.mark.parametrize("mode", ["raw", "delta", "pyramid", "auto"])
def test_checkpoint_modes_bitwise(tmpdb, mode):
    state = _state()
    mgr = CheckpointManager(tmpdb, ncf=2, mode=mode, async_write=False)
    mgr.save(1, state)
    s2 = jax.tree.map(lambda x: x + 1 if x.dtype.kind == "f" else x, state)
    mgr.save(2, s2)
    for step, want in ((1, state), (2, s2)):
        got, _ = mgr.restore(_template(state), step=step)
        assert jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.array_equal(a, b)), got, want)), (mode, step)
    mgr.close()


def test_async_checkpoint_barrier(tmpdb):
    state = _state()
    mgr = CheckpointManager(tmpdb, ncf=2, mode="raw", async_write=True)
    for step in range(1, 6):
        mgr.save(step, state)
    mgr.wait()
    assert mgr.db.contexts() == [1, 2, 3, 4, 5]
    mgr.close()


def test_elastic_restore_different_sharding(tmp_path):
    """Save from one layout, restore to another (slices recomposed)."""
    root = str(tmp_path / "el")
    big = {"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}
    mgr = CheckpointManager(root, ncf=2, async_write=False)
    mgr.save(1, big)
    got, _ = mgr.restore(_template(big), step=1)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(big["w"]))
    mgr.close()


def test_checkpoint_attrs_and_latest(tmpdb):
    mgr = CheckpointManager(tmpdb, ncf=1, async_write=False)
    mgr.save(10, _state(), attrs={"loss": 0.5})
    mgr.save(20, _state(), attrs={"loss": 0.25})
    assert mgr.latest_step() == 20
    _, attrs = mgr.restore(_template(_state()))
    assert attrs["loss"] == 0.25
    mgr.close()


# ----------------------------------------------------------------- HDep

def test_hdep_analysis_roundtrip(tmpdb):
    db = HerculeDB.create(tmpdb, kind="hdep", ncf=2)
    ctx = db.begin_context(0)
    rng = np.random.default_rng(0)
    tensors = {"w1": (rng.standard_normal((64, 32)) * 1e-2).astype(np.float32),
               "stats": rng.standard_normal(1000)}
    api.write_object(ctx, "analysis", 0, tensors)
    ctx.finalize()
    out = api.read_object(db, 0, "analysis", 0)
    for k, v in tensors.items():
        np.testing.assert_array_equal(out[k], v)


def test_hdep_amr_object_roundtrip(tmp_path):
    from repro.core import decompose, prune
    from repro.sim import amrgen, fields
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                             threshold=1.2)
    dom = decompose.assign_domains(t, 4)
    lt = decompose.local_tree(t, dom, 1, coarse_level=1)
    pt = prune.prune(lt)
    db = HerculeDB.create(str(tmp_path / "hd"), kind="hdep", ncf=2)
    ctx = db.begin_context(0)
    api.write_object(ctx, "amr_tree", 1, pt)
    ctx.finalize()
    rt = api.read_object(db, 0, "amr_tree", 1)
    rt.validate()
    assert np.array_equal(rt.refine, pt.refine)
    assert np.array_equal(rt.owner, pt.owner)
    assert np.array_equal(rt.coords, pt.coords)
    for f in pt.fields:
        assert np.array_equal(rt.fields[f], pt.fields[f]), f
