"""Boolean RLE base-52 codec + Hilbert curve properties."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to fixed-example replay (tests/_hypothesis_fallback.py)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import boolcodec as bc, hilbert as hb


@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=2000))
def test_boolcodec_roundtrip(bits):
    arr = np.array(bits, bool)
    enc = bc.encode(arr)
    assert enc.isalpha() or enc == b""  # base-52 letters only
    assert np.array_equal(bc.decode(enc, len(arr)), arr)


def test_boolcodec_long_runs():
    arr = np.zeros(1_000_000, bool)
    arr[123_456:654_321] = True
    enc = bc.encode(arr)
    assert len(enc) < 20  # few giant runs -> bytes
    assert np.array_equal(bc.decode(enc, arr.size), arr)
    # paper regime: ownership compresses ~99% vs bitfield
    assert bc.compression_vs_bitfield(arr) > 0.99


def test_boolcodec_alternating_worstcase():
    arr = (np.arange(4096) % 2).astype(bool)
    enc = bc.encode(arr)
    assert np.array_equal(bc.decode(enc, arr.size), arr)


def test_hilbert_bijective_8cube():
    from itertools import product
    c = np.array(list(product(range(8), repeat=3)), np.uint64)
    k = hb.coords_to_key(c, 3)
    assert sorted(k.tolist()) == list(range(512))
    assert np.array_equal(hb.key_to_coords(k, 3), c)


def test_hilbert_continuity():
    cc = hb.key_to_coords(np.arange(4096, dtype=np.uint64), 4)
    d = np.abs(np.diff(cc.astype(np.int64), axis=0)).sum(1)
    assert (d == 1).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 500))
def test_hilbert_inverse_property(bits, n):
    rng = np.random.default_rng(bits * 1000 + n)
    coords = rng.integers(0, 2**bits, (n, 3)).astype(np.uint64)
    keys = hb.coords_to_key(coords, bits)
    assert np.array_equal(hb.key_to_coords(keys, bits), coords)


def test_domain_split_balance():
    rng = np.random.default_rng(0)
    keys = rng.permutation(10_000).astype(np.uint64)
    dom = hb.domain_split(keys, 7)
    counts = np.bincount(dom)
    assert counts.max() - counts.min() <= 1
    # contiguity along the curve
    order = np.argsort(keys)
    assert (np.diff(dom[order]) >= 0).all()
