"""HProt async checkpoint subsystem (repro.ckpt): parity, delta chains,
integrity verification, crash recovery, lane failure, elastic restore."""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointManager, CorruptShardError,
                        latest_complete_step)
from repro.hercule.checkpoint import CheckpointManager
from repro.hercule.database import HerculeDB

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _state(step: int):
    """Deterministic, temporally correlated state (recomputable anywhere)."""
    base = np.arange(96 * 32, dtype=np.float32).reshape(96, 32) / 977.0
    return {"params": {"w": jnp.asarray(base * (1.0 + step / 100.0)),
                       "b": jnp.asarray(np.full(32, step, np.float32))},
            "mu": {"w": jnp.asarray(base * 0.01 * step)},
            "step": jnp.int32(step)}


def _template(state):
    dev = jax.devices()[0]
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            jnp.shape(x), jnp.result_type(x),
            sharding=jax.sharding.SingleDeviceSharding(dev)), state)


def _assert_tree_equal(got, want, ctx=""):
    flat_g = jax.tree_util.tree_flatten_with_path(got)[0]
    flat_w = jax.tree_util.tree_flatten_with_path(want)[0]
    assert len(flat_g) == len(flat_w)
    for (pg, a), (pw, b) in zip(flat_g, flat_w):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ctx}{pg}")


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_async_matches_sync_restore(tmp_path, backend):
    """Full async checkpoint restores to the same bytes as a sync one."""
    state = _state(3)
    sync = CheckpointManager(str(tmp_path / "sync"), ncf=2,
                             async_write=False)
    sync.save(1, state)
    got_sync, _ = sync.restore(_template(state), step=1)
    sync.close()

    amgr = AsyncCheckpointManager(str(tmp_path / "async"), ncf=2,
                                  lane_backend=backend)
    amgr.save(1, state, attrs={"tag": "parity"})
    amgr.wait()
    got_async, attrs = amgr.restore(_template(state), step=1)
    amgr.close()

    assert attrs["tag"] == "parity" and attrs["mode"] == "full"
    _assert_tree_equal(got_async, got_sync, "async-vs-sync ")
    _assert_tree_equal(got_async, state, "async-vs-source ")


def test_delta_chain_bitexact_across_rebase(tmp_path):
    """K=2 deltas restore bit-exactly, including across the full rebase."""
    m = AsyncCheckpointManager(str(tmp_path / "d"), ncf=2, delta_every=2)
    for s in range(1, 6):
        m.save(s, _state(s))
    m.wait()
    # cycle: 1 full, 2-3 delta, 4 full rebase, 5 delta
    modes = {s: m.db.view(s).attrs["mode"] for s in range(1, 6)}
    assert modes == {1: "full", 2: "delta", 3: "delta", 4: "full",
                     5: "delta"}, modes
    w3 = m.db.view(3).record(0, "ckpt/['params']['w']")
    assert w3.codec == "fpdelta-delta" and int(w3.meta["pred_step"]) == 2
    assert "crc32" in w3.meta
    tpl = _template(_state(1))
    for s in range(1, 6):    # every step, either side of the rebase
        got, _ = m.restore(tpl, step=s)
        _assert_tree_equal(got, _state(s), f"step {s} ")
    m.close()


def test_corrupt_shard_raises(tmp_path):
    m = AsyncCheckpointManager(str(tmp_path / "c"), ncf=2)
    m.save(1, _state(1))
    m.wait()
    rec = m.db.view(1).record(0, "ckpt/['params']['w']")
    path = os.path.join(m.db.root, "data", rec.file)
    with open(path, "r+b") as f:    # flip one payload byte
        f.seek(rec.offset + rec.nbytes // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CorruptShardError, match="CRC32"):
        m.restore(_template(_state(1)), step=1)
    m.close()


def test_latest_step_skips_incomplete(tmp_path):
    """A manifest referencing truncated/missing data loses latest_step."""
    m = AsyncCheckpointManager(str(tmp_path / "t"), ncf=2)
    for s in (1, 2):
        m.save(s, _state(s))
    m.wait()
    assert m.latest_step() == 2
    # truncate the file holding step 2's records below a record extent
    recs = [r for r in m.db.view(2).records]
    path = os.path.join(m.db.root, "data", recs[-1].file)
    with open(path, "r+b") as f:
        f.truncate(recs[-1].offset + recs[-1].nbytes - 1)
    m.db._invalidate_view(2)
    assert m.latest_step() == 1      # newest *complete* step wins
    got, _ = m.restore(_template(_state(1)))
    _assert_tree_equal(got, _state(1))
    m.close()


def test_lane_crash_no_manifest_no_deadlock(tmp_path):
    """A dying writer lane surfaces as an error, leaves no manifest for
    the in-flight step, and never deadlocks wait()."""
    m = AsyncCheckpointManager(str(tmp_path / "k"), ncf=2,
                               lane_backend="process")
    m.save(1, _state(1))
    m.wait()                          # lane exists and step 1 committed
    [proc] = m._backend._procs.values()
    os.kill(proc.pid, signal.SIGKILL)
    proc.join(timeout=10)
    m.save(2, _state(2))
    with pytest.raises(RuntimeError, match="lane"):
        m.wait(timeout=60)
    assert not os.path.exists(
        os.path.join(m.db.root, "ctx_00000002", "MANIFEST.json"))
    assert latest_complete_step(m.db) == 1
    m.close()


_KILL_SNIPPET = """
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys; sys.path.insert(0, {src!r})
from test_ckpt_async import _state
from repro.ckpt import AsyncCheckpointManager

m = AsyncCheckpointManager({root!r}, ncf=2, delta_every=2)
for s in (1, 2):
    m.save(s, _state(s))
m.wait()
m.save(3, _state(3))     # still staging/writing when we die
print("SAVED", flush=True)
os._exit(17)
"""


def test_kill_mid_save_recovers_previous_step(tmp_path):
    """Killing the process mid-checkpoint leaves a restorable database:
    either step 3 committed in time, or recovery lands on step 2 —
    never a torn manifest, never garbage."""
    root = str(tmp_path / "kill")
    out = subprocess.run(
        [sys.executable, "-c",
         _KILL_SNIPPET.format(src=SRC, root=root)],
        env={**os.environ, "PYTHONPATH":
             SRC + os.pathsep + os.path.dirname(__file__)},
        cwd=os.path.dirname(__file__),
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 17, (out.returncode, out.stderr[-3000:])
    db = HerculeDB.open(root)
    latest = latest_complete_step(db)
    assert latest in (2, 3), latest
    db.close()
    m = AsyncCheckpointManager(root, ncf=2)    # reopen like a restart
    got, _ = m.restore(_template(_state(latest)), step=latest)
    _assert_tree_equal(got, _state(latest), f"recovered step {latest} ")
    m.close()


_ELASTIC_SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.ckpt import AsyncCheckpointManager

mesh = Mesh(np.array(jax.devices()).reshape(4), ("d",))
sh = NamedSharding(mesh, P("d"))
state = {{
    "w": jax.device_put(jnp.arange(64 * 8, dtype=jnp.float32
                                   ).reshape(64, 8), sh),
    "b": jax.device_put(jnp.arange(128, dtype=jnp.float32) / 128.0, sh),
    "step": jnp.int32(7),
}}
m = AsyncCheckpointManager({root!r}, ncf=2)
m.save(1, state)
m.wait()
n = len(m.db.view(1).records_named("ckpt/['w']"))
m.close()
print("SAVED", n)
"""

_ELASTIC_RESTORE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.ckpt import AsyncCheckpointManager

mesh = Mesh(np.array(jax.devices()).reshape(2), ("d",))
sh = NamedSharding(mesh, P("d"))
template = {{
    "w": jax.ShapeDtypeStruct((64, 8), jnp.float32, sharding=sh),
    "b": jax.ShapeDtypeStruct((128,), jnp.float32, sharding=sh),
    "step": jax.ShapeDtypeStruct((), jnp.int32,
        sharding=jax.sharding.SingleDeviceSharding(jax.devices()[0])),
}}
m = AsyncCheckpointManager({root!r}, ncf=2)
got, _ = m.restore(template, step=1)
assert got["w"].sharding.num_devices == 2, got["w"].sharding
np.testing.assert_array_equal(
    np.asarray(got["w"]),
    np.arange(64 * 8, dtype=np.float32).reshape(64, 8))
np.testing.assert_array_equal(
    np.asarray(got["b"]), np.arange(128, dtype=np.float32) / 128.0)
assert int(got["step"]) == 7
m.close()
print("RESTORED-OK")
"""


def test_elastic_restore_through_async_manager(tmp_path):
    """4-way sharded async save restores onto a 2-device mesh."""
    root = str(tmp_path / "elastic")

    def run(code):
        return subprocess.run([sys.executable, "-c", code],
                              env={**os.environ, "PYTHONPATH": SRC},
                              capture_output=True, text=True, timeout=300)

    out = run(_ELASTIC_SAVE.format(root=root))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SAVED 4" in out.stdout, out.stdout   # ownership pruning held
    out = run(_ELASTIC_RESTORE.format(root=root))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESTORED-OK" in out.stdout


def test_stall_and_metrics_accounting(tmp_path):
    """Spans + metrics cover the save pipeline; stall total accumulates."""
    from repro.obs import TRACER
    TRACER.enable()
    TRACER.clear()
    try:
        m = AsyncCheckpointManager(str(tmp_path / "m"), ncf=2,
                                   delta_every=2)
        for s in (1, 2):
            m.save(s, _state(s))
        m.wait()
        assert m.stall_seconds_total > 0.0
        t = m.telemetry()
        assert t["committed"] == 2 and t["pending"] == 0
        snap = m.obs.snapshot()
        assert snap["ckpt_stall_seconds"]["samples"][0]["value"]["count"] == 2
        assert snap["ckpt_records_total"]["samples"][0]["value"] == 8
        modes = {s["labels"]["mode"]: s["value"]
                 for s in snap["ckpt_saves_total"]["samples"]}
        assert modes == {"full": 1.0, "delta": 1.0}
        m.close()
        names = {s["name"] for s in TRACER.spans()}
        assert {"ckpt.snapshot", "ckpt.stage", "ckpt.write",
                "ckpt.commit"} <= names, names
    finally:
        TRACER.disable()
        TRACER.clear()
