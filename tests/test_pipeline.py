"""GPipe over the pod axis == sequential forward (subprocess: needs
forced multi-device CPU)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_test_mesh
from repro.launch import pipeline

mesh = make_test_mesh((4, 2), ("pod", "data"))
rng = np.random.default_rng(0)
L, D = 8, 16          # 8 layers -> 4 stages x 2 layers
params = {
    "w": jnp.asarray(rng.standard_normal((L, D, D)) * 0.3, jnp.float32),
    "b": jnp.asarray(rng.standard_normal((L, D)) * 0.1, jnp.float32),
}

def stage_fn(p, x):     # p has leading dim L/S
    for i in range(p["w"].shape[0]):
        x = jnp.tanh(x @ p["w"][i] + p["b"][i])
    return x

n_micro, mb = 6, 4
x = jnp.asarray(rng.standard_normal((n_micro, mb, D)), jnp.float32)
stages = pipeline.stack_stages(params, 4)
with mesh:
    got = pipeline.gpipe_forward(stage_fn, stages, x, mesh=mesh)
want = pipeline.sequential_forward(stage_fn, stages, x, 4)
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-5, err
assert abs(pipeline.bubble_fraction(6, 4) - 3/9) < 1e-9
print("PIPELINE_OK", err)
"""


def test_gpipe_equivalence():
    out = subprocess.run([sys.executable, "-c", _CODE],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout
