"""bitstream: pack/unpack roundtrips (unit + hypothesis property)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to fixed-example replay (tests/_hypothesis_fallback.py)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import bitstream as bs


def _mask(nbits):
    nb = nbits.astype(np.uint64)
    return np.where(nbits >= 32, np.uint32(0xFFFFFFFF),
                    ((np.uint64(1) << nb) - np.uint64(1)).astype(np.uint32))


def test_roundtrip_basic():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 2**32, 5000, dtype=np.uint64).astype(np.uint32)
    nbits = rng.integers(0, 33, 5000).astype(np.int32)
    words, total = bs.pack_bits_host(vals, nbits)
    assert total == int(nbits.sum())
    out = bs.unpack_bits_host(words, nbits)
    assert np.array_equal(out, vals & _mask(nbits))


def test_all_32bit():
    vals = np.arange(100, dtype=np.uint32) * 40503
    nbits = np.full(100, 32, np.int32)
    words, total = bs.pack_bits_host(vals, nbits)
    assert total == 3200
    assert np.array_equal(bs.unpack_bits_host(words, nbits), vals)


def test_zero_bits():
    vals = np.full(64, 0xDEADBEEF, np.uint32)
    nbits = np.zeros(64, np.int32)
    words, total = bs.pack_bits_host(vals, nbits)
    assert total == 0
    assert np.array_equal(bs.unpack_bits_host(words, nbits), np.zeros(64, np.uint32))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 32)),
                min_size=1, max_size=300))
def test_roundtrip_property(pairs):
    vals = np.array([p[0] for p in pairs], np.uint32)
    nbits = np.array([p[1] for p in pairs], np.int32)
    words, _ = bs.pack_bits_host(vals, nbits)
    out = bs.unpack_bits_host(words, nbits)
    assert np.array_equal(out, vals & _mask(nbits))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(allow_nan=False, width=64), min_size=1, max_size=64))
def test_f64_pair_roundtrip(xs):
    a = np.array(xs, np.float64)
    hi, lo = bs.f64_to_pair(a)
    assert np.array_equal(bs.pair_to_f64(hi, lo), a)


def test_f64_pair_specials():
    a = np.array([np.nan, np.inf, -np.inf, -0.0, 5e-324, np.pi])
    hi, lo = bs.f64_to_pair(a)
    back = bs.pair_to_f64(hi, lo)
    assert np.array_equal(back, a, equal_nan=True)
    assert np.signbit(back[3])  # -0.0 preserved
