"""Per-arch smoke tests (deliverable f) + decode==forward consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import serving
from repro.models.transformer import LM
from repro.train import optim, step as step_lib


def _inputs(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32) * 0.1
    if cfg.family == "encdec":
        extras["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_frames, cfg.d_model)), jnp.float32) * 0.1
    return tokens, extras


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes + no NaNs (assignment requirement)."""
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    tokens, extras = _inputs(cfg, b, s)
    logits, aux = lm.forward(params, tokens, extras=extras)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    ts = step_lib.make_train_step(lm, optim.OptConfig(warmup_steps=1))
    state = {"params": params, **optim.init_opt_state(params)}
    batch = {"tokens": tokens, "labels": tokens, **extras}
    state, metrics = jax.jit(ts)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state["step"]) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b_: bool(jnp.any(a != b_)),
                         state["params"], params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill+decode logits == full forward logits (cache correctness).
    MoE uses a no-drop capacity factor: token dropping legitimately
    depends on batch composition."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    b, s, extra = 2, 12, 3
    tokens, extras = _inputs(cfg, b, s + extra, seed=1)
    full, _ = lm.forward(params, tokens, extras=extras)
    lg, cache = serving.prefill(lm, params, tokens[:, :s], extras=extras,
                                max_seq=s + extra)
    scale = float(jnp.max(jnp.abs(full)))
    errs = [float(jnp.max(jnp.abs(lg - full[:, s - 1])))]
    for i in range(extra):
        lg, cache = serving.decode_step(lm, params, tokens[:, s + i],
                                        jnp.int32(s + i), cache)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, s + i]))))
    assert max(errs) / max(scale, 1e-6) < 2e-2, errs


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    want = {
        "whisper_medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               d_ff=4096, vocab_size=51865),
        "minicpm_2b": dict(n_layers=40, d_model=2304, n_heads=36,
                           d_ff=5760, vocab_size=122753),
        "internlm2_20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92544),
        "nemotron_4_340b": dict(n_layers=96, d_model=18432, n_heads=96,
                                n_kv_heads=8, d_ff=73728, vocab_size=256000),
        "stablelm_1_6b": dict(n_layers=24, d_model=2048, n_heads=32,
                              d_ff=5632, vocab_size=100352),
        "mamba2_1_3b": dict(n_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "mixtral_8x22b": dict(n_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=32768,
                              n_experts=8, top_k=2),
        "granite_moe_1b_a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab_size=49155,
                                     n_experts=32, top_k=8),
        "recurrentgemma_2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv_heads=1, d_ff=7680, vocab_size=256000),
        "llava_next_34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab_size=64000),
    }
    for arch, fields in want.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_count_plausible():
    """Formula param counts near published sizes (rough: +-40%)."""
    approx = {"minicpm_2b": 2.7e9, "internlm2_20b": 20e9,
              "nemotron_4_340b": 340e9, "stablelm_1_6b": 1.6e9,
              "mamba2_1_3b": 1.3e9, "mixtral_8x22b": 141e9,
              "recurrentgemma_2b": 2.7e9, "llava_next_34b": 34e9}
    for arch, want in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * want < n < 1.6 * want, (arch, n, want)


def test_unroll_matches_scan():
    """maybe_scan(unroll=True) must be numerically identical to scan."""
    cfg = get_smoke_config("internlm2_20b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(2))
    tokens, _ = _inputs(cfg, 2, 8, seed=3)
    a, _ = lm.forward(params, tokens)
    cfg_u = dataclasses.replace(cfg, unroll_layers=True)
    b, _ = LM(cfg_u).forward(params, tokens)
    # bf16 compute: scan vs unroll fuse differently -> rounding-order noise
    scale = float(np.abs(np.asarray(a)).max())
    assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) < 0.05 * scale


def test_sliding_window_masks_old_tokens():
    cfg = dataclasses.replace(get_smoke_config("mixtral_8x22b"), window=4,
                              capacity_factor=16.0)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(3))
    tokens, _ = _inputs(cfg, 1, 10, seed=4)
    # changing a token >window positions back must not change the logits
    t2 = tokens.at[0, 0].set((int(tokens[0, 0]) + 1) % cfg.vocab_size)
    la, _ = lm.forward(params, tokens)
    lb, _ = lm.forward(params, t2)
    np.testing.assert_allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]),
                               atol=1e-4)
