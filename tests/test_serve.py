"""Serving-engine tests (PR 8 acceptance).

Covers: single-flight coalescing under a thundering herd (N concurrent
identical queries, one backend read, byte-identical responses), region
batching onto one flight, admission control (ServeOverloaded / HTTP 429
with Retry-After, backpressure-coupled capacity), per-client round-robin
fairness, cache-hit admission bypass, progressive (coarse-first)
response planning and bit-exact reassembly, the HTTP integration
(engine-routed /v1/query, ETag/304 interplay, busy retries, chunked
progressive streams), and the bounded connection-worker pool.
"""
import threading
import time

import numpy as np
import pytest

from repro.insitu import (Catalog, CatalogBusy, CatalogServer,
                          InTransitEngine, LevelHistogramReducer,
                          ProgressiveAssembler, ProjectionReducer,
                          RemoteCatalog, ServeEngine, ServeOverloaded,
                          SliceReducer, plan_progressive)
from repro.insitu.server import pack_frame, unpack_frame
from repro.sim import amrgen, fields


# --------------------------------------------------------------- fakes

class FakeCatalog:
    """In-memory catalog double: countable, pace-able backend reads."""

    def __init__(self, delay=0.0):
        self.delay = delay
        self.reads = []
        self._lock = threading.Lock()
        self._cached = set()

    def peek(self, step, reducer, domain=None):
        return (step, reducer, domain) in self._cached

    def query(self, step, reducer, *, domain=None):
        if self.delay:
            time.sleep(self.delay)
        with self._lock:
            self.reads.append((step, reducer, domain))
        arr = np.arange(64 * 64, dtype=np.float64).reshape(64, 64) + step
        arr.flags.writeable = False
        return {"image": arr}


def _storm(engine, n, call):
    """Barrier-release ``n`` threads through ``call(i)``; collect."""
    results, errors = [None] * n, [None] * n
    bar = threading.Barrier(n)

    def run(i):
        bar.wait()
        try:
            results[i] = call(i)
        except Exception as exc:              # noqa: BLE001 — assert later
            errors[i] = exc

    ts = [threading.Thread(target=run, args=(i,)) for i in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return results, errors


# ------------------------------------------------------- single flight

def test_thundering_herd_single_read():
    fake = FakeCatalog(delay=0.05)
    eng = ServeEngine(fake, workers=2, max_pending=64)
    try:
        res, errs = _storm(eng, 24, lambda i: eng.fetch(1, "slice"))
        assert not any(errs)
        assert len(fake.reads) == 1          # one decode+merge for 24
        ref = res[0]["image"]
        for r in res[1:]:                    # byte-identical responses
            assert r["image"].tobytes() == ref.tobytes()
        st = eng.stats()
        assert st["coalesced"] == 23
        assert st["backend_reads"] == 1
    finally:
        eng.close()


def test_batched_region_crops_one_read():
    fake = FakeCatalog(delay=0.05)
    eng = ServeEngine(fake, workers=2, max_pending=64)
    regions = [None, ((0, 16), (0, 16)), ((8, 24), (8, 24)),
               ((0, 32), (32, 64))]
    try:
        res, errs = _storm(
            eng, 16,
            lambda i: eng.fetch(1, "slice", region=regions[i % 4],
                                client=f"c{i}"))
        assert not any(errs)
        assert len(fake.reads) == 1          # all crops share the read
        full = fake.query(1, "slice")["image"]
        fake.reads.clear()
        for i, r in enumerate(res):
            reg = regions[i % 4]
            want = full if reg is None else \
                full[tuple(slice(lo, hi) for lo, hi in reg)]
            np.testing.assert_array_equal(r["image"], want)
        assert eng.stats()["batched_reads"] >= 1
    finally:
        eng.close()


def test_distinct_keys_not_coalesced():
    fake = FakeCatalog(delay=0.01)
    eng = ServeEngine(fake, workers=4, max_pending=64)
    try:
        res, errs = _storm(eng, 8, lambda i: eng.fetch(i, "slice"))
        assert not any(errs)
        assert len(fake.reads) == 8          # 8 distinct steps
        for i, r in enumerate(res):
            assert r["image"][0, 0] == float(i)
    finally:
        eng.close()


# --------------------------------------------------- admission control

def test_admission_rejects_with_retry_after():
    fake = FakeCatalog(delay=0.2)
    eng = ServeEngine(fake, workers=1, max_pending=1)
    try:
        t0 = threading.Thread(target=lambda: eng.fetch(1, "slice"))
        t0.start()
        time.sleep(0.05)                     # step 1 occupies the worker
        with pytest.raises(ServeOverloaded) as ei:
            # a *distinct* key cannot coalesce and must be rejected:
            # pending is already at max_pending
            eng.fetch(2, "slice")
        assert ei.value.retry_after > 0
        t0.join()
        assert eng.stats()["rejections"] == 1
    finally:
        eng.close()


def test_backpressure_shrinks_capacity():
    fake = FakeCatalog()
    eng = ServeEngine(fake, workers=1, max_pending=100,
                      pressure_fn=lambda: 1.0)
    try:
        # full staging pressure collapses admission to the ~10% floor
        assert 1 <= eng.capacity() <= 10
        assert eng.retry_after() > ServeEngine(fake).retry_after()
    finally:
        eng.close()


def test_cache_hit_bypasses_admission():
    fake = FakeCatalog(delay=0.2)
    fake._cached.add((7, "slice", None))
    eng = ServeEngine(fake, workers=1, max_pending=1,
                      pressure_fn=lambda: 1.0)
    try:
        t0 = threading.Thread(target=lambda: eng.fetch(1, "slice"))
        t0.start()
        time.sleep(0.05)
        # the queue is saturated, but step 7 is already cached: it must
        # be served inline, not 429'd
        out = eng.fetch(7, "slice")
        assert out["image"][0, 0] == 7.0
        t0.join()
        assert eng.stats()["cache_serves"] == 1
        assert eng.stats()["rejections"] == 0
    finally:
        eng.close()


def test_fairness_round_robin_across_clients():
    fake = FakeCatalog(delay=0.05)
    eng = ServeEngine(fake, workers=1, max_pending=64)
    done = {}
    lock = threading.Lock()

    def fetch(step, client):
        eng.fetch(step, "slice", client=client)
        with lock:
            done[(client, step)] = time.perf_counter()

    try:
        # client A floods the single worker with 6 distinct keys...
        blocker = threading.Thread(target=fetch, args=(0, "A"))
        blocker.start()
        time.sleep(0.02)                     # A's first read is running
        flood = [threading.Thread(target=fetch, args=(s, "A"))
                 for s in range(1, 6)]
        for t in flood:
            t.start()
        time.sleep(0.02)                     # A's queue is now deep
        b = threading.Thread(target=fetch, args=(100, "B"))
        b.start()
        for t in [blocker, *flood, b]:
            t.join()
        # ...yet B's single request is served round-robin: before A's
        # queue tail, not after it
        b_done = done[("B", 100)]
        a_after_b = [s for s in range(1, 6)
                     if done[("A", s)] > b_done]
        assert a_after_b, (
            "client B waited behind client A's whole backlog")
    finally:
        eng.close()


def test_close_fails_queued_flights():
    fake = FakeCatalog(delay=0.2)
    eng = ServeEngine(fake, workers=1, max_pending=32)
    errs = []

    def go(step):
        try:
            eng.fetch(step, "slice")
        except RuntimeError as exc:
            errs.append(exc)

    ts = [threading.Thread(target=go, args=(s,)) for s in range(4)]
    for t in ts:
        t.start()
    time.sleep(0.05)
    eng.close()
    for t in ts:
        t.join()
    # whatever had not completed was failed fast, not left hanging
    assert len(errs) + len(fake.reads) >= 4


# ---------------------------------------------------------- progressive

def test_progressive_plan_and_reassembly_bitexact():
    rng = np.random.default_rng(7)
    arrays = {
        "image": np.cumsum(rng.standard_normal((96, 96)), axis=1),
        "field32": np.cumsum(rng.standard_normal(9000)
                             ).astype(np.float32),
        "counts": np.arange(500, dtype=np.int64),   # ints: frame 0 only
        "tiny": np.ones(16),                         # below min_size
    }
    frames = plan_progressive(arrays)
    assert len(frames) > 1
    assert "counts" in frames[0] and "tiny" in frames[0]
    assert "image@root" in frames[0]
    asm = ProgressiveAssembler()
    errs = []
    for fr in frames:
        cur = asm.feed(unpack_frame(pack_frame(fr)))
        errs.append(float(np.abs(cur["image"] - arrays["image"]).max()))
    assert asm.done
    # refinement is monotone: every chunk tightens the preview
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] == 0.0
    final = asm.result()
    for name, arr in arrays.items():
        assert final[name].dtype == arr.dtype
        np.testing.assert_array_equal(final[name], arr)


def test_progressive_small_arrays_single_frame():
    frames = plan_progressive({"v": np.arange(10, dtype=np.float64)})
    assert len(frames) == 1                  # nothing worth refining
    asm = ProgressiveAssembler()
    asm.feed(unpack_frame(pack_frame(frames[0])))
    assert asm.done
    np.testing.assert_array_equal(asm.result()["v"], np.arange(10.0))


# ----------------------------------------------------- HTTP integration

@pytest.fixture(scope="module")
def served_db(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("serve") / "db")
    eng = InTransitEngine(root, [
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=64),
        ProjectionReducer(field="density", axis=2, resolution=64),
        LevelHistogramReducer(field="density", bins=16, lo=0.0, hi=8.0),
    ], domains=2).start()
    tree = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=4,
                                threshold=1.2)
    assert eng.submit(1, tree)
    eng.close()
    return root


class SlowCatalog:
    """Duck-typed pass-through catalog with paced, counted reads."""

    def __init__(self, inner, delay=0.05):
        self._inner = inner
        self.delay = delay
        self.backend_reads = 0
        self._count_lock = threading.Lock()

    def query(self, *a, **kw):
        time.sleep(self.delay)
        with self._count_lock:
            self.backend_reads += 1
        return self._inner.query(*a, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_http_storm_coalesces_with_etag_interplay(served_db):
    slow = SlowCatalog(Catalog(served_db), delay=0.05)
    srv = CatalogServer(slow, port=0).start()
    try:
        name = RemoteCatalog(srv.url).reducers(1)[0]
        slow._inner.clear_cache()
        reads0 = slow.backend_reads

        def one(i):
            return RemoteCatalog(
                srv.url, client_id=f"c{i}").query(1, name)

        res, errs = _storm(srv.engine, 16, one)
        assert not any(errs)
        # exactly one *flight* read the backend; a late-arriving client
        # may additionally be served inline from the warm cache
        assert srv.engine.stats()["backend_reads"] == 1
        assert slow.backend_reads - reads0 >= 1
        ref = {k: v.tobytes() for k, v in res[0].items()}
        for r in res[1:]:
            assert {k: v.tobytes() for k, v in r.items()} == ref
        assert srv.engine.stats()["coalesced"] > 0
        # a client that already holds the ETag revalidates with a 304
        # that never touches the serving queue
        rc = RemoteCatalog(srv.url)
        rc.query(1, name)
        reads1, inflight1 = slow.backend_reads, srv.engine.stats()
        rc.query(1, name)                    # -> 304
        assert rc.client_cache_info()["etag_hits"] == 1
        assert slow.backend_reads == reads1
        assert srv.engine.stats()["backend_reads"] == \
            inflight1["backend_reads"]
    finally:
        srv.close()


def test_http_429_and_busy_retries(served_db):
    slow = SlowCatalog(Catalog(served_db), delay=0.3)
    srv = CatalogServer(slow, port=0, serve_workers=1, max_pending=1)
    srv.start()
    try:
        names = RemoteCatalog(srv.url).reducers(1)
        slow._inner.clear_cache()
        t0 = threading.Thread(
            target=lambda: RemoteCatalog(srv.url).query(1, names[0]))
        t0.start()
        time.sleep(0.1)                      # names[0] holds the worker
        with pytest.raises(CatalogBusy) as ei:
            RemoteCatalog(srv.url).query(1, names[1])
        assert ei.value.retry_after > 0
        # with retries enabled the same request eventually lands
        out = RemoteCatalog(srv.url, busy_retries=20).query(1, names[1])
        assert out
        t0.join()
        assert srv.engine.stats()["rejections"] >= 1
        assert srv.telemetry()["serve"]["rejections"] >= 1
    finally:
        srv.close()


def test_http_progressive_stream_matches_buffered(served_db):
    srv = CatalogServer(served_db, port=0, compress=True).start()
    try:
        rc = RemoteCatalog(srv.url)
        for name in rc.reducers(1):
            buffered = RemoteCatalog(srv.url).query(1, name)
            stages = list(rc.query_progressive(1, name))
            final = stages[-1]
            for k, v in buffered.items():
                assert final[k].dtype == v.dtype
                np.testing.assert_array_equal(final[k], v)
    finally:
        srv.close()


def test_bounded_connection_pool(served_db):
    srv = CatalogServer(served_db, port=0, max_connections=2).start()
    try:
        name = RemoteCatalog(srv.url).reducers(1)[0]

        def one(i):
            return RemoteCatalog(srv.url,
                                 client_id=f"p{i}").query(1, name)

        # 12 concurrent connections through a 2-worker pool: all are
        # served (queued, not dropped), and saturation is observable
        res, errs = _storm(srv.engine, 12, one)
        assert not any(errs)
        assert all(r is not None for r in res)
        text = srv.obs.render_prometheus()
        assert "server_conn_pool_size 2" in text
        assert "# TYPE server_conn_saturation_total counter" in text
    finally:
        srv.close()
