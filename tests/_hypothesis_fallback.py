"""Minimal stand-in for ``hypothesis`` when it is not installed.

The property tests in this suite only use ``@settings``, ``@given`` and
three strategies (integers, booleans, lists-of-booleans). When hypothesis
is available the real package is used (see the try/except in the test
modules); otherwise these shims replay a small fixed set of examples so
the properties are still exercised — fewer cases, same assertions.
"""
from __future__ import annotations

import itertools

import numpy as np

_MAX_CASES = 20


def settings(**_kw):
    def deco(fn):
        return fn
    return deco


class _Strategy:
    """A strategy is just a finite list of example values here."""

    def __init__(self, examples):
        self.examples = list(examples)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:
        span = hi - lo
        picks = sorted({lo, hi, lo + span // 2, lo + span // 3,
                        lo + 1 if span else lo, lo + 7 % (span + 1)})
        return _Strategy(picks)

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy([False, True])

    @staticmethod
    def floats(allow_nan: bool = True, width: int = 64,
               **_kw) -> _Strategy:
        picks = [0.0, -0.0, 1.0, -1.5, 3.141592653589793, 1e-3,
                 -123456.789, 1e30, -1e30, 5e-324, float("inf"),
                 float("-inf")]
        if allow_nan:
            picks.append(float("nan"))
        return _Strategy(picks)

    @staticmethod
    def sampled_from(values) -> _Strategy:
        return _Strategy(values)

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        rng = np.random.default_rng(99)
        out = []
        for _ in range(8):
            out.append(tuple(s.examples[rng.integers(0, len(s.examples))]
                             for s in strats))
        return _Strategy(out)

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        rng = np.random.default_rng(1234)
        sizes = sorted({min_size, max_size,
                        min(max_size, min_size + 1),
                        (min_size + max_size) // 2,
                        (min_size + max_size) // 7 or min_size})
        out = []
        for n in sizes:
            if n < min_size or n > max_size:
                continue
            idx = rng.integers(0, len(elem.examples), size=n)
            out.append([elem.examples[i] for i in idx])
        return _Strategy(out)


def given(*strats: _Strategy):
    cases = list(itertools.islice(
        itertools.product(*(s.examples for s in strats)), _MAX_CASES))

    def deco(fn):
        # no functools.wraps: pytest must see the zero-arg signature, not
        # the wrapped one (strategy args would look like missing fixtures)
        def runner():
            for case in cases:
                fn(*case)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
