"""AMR tree invariants, decomposition, and pruning semantics."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to fixed-example replay (tests/_hypothesis_fallback.py)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import decompose, prune
from repro.core.amr import subset_tree
from repro.sim import amrgen, fields


@pytest.fixture(scope="module")
def orion_tree():
    f = fields.orion(seed=7)
    t = amrgen.generate_tree(f, min_level=3, max_level=7,
                             threshold=1.0, level_factor=1.6)
    t.validate()
    return t


@pytest.fixture(scope="module")
def domains(orion_tree):
    return decompose.assign_domains(orion_tree, 8)


def test_tree_structure(orion_tree):
    t = orion_tree
    assert t.n_levels >= 5
    assert t.level_offsets[1] - t.level_offsets[0] == 1  # single root
    # BFS child invariant is checked inside validate(); re-check parents
    parent = t.parent()
    cs = t.child_start()
    refined = np.flatnonzero(t.refine)
    assert (parent[cs[refined]] == refined).all()


def test_restriction_is_mean_of_sons(orion_tree):
    t = orion_tree
    cs = t.child_start()
    refined = np.flatnonzero(t.refine)[:100]
    v = t.fields["density"]
    sons = v[(cs[refined][:, None] + np.arange(8)[None, :])]
    assert np.allclose(v[refined], sons.mean(axis=1))


def test_domain_balance(orion_tree, domains):
    counts = np.bincount(domains)
    assert counts.size == 8
    assert counts.max() - counts.min() <= 1


def test_local_tree_and_prune_invariants(orion_tree, domains):
    t = orion_tree
    idx = decompose._LevelIndex(t)
    lt = decompose.local_tree(t, domains, 3, coarse_level=2, index=idx)
    lt.validate()
    pt = prune.prune(lt)
    pt.validate()

    # (1) pruning only removes nodes
    assert pt.n_nodes < lt.n_nodes
    # (2) every owned leaf survives with identical data
    def owned_leaf_set(tr):
        sel = ~tr.refine & tr.owner
        lv = tr.levels()[sel]
        key = [tuple(c) + (int(l),) for c, l in zip(tr.coords[sel], lv)]
        return dict(zip(key, tr.fields["density"][sel]))
    before = owned_leaf_set(lt)
    after = owned_leaf_set(pt)
    assert before.keys() == after.keys()
    for k in before:
        assert before[k] == after[k]
    # (3) removed fraction in the paper's observed band (loose)
    frac = prune.removed_fraction(lt, pt)
    assert 0.05 < frac < 0.7
    # (4) idempotence: pruning a pruned tree removes nothing
    pt2 = prune.prune(pt)
    assert pt2.n_nodes == pt.n_nodes


def test_ghosts_are_neighbors(orion_tree, domains):
    t = orion_tree
    idx = decompose._LevelIndex(t)
    g = decompose.ghost_leaves(t, domains, 0, index=idx)
    leaves = np.flatnonzero(~t.refine)
    leaf_rank = np.full(t.n_nodes, -1, np.int64)
    leaf_rank[leaves] = np.arange(leaves.size)
    assert (domains[leaf_rank[g]] != 0).all()  # ghosts are never mine
    assert g.size > 0


def test_subset_tree_keep_all_is_identity(orion_tree):
    t = orion_tree
    s = subset_tree(t, np.ones(t.n_nodes, bool))
    assert s.n_nodes == t.n_nodes
    assert np.array_equal(s.refine, t.refine)
    assert np.array_equal(s.coords, t.coords)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_random_tree_prune_validates(seed):
    """Property: pruning any generated local tree keeps a valid octree."""
    f = fields.orion(seed=seed % 100)
    t = amrgen.generate_tree(f, min_level=2, max_level=5,
                             threshold=1.0, level_factor=1.5)
    dom = decompose.assign_domains(t, 4)
    lt = decompose.local_tree(t, dom, seed % 4, coarse_level=1)
    pt = prune.prune(lt)
    pt.validate()
    assert pt.owner.sum() == lt.owner.sum()
