"""Pluggable lane runtime + server-mode catalog (PR 4 acceptance).

Covers: thread/process backend parity (same steps in, byte-identical
merged reads out), shared-memory slab reclamation on release()/close(),
TTL-finalized partial contexts, and a RemoteCatalog round trip against a
live catalog server on an ephemeral port.
"""
import pickle
import time

import numpy as np
import pytest

from repro.insitu import (BACKENDS, Catalog, CatalogServer, InTransitEngine,
                          LevelHistogramReducer, LODCutReducer,
                          ProjectionReducer, RemoteCatalog, ShmStagingArea,
                          SliceReducer, TensorNormReducer)
from repro.insitu.partition import partition_snapshot
from repro.insitu.staging import _attach_shm
from repro.sim import amrgen, fields


@pytest.fixture(scope="module")
def sedov_tree():
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=5,
                             threshold=1.2)
    t.validate()
    return t


def _reducers():
    # fixed histogram bounds: auto bounds cannot merge across domains
    return [LODCutReducer(max_level=3),
            SliceReducer(field="density", axis=2, position=0.5,
                         resolution=48),
            ProjectionReducer(field="density", axis=2, resolution=48),
            LevelHistogramReducer(field="density", bins=16, lo=0.0, hi=8.0)]


# ----------------------------------------------------- backend registry

def test_backend_registry(tmp_path):
    assert set(BACKENDS) >= {"thread", "process"}
    root = tmp_path / "db"
    with pytest.raises(ValueError, match="unknown lane backend"):
        InTransitEngine(str(root), [], backend="warp-drive")
    assert not root.exists()   # validated before touching the disk


# ----------------------------------------------- thread/process parity

def test_thread_process_parity_byte_identical(tmp_path, sedov_tree):
    """Same steps in -> byte-identical merged reads out of either lane
    runtime, and identical context attrs surface (the acceptance bar:
    thread stays PR-3 behavior, process reproduces it exactly)."""
    roots = {}
    for backend in ("thread", "process"):
        root = str(tmp_path / backend)
        roots[backend] = root
        eng = InTransitEngine(root, _reducers(), domains=2,
                              backend=backend, policy="block",
                              queue_capacity=2).start()
        assert eng.backend == backend
        for s in (1, 2):
            assert eng.submit(s, sedov_tree)
        eng.close()
        assert eng.written_steps == [1, 2]

    ct, cp = Catalog(roots["thread"]), Catalog(roots["process"])
    assert ct.steps() == cp.steps() == [1, 2]
    checked = 0
    for s in ct.steps():
        assert ct.reducers(s) == cp.reducers(s)
        at, ap = ct.attrs(s)["insitu"], cp.attrs(s)["insitu"]
        for key in ("kind", "reducers", "merge", "n_domains", "domains"):
            assert at[key] == ap[key], key
        for reducer in ct.reducers(s):
            assert ct.domains(s, reducer) == cp.domains(s, reducer) == [0, 1]
            merged_t = ct.query(s, reducer)            # merge-at-read
            merged_p = cp.query(s, reducer)
            assert set(merged_t) == set(merged_p)
            for k, v in merged_t.items():
                assert v.dtype == merged_p[k].dtype
                assert np.array_equal(v, merged_p[k], equal_nan=True), \
                    (s, reducer, k)
                checked += 1
            for d in (0, 1):                           # per-domain parts
                pt, pp = ct.query(s, reducer, domain=d), \
                    cp.query(s, reducer, domain=d)
                for k, v in pt.items():
                    assert np.array_equal(v, pp[k], equal_nan=True)
    assert checked >= 8
    ct.close()
    cp.close()


def test_process_backend_forces_exclusive_groups(tmp_path):
    from repro.hercule.database import HerculeDB
    # engine-created db: ncf forced to 1 so each lane owns its files
    eng = InTransitEngine(str(tmp_path / "a"), _reducers(), domains=2,
                          backend="process")
    assert eng.db.ncf == 1
    eng.close(drain=False)
    # pre-opened db with shared group files is refused
    db = HerculeDB.create(str(tmp_path / "b"), kind="hdep", ncf=4)
    with pytest.raises(ValueError, match="ncf"):
        InTransitEngine(db, _reducers(), domains=2, backend="process")
    db.close()
    # a *pre-existing* ncf=4 database directory is refused too: create()
    # honors the on-disk manifest, so the parent and the lane processes
    # can never disagree about the group->file mapping
    with pytest.raises(ValueError, match="ncf"):
        InTransitEngine(str(tmp_path / "b"), _reducers(), domains=2,
                        backend="process")


def test_create_honors_existing_manifest(tmp_path):
    """HerculeDB.create on an existing database adopts the on-disk
    manifest — the files were laid out under *that* ncf — instead of
    silently returning a handle with the requested parameters."""
    from repro.hercule.database import HerculeDB
    HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=4).close()
    again = HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=1)
    assert again.ncf == 4
    again.close()


# --------------------------------------------------- shm slab lifecycle

def test_shm_slab_reclamation_on_release_and_close():
    area = ShmStagingArea(capacity=2, policy="block", n_slots=3)
    consumer = ShmStagingArea.attach(area.handle())

    assert area.push(1, {"a": np.arange(64.0)})
    assert area.push(2, {"a": np.arange(64.0) * 2})
    assert len(area) == 2
    snap = consumer.pop(timeout=1.0)
    np.testing.assert_array_equal(snap.arrays["a"], np.arange(64.0))

    # release() returns the slab to the ring: the same slot (same shm
    # segment generation) is reused by the next push, no new allocation
    allocs_before = area.stats.buffer_allocs
    consumer.release(snap)
    assert snap._slot is None            # double-release is a no-op
    assert area.push(3, {"a": np.arange(64.0) * 3})
    assert area.stats.buffer_allocs == allocs_before
    assert area.stats.buffer_reuses >= 1

    # growth: an oversized snapshot rolls the slab to a new generation
    for _ in range(2):
        consumer.release(consumer.pop(timeout=1.0))
    assert area.push(4, {"big": np.zeros(200_000)})
    big = consumer.pop(timeout=1.0)
    assert big.arrays["big"].nbytes == 1_600_000
    consumer.release(big)

    # close() + unlink() reclaim every named segment
    names = [area._data_name(slot, gen)
             for slot, (gen, _) in area._segs.items()]
    names.append(area._shm.name)
    area.close()
    assert consumer.pop(timeout=0.5) is None and consumer.closed
    consumer.detach()
    area.unlink()
    for name in names:
        with pytest.raises(FileNotFoundError):
            _attach_shm(name)


def test_shm_shared_stride_controller_multi_producer():
    """Subsample stride state lives in the segment's control words: every
    bound producer sees one consistent stride, and overflow driven from
    one binding moves the stride observed through another."""
    from repro.insitu.staging import SharedStrideController

    area = ShmStagingArea(capacity=2, policy="subsample", n_slots=3)
    peer = ShmStagingArea.attach(area.handle())
    assert isinstance(area._ctrl, SharedStrideController)
    assert area.stride == 1 and peer.stride == 1

    # sustained overflow through the *peer* binding raises the stride
    # the owner observes (one shared controller, not one per producer)
    for _ in range(64):
        peer._ctrl.overflow()
    assert peer.stride > 1
    assert area.stride == peer.stride

    # the shared decision function gates pushes identically on both ends
    stride = area.stride
    accepted = sum(bool(area.push(s, {"a": np.zeros(4)}))
                   for s in range(stride * 2))
    assert accepted == 2          # one admit per stride cycle, 2 cycles

    # freeze() on detach keeps a coherent host-side copy after unlink
    peer.detach()
    assert peer.stride == stride
    while len(area):
        area.release(area.pop(timeout=1.0))
    area.close()
    area.unlink()
    assert area.stride == stride  # frozen plain controller survives


def test_shm_area_policies_match_thread_semantics():
    """drop-oldest keeps the freshest snapshots; victims fire on_evict."""
    evicted = []
    area = ShmStagingArea(capacity=2, policy="drop-oldest", n_slots=3,
                          on_evict=evicted.append)
    for s in range(1, 6):
        assert area.push(s, {"a": np.full(4, float(s))})
    assert len(area) == 2
    assert area.stats.evicted == 3
    assert [v.step for v in evicted] == [1, 2, 3]
    got = [area.pop(timeout=1.0), area.pop(timeout=1.0)]
    assert [g.step for g in got] == [4, 5]
    np.testing.assert_array_equal(got[1].arrays["a"], np.full(4, 5.0))
    for g in got:
        area.release(g)
    area.close()
    area.unlink()


# ------------------------------------------------- TTL partial contexts

def test_step_ttl_finalizes_partial_context(tmp_path, sedov_tree):
    """A producer skipping an on-cadence step no longer leaks the
    pending context: after step_ttl the context commits with the
    surviving domains only (same path as drop-oldest eviction)."""
    eng = InTransitEngine(str(tmp_path / "db"), _reducers(), domains=2,
                          step_ttl=0.25).start()
    parts = partition_snapshot(sedov_tree.to_arrays(), "amr", 2)
    assert eng.submit_part(1, 0, parts[0])   # producer 1 never shows up
    eng.drain(timeout=15.0)
    assert eng.ttl_expired_steps == 1
    # a healthy step afterwards still completes with both domains
    assert eng.submit_part(2, 0, parts[0])
    assert eng.submit_part(2, 1, parts[1])
    eng.close()

    cat = Catalog(str(tmp_path / "db"))
    assert cat.steps() == [1, 2]
    assert cat.attrs(1)["insitu"]["domains"] == [0]
    assert cat.attrs(2)["insitu"]["domains"] == [0, 1]
    # the partial context serves its surviving domain transparently
    hist = cat.query(1, "hist-density-b16-lo0-hi8")["hist"]
    part = cat.query(1, "hist-density-b16-lo0-hi8", domain=0)["hist"]
    np.testing.assert_array_equal(hist, part)
    cat.close()


def test_step_ttl_late_straggler_cannot_overwrite_manifest(tmp_path,
                                                           sedov_tree):
    """A part arriving after its step's context TTL-committed is
    rejected: a lone straggler restarting the countdown would commit a
    manifest holding only its own domain over the survivors'."""
    eng = InTransitEngine(str(tmp_path / "db"), _reducers(), domains=2,
                          step_ttl=0.25).start()
    parts = partition_snapshot(sedov_tree.to_arrays(), "amr", 2)
    assert eng.submit_part(1, 0, parts[0])
    eng.drain(timeout=15.0)            # TTL commits with domains=[0]
    assert eng.submit_part(1, 1, parts[1]) is False   # straggler rejected
    eng.close()
    cat = Catalog(str(tmp_path / "db"))
    assert cat.attrs(1)["insitu"]["domains"] == [0]   # manifest intact
    cat.close()


def test_step_ttl_all_parts_skipped_leaves_no_context(tmp_path):
    """TTL on a step where nothing landed: no empty context litter."""
    eng = InTransitEngine(str(tmp_path / "db"),
                          [LevelHistogramReducer()], domains=2,
                          step_ttl=0.2).start()
    # a part of an unknown kind settles as 'skipped'; the other producer
    # never submits -> countdown completes via TTL with ctx=None
    assert eng.submit_part(1, 0, {"x": np.zeros(8)}, kind="tensors")
    eng.drain(timeout=15.0)
    eng.close()
    assert eng.ttl_expired_steps == 1
    assert Catalog(str(tmp_path / "db")).steps() == []


# --------------------------------------------- remote catalog round trip

def test_remote_catalog_round_trip(tmp_path, sedov_tree):
    """RemoteCatalog over a live ephemeral-port server returns arrays
    equal to the local merge-at-read for a 2-domain run."""
    root = str(tmp_path / "db")
    eng = InTransitEngine(root, _reducers(), domains=2).start()
    for s in (1, 2, 3):
        assert eng.submit(s, sedov_tree)
    eng.close()

    local = Catalog(root)
    srv = CatalogServer(local, port=0).start()
    try:
        rc = RemoteCatalog(srv.url)
        assert rc.steps() == local.steps() == [1, 2, 3]
        assert rc.latest_step() == 3
        assert rc.reducers(3) == local.reducers(3)
        assert rc.attrs(3)["insitu"]["domains"] == [0, 1]

        for reducer in rc.reducers(3):
            assert rc.domains(3, reducer) == local.domains(3, reducer)
            remote = rc.query(3, reducer)        # server-side merge
            ref = local.query(3, reducer)
            assert set(remote) == set(ref)
            for k, v in ref.items():
                assert remote[k].dtype == v.dtype
                assert np.array_equal(v, remote[k], equal_nan=True), \
                    (reducer, k)
            one = rc.query(3, reducer, domain=1)  # concrete domain part
            for k, v in local.query(3, reducer, domain=1).items():
                assert np.array_equal(v, one[k], equal_nan=True)

        # region crops are applied server-side on the cached object
        slicer = next(r for r in rc.reducers(3) if r.startswith("slice"))
        win = rc.query(3, slicer, region=((8, 24), (4, 20)))["image"]
        np.testing.assert_array_equal(
            win, local.query(3, slicer)["image"][8:24, 4:20])

        # series mirrors Catalog.series (steps + per-step arrays)
        st, vals = rc.series(slicer, "image")
        lst, lvals = local.series(slicer, "image")
        np.testing.assert_array_equal(st, lst)
        assert all(np.array_equal(a, b, equal_nan=True)
                   for a, b in zip(vals, lvals))

        # many viewers, one cache: this viewer's repeated query now
        # revalidates client-side (304, zero payload)...
        before_etag = rc.client_cache_info()["etag_hits"]
        rc.query(3, slicer)
        assert rc.client_cache_info()["etag_hits"] > before_etag
        # ...while a *fresh* viewer (empty ETag cache) still shares the
        # server's LRU reduction cache
        before = rc.cache_info()
        RemoteCatalog(srv.url).query(3, slicer)
        after = rc.cache_info()
        assert after["hits"] > before["hits"]

        # a missing object raises KeyError exactly like the local catalog
        with pytest.raises(KeyError):
            rc.query(3, "absent-reducer")
        with pytest.raises(KeyError):
            rc.reducers(99)
    finally:
        srv.close()
        local.close()


# ------------------------------------------------------ reducer pickling

def test_jitted_reducers_pickle_for_process_lanes():
    """Process lanes ship reducers to spawned workers: the jitted
    closures drop out of the pickle and rebuild on arrival."""
    r = TensorNormReducer()
    clone = pickle.loads(pickle.dumps(r))
    assert clone.name == r.name and clone.merge == r.merge
    from repro.insitu.staging import Snapshot
    snap = Snapshot(step=0, kind="tensors",
                    arrays={"w": np.arange(12.0).reshape(3, 4)})
    out = clone.reduce(snap, {})
    np.testing.assert_allclose(
        out["stats"][0, 0], np.linalg.norm(np.arange(12.0)), rtol=1e-6)


def test_drain_timeout_still_raises(tmp_path):
    """Without a TTL, a skipped producer surfaces as a drain timeout
    (the PR-3 contract) rather than silently committing."""
    eng = InTransitEngine(str(tmp_path / "db"), _reducers(), domains=2).start()
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=3,
                             threshold=1.2)
    parts = partition_snapshot(t.to_arrays(), "amr", 2)
    assert eng.submit_part(1, 0, parts[0])
    time.sleep(0.1)
    with pytest.raises(TimeoutError):
        eng.drain(timeout=0.5)
    # the missing part arrives late: everything completes after all
    assert eng.submit_part(1, 1, parts[1])
    eng.close()
    assert eng.written_steps == [1]


# --------------------------------------------------- persistent lane pool

def test_lane_pool_reuses_spawned_lanes(tmp_path, sedov_tree):
    """lane_pool=True: a second engine borrows the first engine's lane
    processes (same PIDs) instead of paying spawn+import again, and the
    reduced catalogs come out correct both times."""
    from repro.insitu import shutdown_pool
    from repro.insitu.lanes import LANE_POOL
    pids = []
    try:
        for i in range(2):
            root = str(tmp_path / f"db{i}")
            eng = InTransitEngine(root, _reducers(), domains=2,
                                  backend="process", lane_pool=True,
                                  ncf=1).start()
            assert eng.submit(1, sedov_tree)
            eng.close()
            pids.append(tuple(p.pid for p in eng._backend._procs))
            cat = Catalog(root)
            assert cat.steps() == [1]
            assert cat.domains(1, _reducers()[2].name) == [0, 1]
            cat.close()
        assert pids[0] == pids[1]           # lanes actually reused
        assert 2 in LANE_POOL._free and LANE_POOL._free[2]
    finally:
        shutdown_pool()
    assert not LANE_POOL._free


# ------------------------------------------------- server auth + ETag

def _insitu_db(tmp_path, sedov_tree):
    root = str(tmp_path / "srvdb")
    eng = InTransitEngine(root, _reducers(), domains=2).start()
    for s in (1, 2):
        eng.submit(s, sedov_tree)
    eng.close()
    return root


def test_server_bearer_token_auth(tmp_path, sedov_tree):
    """--token mode: requests without the exact bearer token get 401
    (PermissionError client-side); the right token is served normally."""
    root = _insitu_db(tmp_path, sedov_tree)
    srv = CatalogServer(root, port=0, token="s3cret").start()
    try:
        with pytest.raises(PermissionError):
            RemoteCatalog(srv.url).steps()
        with pytest.raises(PermissionError):
            RemoteCatalog(srv.url, token="wrong").steps()
        rc = RemoteCatalog(srv.url, token="s3cret")
        assert rc.steps() == [1, 2]
        assert rc.query(1, _reducers()[2].name)["image"].shape == (48, 48)
    finally:
        srv.close()


def test_remote_catalog_etag_cache(tmp_path, sedov_tree):
    """Hot viewers skip the transfer: a repeated query revalidates via
    If-None-Match, gets a 304, and serves the cached arrays."""
    root = _insitu_db(tmp_path, sedov_tree)
    srv = CatalogServer(root, port=0).start()
    try:
        rc = RemoteCatalog(srv.url)
        name = _reducers()[2].name
        first = rc.query(1, name)
        assert rc.client_cache_info() == {"entries": 1, "etag_hits": 0,
                                          "etag_misses": 1}
        again = rc.query(1, name)
        info = rc.client_cache_info()
        assert info["etag_hits"] == 1 and info["etag_misses"] == 1
        np.testing.assert_array_equal(first["image"], again["image"])
        # cached arrays are frozen like the local catalog's
        with pytest.raises(ValueError):
            again["image"][0, 0] = 1.0
        # distinct (region/domain) keys are separate cache entries
        crop = rc.query(1, name, region=((0, 8), (0, 8)))
        assert crop["image"].shape == (8, 8)
        dom = rc.query(1, name, domain=0)
        assert rc.client_cache_info()["entries"] == 3
        np.testing.assert_array_equal(crop["image"], first["image"][:8, :8])
        # and revalidation still matches a fresh unconditional fetch
        fresh = RemoteCatalog(srv.url).query(1, name, domain=0)
        np.testing.assert_array_equal(dom["image"], fresh["image"])
    finally:
        srv.close()


def test_etag_rotates_and_cache_invalidates_on_context_rewrite(tmp_path,
                                                               sedov_tree):
    """A rewritten context (engine resubmission) must rotate the ETag
    AND drop the server's cached bytes — a fresh validator stamped onto
    stale LRU content would poison every client forever."""
    from repro.hercule import api
    from repro.hercule.database import HerculeDB
    root = str(tmp_path / "db")
    db = HerculeDB.create(root, kind="hdep", ncf=1)
    ctx = db.begin_context(1)
    api.write_object(ctx, "reduced", 0, {"x": np.zeros(8)}, reducer="red")
    ctx.finalize(attrs={"insitu": {"reducers": ["red"], "merge": {},
                                   "n_domains": 1, "domains": [0]}})
    srv = CatalogServer(root, port=0).start()
    try:
        rc = RemoteCatalog(srv.url)
        np.testing.assert_array_equal(rc.query(1, "red")["x"], np.zeros(8))
        # rewrite step 1 with different bytes (and a changed manifest)
        time.sleep(0.01)          # ensure a distinct mtime_ns
        ctx = db.begin_context(1)
        api.write_object(ctx, "reduced", 0, {"x": np.ones(8)},
                         reducer="red")
        ctx.finalize(attrs={"insitu": {"reducers": ["red"], "merge": {},
                                       "n_domains": 1, "domains": [0]}})
        # revalidation must MISS (rotated tag) and serve the new bytes
        out = rc.query(1, "red")["x"]
        np.testing.assert_array_equal(out, np.ones(8))
        assert rc.client_cache_info()["etag_misses"] == 2
        # and the fresh tag now revalidates to the fresh bytes
        np.testing.assert_array_equal(rc.query(1, "red")["x"], np.ones(8))
        assert rc.client_cache_info()["etag_hits"] == 1
    finally:
        srv.close()
        db.close()
