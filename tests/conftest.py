import os
import sys

# tests see the default single CPU device (the dry-run sets its own flags
# in a subprocess); keep any user flags out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: makes the benchmarks package importable (diff_records tests)
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))
