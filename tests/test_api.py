"""Unified Hercule object API: Selector semantics, indexed ContextView
reads, the codec and ObjectKind registries, scan, and the deprecation
shims over the legacy hdep free functions."""
import os

import numpy as np
import pytest

from repro.hercule import HerculeDB, api
from repro.hercule.database import codec_names, decode_record, get_codec


@pytest.fixture()
def db(tmp_path):
    return HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=2)


def _write_step(db, step, *, domains=(0, 1)):
    ctx = db.begin_context(step)
    for d in domains:
        ctx.write_array(d, "analysis/w", np.full((4, 4), step + d, np.float32))
        ctx.write_array(d, "reduced/slice/image", np.full(8, 10 * step + d))
    ctx.write_array(0, "['params']['w']", np.arange(6.0))
    ctx.finalize(attrs={"step": step})
    return ctx


# ---------------------------------------------------------------- selector

def test_selector_name_globs_and_exact():
    sel = api.Selector(names="reduced/*/image")
    assert sel.match_name("reduced/slice/image")
    assert not sel.match_name("analysis/w")
    # no glob chars -> exact match; brackets are NOT character classes
    sel = api.Selector(names="['params']['w']")
    assert sel.match_name("['params']['w']")
    assert not sel.match_name("p")  # fnmatch would match a char class

    multi = api.Selector(names=["analysis/*", "amr/refine"])
    assert multi.match_name("analysis/w")
    assert multi.match_name("amr/refine")
    assert not multi.match_name("amr/owner")


def test_selector_glob_with_brackets_stays_literal():
    """Globbing honors only * and ? — brackets never become char classes."""
    sel = api.Selector(names="analysis/['dense']*")
    assert sel.match_name("analysis/['dense']['w']")
    assert sel.match_name("analysis/['dense']['b']")
    assert not sel.match_name("analysis/['conv']['w']")
    assert api.Selector(names="['params']*").match_name("['params']['w']")


def test_catalog_series_exact_names(tmp_path):
    from repro.insitu import Catalog
    db = HerculeDB.create(str(tmp_path / "cat"), kind="hdep", ncf=2)
    for s in (1, 2, 4):
        ctx = db.begin_context(s)
        api.write_object(ctx, "reduced", 0, {"v": np.array([float(s)])},
                         reducer="my[red]")  # brackets + globbable chars ok
        ctx.finalize()
    cat = Catalog(db)
    steps, vals = cat.series("my[red]", "v")
    np.testing.assert_array_equal(steps, [1, 2, 4])
    assert [float(v[0]) for v in vals] == [1.0, 2.0, 4.0]
    steps, _ = cat.series("my[red]", "v", steps=[2, 4])
    np.testing.assert_array_equal(steps, [2, 4])
    steps, _ = cat.series("other", "v")
    assert steps.size == 0


def test_selector_steps_domains_kinds():
    assert api.Selector(steps=range(0, 10, 2)).match_step(4)
    assert not api.Selector(steps=range(0, 10, 2)).match_step(5)
    assert api.Selector(steps=7).match_step(7)
    assert api.Selector(steps=[1, 3]).match_step(3)
    assert api.Selector().match_step(123)

    rec_a = api.Record(name="analysis/w", domain=1, file="f", offset=0,
                       nbytes=4, dtype="float32", shape=(1,))
    rec_c = api.Record(name="['params']['w']", domain=0, file="f", offset=0,
                       nbytes=4, dtype="float32", shape=(1,))
    assert api.Selector(kinds="analysis").match(rec_a)
    assert not api.Selector(kinds="analysis").match(rec_c)
    assert api.Selector(kinds=("ckpt_shard",)).match(rec_c)
    assert not api.Selector(domains=0).match(rec_a)
    with pytest.raises(ValueError, match="unknown object kind"):
        api.Selector(kinds="nope")


def test_kind_of_classification():
    assert api.kind_of("amr/refine").name == "amr_tree"
    assert api.kind_of("amr/field/density").name == "amr_tree"
    assert api.kind_of("analysis/layer0.w").name == "analysis"
    assert api.kind_of("reduced/slice256/image").name == "reduced"
    assert api.kind_of("['params']['w']").name == "ckpt_shard"  # fallback
    assert api.REDUCED.parse("reduced/slice256/image") == \
        {"reducer": "slice256", "array": "image"}


# ------------------------------------------------------------ context view

def test_view_indexed_point_reads(db):
    _write_step(db, 3)
    view = db.view(3)
    assert view is db.view(3)          # cached, parsed once
    assert len(view) == 5
    np.testing.assert_array_equal(view.read(1, "analysis/w"),
                                  np.full((4, 4), 4, np.float32))
    # db.read routes through the same view
    np.testing.assert_array_equal(db.read(3, 1, "analysis/w"),
                                  view.read(1, "analysis/w"))
    with pytest.raises(KeyError, match="not in context 3"):
        view.read(9, "analysis/w")
    assert view.domains() == [0, 1]
    assert view.domains("['params']['w']") == [0]
    assert set(view.kinds()) == {"analysis", "reduced", "ckpt_shard"}
    assert view.attrs["step"] == 3


def test_view_batched_and_merged_reads(db):
    _write_step(db, 1)
    view = db.view(1)
    got = view.read_many([(0, "analysis/w"), (1, "analysis/w")])
    assert set(got) == {(0, "analysis/w"), (1, "analysis/w")}
    np.testing.assert_array_equal(got[(1, "analysis/w")],
                                  np.full((4, 4), 2, np.float32))
    # selector form
    got = view.read_many(names="reduced/slice/image")
    assert set(got) == {(0, "reduced/slice/image"), (1, "reduced/slice/image")}
    # domain-merged read of one name across contributors
    merged = view.read_merged("analysis/w")
    assert sorted(merged) == [0, 1]
    np.testing.assert_array_equal(merged[0], np.full((4, 4), 1, np.float32))


def test_view_select(db):
    _write_step(db, 2)
    view = db.view(2)
    assert len(view.select()) == 5
    assert [r.name for r in view.select(names="['params']['w']")] == \
        ["['params']['w']"]
    assert len(view.select(domains=1)) == 2
    assert len(view.select(kinds="reduced")) == 2
    assert len(view.select(names="reduced/*", domains=0)) == 1


def test_scan_across_contexts(db):
    for s in (1, 2, 3, 4):
        _write_step(db, s)
    refs = list(api.scan(db, steps=range(2, 5), names="reduced/*/image",
                         domains=0))
    assert [r.step for r in refs] == [2, 3, 4]
    assert all(r.kind == "reduced" for r in refs)
    np.testing.assert_array_equal(refs[0].read(), np.full(8, 20))


# -------------------------------------------------------------- object API

def test_amr_tree_kind_roundtrip(tmp_path):
    from repro.core import decompose, prune
    from repro.sim import amrgen, fields
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=4,
                             threshold=1.2)
    dom = decompose.assign_domains(t, 2)
    lt = decompose.local_tree(t, dom, 1, coarse_level=1)
    pt = prune.prune(lt)
    db = HerculeDB.create(str(tmp_path / "amr"), kind="hdep", ncf=2)
    ctx = db.begin_context(0)
    api.write_object(ctx, "amr_tree", 1, pt)
    ctx.finalize()
    rt = api.read_object(db, 0, "amr_tree", 1)
    rt.validate()
    assert np.array_equal(rt.refine, pt.refine)
    assert np.array_equal(rt.coords, pt.coords)
    for f in pt.fields:
        assert np.array_equal(rt.fields[f], pt.fields[f]), f
    assert api.AMR_TREE.domains_in(db.view(0)) == [1]


def test_unknown_object_kind_raises(db):
    ctx = db.begin_context(0)
    with pytest.raises(ValueError, match="registered"):
        api.write_object(ctx, "nope", 0, {})
    ctx.abort()
    with pytest.raises(ValueError, match="registered"):
        api.read_object(db, 0, "nope")


def test_ckpt_shard_elastic_region_read(tmp_path):
    from repro.hercule.checkpoint import CheckpointManager
    full = np.arange(64, dtype=np.float32).reshape(8, 8)
    mgr = CheckpointManager(str(tmp_path / "ck"), ncf=2, async_write=False)
    mgr.save(1, {"w": full})
    view = mgr.db.view(1)
    name = api.CKPT_SHARD.shards(view, "['w']")[0].name
    region = api.CKPT_SHARD.read_region(view, name,
                                        [slice(2, 6), slice(1, 4)])
    np.testing.assert_array_equal(region, full[2:6, 1:4])
    mgr.close()


# ---------------------------------------------------------- codec registry

def test_every_registered_codec_roundtrips_through_view(tmp_path):
    rng = np.random.default_rng(0)
    base = rng.standard_normal((16, 16)).astype(np.float32)
    nxt = base + rng.standard_normal((16, 16)).astype(np.float32) * 1e-3
    bits = rng.random(300) < 0.2

    db = HerculeDB.create(str(tmp_path / "cod"), kind="hdep", ncf=2)
    ctx = db.begin_context(0)
    cases = {"raw": base, "boolrle": bits, "fpdelta-pyramid": base,
             "pyramid": base}
    for cname, arr in cases.items():
        payload, meta = get_codec(cname).encode(arr)
        ctx.write_bytes(0, f"x/{cname}", payload, dtype=str(arr.dtype),
                        shape=arr.shape, codec=cname, meta=meta)
    # the delta codec predicts from the same record in an earlier context
    payload, meta = get_codec("raw").encode(base)
    ctx.write_bytes(0, "x/fpdelta-delta", payload, dtype=str(base.dtype),
                    shape=base.shape, codec="raw", meta=meta)
    ctx.finalize()
    ctx = db.begin_context(1)
    payload, meta = get_codec("fpdelta-delta").encode(nxt, prev=base)
    ctx.write_bytes(0, "x/fpdelta-delta", payload, dtype=str(nxt.dtype),
                    shape=nxt.shape, codec="fpdelta-delta",
                    meta={**meta, "pred_step": 0})
    ctx.finalize()

    view = db.view(0)
    for cname, arr in cases.items():
        np.testing.assert_array_equal(view.read(0, f"x/{cname}"), arr, err_msg=cname)
    np.testing.assert_array_equal(db.view(1).read(0, "x/fpdelta-delta"), nxt)

    # coverage guard: every codec that can round-trip standalone was tested
    roundtrippable = {n for n in codec_names()
                     if get_codec(n).encode is not None
                     and get_codec(n).decode is not None}
    assert roundtrippable == set(cases) | {"fpdelta-delta"}


def test_unknown_codec_error_lists_known(db):
    ctx = db.begin_context(0)
    ctx.write_bytes(0, "x", b"\x00" * 8, dtype="float64", shape=(1,),
                    codec="zstd-9000")
    ctx.finalize()
    with pytest.raises(ValueError) as ei:
        db.view(0).read(0, "x")
    msg = str(ei.value)
    assert "zstd-9000" in msg
    for known in ("raw", "boolrle", "fpdelta-pyramid", "fpdelta-delta"):
        assert known in msg, msg


def test_tree_codec_requires_kind_assembly(tmp_path):
    """fpdelta-tree records are registered but only kind-decodable."""
    from repro.sim import amrgen, fields
    t = amrgen.generate_tree(fields.sedov(), min_level=2, max_level=3,
                             threshold=1.2)
    db = HerculeDB.create(str(tmp_path / "tr"), kind="hdep", ncf=1)
    ctx = db.begin_context(0)
    api.write_object(ctx, "amr_tree", 0, t)
    ctx.finalize()
    rec = db.view(0).record(0, "amr/field/density")
    assert rec.codec == "fpdelta-tree"
    with pytest.raises(ValueError, match="object kind"):
        decode_record(db, rec)
    # while the kind assembles it fine
    rt = api.read_object(db, 0, "amr_tree", 0)
    np.testing.assert_array_equal(rt.fields["density"], t.fields["density"])


# ------------------------------------------------------- database hygiene

def test_contexts_skips_stray_dirs(db):
    _write_step(db, 4)
    os.makedirs(os.path.join(db.root, "ctx_notastep"))
    os.makedirs(os.path.join(db.root, "ctx_00000004_backup"))
    os.makedirs(os.path.join(db.root, "ctx_00000009"))  # no MANIFEST: invisible
    assert db.contexts() == [4]
    assert db.latest_context() == 4


# ------------------------------------------------------- deprecation shims

def test_hdep_shims_removed():
    """DESIGN.md §11 countdown completed: the legacy free functions are
    gone; the module survives only as a pointer at the unified API."""
    from repro.hercule import hdep
    for name in ("write_domain_tree", "read_domain_tree", "domains_in",
                 "write_analysis", "read_analysis", "write_reduced",
                 "read_reduced", "reducers_in"):
        assert not hasattr(hdep, name), f"shim {name} still present"
    assert "repro.hercule.api" in (hdep.__doc__ or "")
