"""Elastic restore across *different* shard layouts (checkpoint.py claim).

Saves a sharded state on a 4-device mesh, then restores it on 2- and
8-device meshes. Each phase runs in a subprocess because the forced host
device count must be set before jax initializes.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SAVE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.hercule.checkpoint import CheckpointManager

mesh = Mesh(np.array(jax.devices()).reshape({ndev}), ("d",))
sh = NamedSharding(mesh, P("d"))
state = {{
    "w": jax.device_put(jnp.arange(64 * 8, dtype=jnp.float32
                                   ).reshape(64, 8), sh),
    "b": jax.device_put(jnp.arange(128, dtype=jnp.float32) / 128.0, sh),
    "step": jnp.int32(7),
}}
mgr = CheckpointManager("{root}", ncf=2, async_write=False)
mgr.save(1, state)
mgr.close()
print("SAVED", len(mgr.db.records(1, name="['w']")))
"""

_RESTORE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.hercule.checkpoint import CheckpointManager

mesh = Mesh(np.array(jax.devices()).reshape({ndev}), ("d",))
sh = NamedSharding(mesh, P("d"))
template = {{
    "w": jax.ShapeDtypeStruct((64, 8), jnp.float32, sharding=sh),
    "b": jax.ShapeDtypeStruct((128,), jnp.float32, sharding=sh),
    "step": jax.ShapeDtypeStruct((), jnp.int32,
        sharding=jax.sharding.SingleDeviceSharding(jax.devices()[0])),
}}
mgr = CheckpointManager("{root}", ncf=2, async_write=False)
got, _ = mgr.restore(template, step=1)
assert got["w"].sharding.num_devices == {ndev}, got["w"].sharding
np.testing.assert_array_equal(
    np.asarray(got["w"]),
    np.arange(64 * 8, dtype=np.float32).reshape(64, 8))
np.testing.assert_array_equal(
    np.asarray(got["b"]), np.arange(128, dtype=np.float32) / 128.0)
assert int(got["step"]) == 7
print("RESTORED-OK", {ndev})
"""


def _run(code: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, "-c", code],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=300)


@pytest.mark.parametrize("restore_ndev", [2, 8])
def test_restore_onto_different_shard_layout(tmp_path, restore_ndev):
    root = str(tmp_path / "ckpt")
    out = _run(_SAVE_SNIPPET.format(ndev=4, root=root))
    assert out.returncode == 0, out.stderr[-3000:]
    # ownership pruning: 4 distinct shards of w were written, one each
    assert "SAVED 4" in out.stdout, out.stdout
    out = _run(_RESTORE_SNIPPET.format(ndev=restore_ndev, root=root))
    assert out.returncode == 0, out.stderr[-3000:]
    assert f"RESTORED-OK {restore_ndev}" in out.stdout
