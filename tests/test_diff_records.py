"""Bench-trajectory diff tool (benchmarks.diff_records).

The unit classifier decides regression direction: cost units (µs,
bytes, pct, frac) warn when the value goes *up*, benefit units (x,
ratio, speedup, qps) warn when it goes *down*. The match is on the
unit's last ``_`` token — ``bytes_per_step_max`` ends with ``x`` but is
a cost, and ``frac`` is a cost; both were previously misclassified by a
suffix match.
"""
import json

import pytest

from benchmarks.diff_records import _is_benefit, diff, load_records, main


def _rec(name, value, unit="us_per_call"):
    return {"name": name, "value": value, "unit": unit}


@pytest.mark.parametrize("unit,benefit", [
    ("x", True), ("ratio", True), ("speedup", True), ("qps", True),
    ("us_per_call", False), ("bytes_per_step", False), ("pct", False),
    ("ms", False),
    # the token-vs-suffix distinction this classifier exists for:
    ("frac", False),                 # residency fraction: lower = better
    ("bytes_per_step_max", False),   # ends with "x" but is a cost
    ("latency_max", False),
    ("mesh_vs_single_x", True),      # last token exactly "x"
    ("write_qps", True),
])
def test_unit_classification(unit, benefit):
    assert _is_benefit(_rec("r", 1.0, unit)) is benefit


def test_cost_regression_warns_on_increase():
    old = {"a": _rec("a", 100.0)}
    new = {"a": _rec("a", 150.0)}
    _, warnings = diff(old, new, warn_pct=20.0)
    assert len(warnings) == 1 and "a:" in warnings[0]
    _, warnings = diff(new, old, warn_pct=20.0)   # got faster: no warning
    assert not warnings


def test_benefit_regression_warns_on_decrease():
    old = {"a": _rec("a", 10.0, unit="x")}
    new = {"a": _rec("a", 5.0, unit="x")}
    _, warnings = diff(old, new, warn_pct=20.0)
    assert len(warnings) == 1
    _, warnings = diff(new, old, warn_pct=20.0)   # ratio improved
    assert not warnings


def test_frac_increase_is_a_regression():
    """Higher per-device residency fraction must warn (it would not
    under the old suffix rule only because 'frac' lacks an 'x' — but a
    hypothetical benefit match would invert the direction)."""
    old = {"f": _rec("f", 0.25, unit="frac")}
    new = {"f": _rec("f", 0.55, unit="frac")}
    _, warnings = diff(old, new, warn_pct=20.0)
    assert len(warnings) == 1


def test_max_suffixed_cost_unit_warns_in_cost_direction():
    old = {"m": _rec("m", 100.0, unit="bytes_per_step_max")}
    new = {"m": _rec("m", 200.0, unit="bytes_per_step_max")}
    _, warnings = diff(old, new, warn_pct=20.0)
    assert len(warnings) == 1, "cost unit ending in 'x' treated as benefit"


def test_added_removed_and_zero_baseline_never_warn():
    old = {"gone": _rec("gone", 5.0), "z": _rec("z", 0.0)}
    new = {"new": _rec("new", 7.0), "z": _rec("z", 100.0)}
    lines, warnings = diff(old, new, warn_pct=20.0)
    assert not warnings
    assert any("(new record)" in ln for ln in lines)
    assert any("removed" in ln for ln in lines)
    assert any("zero baseline" in ln for ln in lines)


def _write(path, records):
    path.write_text(json.dumps(
        {"schema": "bench-record/v1", "records": records}))
    return str(path)


def test_main_end_to_end(tmp_path, capsys):
    old = _write(tmp_path / "old.json",
                 [_rec("a", 100.0), _rec("r", 10.0, unit="x")])
    new = _write(tmp_path / "new.json",
                 [_rec("a", 300.0), _rec("r", 10.0, unit="x")])
    assert main([old, new]) == 0            # warnings don't fail by default
    assert "REGRESSION" in capsys.readouterr().out
    assert main([old, new, "--strict"]) == 1
    assert main([old, old, "--strict"]) == 0


def test_main_rejects_wrong_schema(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "nope", "records": []}))
    good = _write(tmp_path / "good.json", [_rec("a", 1.0)])
    assert main([str(bad), good]) == 2
    assert load_records(good)["a"]["value"] == 1.0
