"""Multi-domain in-transit reduction and merge-at-read.

Round-trips per merge strategy: contributor groups each reduce their
partition and write their own Hercule domain; ``ContextView.read_merged``
-based assembly must return exactly the single-domain reference. The
single-domain degenerate case must match PR 1/2 behavior bit-for-bit.
"""
import time

import numpy as np
import pytest

from repro.core.amr import AMRTree
from repro.hercule import HerculeDB, api
from repro.insitu import (Catalog, InTransitEngine, LevelHistogramReducer,
                          LODCutReducer, ProjectionReducer, Reducer,
                          SliceReducer, SpectraReducer, TensorNormReducer,
                          partition_snapshot)
from repro.sim import amrgen, fields


@pytest.fixture(scope="module")
def deep_tree():
    """A Sedov tree whose deepest level is occupied (LOD cuts cut)."""
    t = amrgen.generate_tree(fields.sedov(), min_level=3, max_level=6,
                             threshold=1.15, level_factor=1.05)
    t.validate()
    assert t.level_offsets[-1] > t.level_offsets[-2]
    return t


def _amr_reducers():
    return [LODCutReducer(max_level=4),
            SliceReducer(field="density", resolution=64),
            SliceReducer(field="density", resolution=32, source="lod4"),
            ProjectionReducer(field="density", resolution=64),
            LevelHistogramReducer(field="density", bins=16, lo=0.0, hi=5.0)]


def _reduce_all(root, tree, groups, reducers=None, **engine_kw):
    eng = InTransitEngine(str(root), reducers or _amr_reducers(),
                          domains=groups, policy="block", **engine_kw)
    eng.start()
    assert eng.submit(0, tree)
    eng.close()
    return Catalog(str(root))


# -------------------------------------------------------------- partition

def test_partition_covers_every_leaf_exactly_once(deep_tree):
    parts = [AMRTree.from_arrays(a) for a in
             partition_snapshot(deep_tree.to_arrays(), "amr", 3)]
    for p in parts:
        p.validate()
    owned = sum(int(((~p.refine) & p.owner).sum()) for p in parts)
    assert owned == deep_tree.n_leaves
    # owned leaves across groups are disjoint as (level, coords) cells
    seen = set()
    for p in parts:
        lv = p.levels()
        for i in np.flatnonzero((~p.refine) & p.owner):
            key = (int(lv[i]), *map(int, p.coords[i]))
            assert key not in seen
            seen.add(key)


def test_partition_tensors_stripes_names():
    arrays = {f"t{i}": np.full(3, i) for i in range(7)}
    parts = partition_snapshot(arrays, "tensors", 3)
    names = [sorted(p) for p in parts]
    assert sorted(n for ns in names for n in ns) == sorted(arrays)
    assert all(len(p) >= 2 for p in parts)


def test_partition_rejects_unpartitionable():
    with pytest.raises(ValueError, match="AMR tree"):
        partition_snapshot({"a": np.zeros(4)}, "amr", 2)
    with pytest.raises(ValueError, match="kind"):
        partition_snapshot({"a": np.zeros(4)}, "weird", 2)
    # one group is the identity for any kind: no partition, no copies
    arrays = {"a": np.zeros(4)}
    assert partition_snapshot(arrays, "weird", 1)[0]["a"] is arrays["a"]


# ------------------------------------------- merge-at-read per strategy

@pytest.fixture(scope="module")
def merged_catalogs(deep_tree, tmp_path_factory):
    base = tmp_path_factory.mktemp("md")
    return {g: _reduce_all(base / f"g{g}", deep_tree, g) for g in (1, 2, 4)}


@pytest.mark.parametrize("groups", [2, 4])
def test_slice_tile_merge_exact(merged_catalogs, groups):
    ref = merged_catalogs[1]
    cat = merged_catalogs[groups]
    for r in (n for n in ref.reducers(0) if n.startswith("slice-")):
        a, b = ref.query(0, r)["image"], cat.query(0, r)["image"]
        np.testing.assert_array_equal(a, b)
    assert len(cat.domains(0, "lod4")) == groups


@pytest.mark.parametrize("groups", [2, 4])
def test_hist_sum_merge_exact(merged_catalogs, groups):
    ref = merged_catalogs[1]
    cat = merged_catalogs[groups]
    name = next(n for n in ref.reducers(0) if n.startswith("hist-"))
    a, b = ref.query(0, name), cat.query(0, name)
    np.testing.assert_array_equal(a["hist"], b["hist"])
    np.testing.assert_array_equal(a["edges"], b["edges"])


@pytest.mark.parametrize("groups", [2, 4])
def test_projection_sum_merge(merged_catalogs, groups):
    ref = merged_catalogs[1]
    cat = merged_catalogs[groups]
    name = next(n for n in ref.reducers(0) if n.startswith("proj-"))
    np.testing.assert_allclose(cat.query(0, name)["image"],
                               ref.query(0, name)["image"], rtol=1e-12)


@pytest.mark.parametrize("groups", [2, 4])
def test_lod_assemble_merge_exact(merged_catalogs, groups):
    ref = merged_catalogs[1].query(0, "lod4")
    got = merged_catalogs[groups].query(0, "lod4")
    assert sorted(ref) == sorted(got)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])
    AMRTree.from_arrays(got).validate()


def test_hist_mismatched_edges_cannot_merge(tmp_path):
    """Per-partition auto bounds produce incompatible edges: merge must
    refuse rather than sum counts binned over different ranges."""
    db = HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=2)
    ctx = db.begin_context(0)
    for d, hi in ((0, 1.0), (1, 2.0)):
        api.write_object(ctx, "reduced", d,
                         {"hist": np.ones((2, 4), np.int64),
                          "edges": np.linspace(0.0, hi, 5)},
                         reducer="hist-auto")
    ctx.finalize()
    with pytest.raises(ValueError, match="fixed lo/hi"):
        api.read_object(db, 0, "reduced", None, reducer="hist-auto",
                        strategy="hist")


def test_tensor_concat_and_union_merge(tmp_path):
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    state = {"params": {f"l{i}/w": jnp.asarray(
        rng.standard_normal((12, 12)).astype(np.float32)) for i in range(5)}}
    objs = {}
    for g in (1, 2):
        eng = InTransitEngine(str(tmp_path / f"t{g}"),
                              [TensorNormReducer(), SpectraReducer(k=4)],
                              domains=g, policy="block").start()
        assert eng.submit_state(1, state)
        eng.close()
        cat = Catalog(str(tmp_path / f"t{g}"))
        objs[g] = {r: cat.query(1, r) for r in cat.reducers(1)}
    for r, ref in objs[1].items():
        for k, v in ref.items():
            assert objs[2][r][k].dtype == v.dtype
            np.testing.assert_array_equal(objs[2][r][k], v)


# -------------------------------------------------- degenerate + plumbing

def test_single_domain_merged_read_bit_for_bit(deep_tree, tmp_path):
    """G=1 engine output is PR 1/2-shaped; merged read is the identity."""
    cat = _reduce_all(tmp_path / "db", deep_tree, 1)
    view = cat.db.view(0)
    assert view.domains() == [0]                  # single-writer layout
    for r in cat.reducers(0):
        merged = api.read_object(cat.db, 0, "reduced", None, reducer=r)
        direct = api.read_object(cat.db, 0, "reduced", 0, reducer=r)
        assert sorted(merged) == sorted(direct)
        for k in merged:
            assert merged[k].dtype == direct[k].dtype
            np.testing.assert_array_equal(merged[k], direct[k])


def test_merge_strategy_resolution_errors(tmp_path):
    db = HerculeDB.create(str(tmp_path / "db"), kind="hdep", ncf=2)
    ctx = db.begin_context(5)
    for d in (0, 1):
        api.write_object(ctx, "reduced", d, {"x": np.full(4, d)},
                         reducer="anon")
    ctx.finalize()        # no insitu attrs: strategy is unresolvable
    with pytest.raises(ValueError, match="no merge strategy"):
        api.read_object(db, 5, "reduced", None, reducer="anon")
    with pytest.raises(ValueError, match="unknown merge strategy"):
        api.read_object(db, 5, "reduced", None, reducer="anon",
                        strategy="nope")
    out = api.read_object(db, 5, "reduced", None, reducer="anon",
                          strategy="sum")
    np.testing.assert_array_equal(out["x"], np.full(4, 1))
    # domain restriction: a single selected domain needs no strategy
    out = api.read_object(db, 5, "reduced", None, reducer="anon",
                          domains=[1])
    np.testing.assert_array_equal(out["x"], np.full(4, 1))


def test_engine_attrs_record_merge_map(deep_tree, tmp_path):
    cat = _reduce_all(tmp_path / "db", deep_tree, 2)
    att = cat.attrs(0)["insitu"]
    assert att["n_domains"] == 2 and att["domains"] == [0, 1]
    assert att["merge"]["lod4"] == "assemble"
    assert att["merge"][next(n for n in att["reducers"]
                             if n.startswith("slice-"))] == "tile"
    assert len(att["staging"]) == 2               # per-group stats


def test_multidomain_drop_oldest_partial_contexts(tmp_path):
    """Evicted parts must not wedge the countdown; surviving domains
    finalize and merged reads serve what landed."""
    class Slow(Reducer):
        name = "slow"
        kinds = ("tensors",)
        merge = "union"

        def reduce(self, snap, upstream):
            time.sleep(0.03)
            return {f"x{snap.domain}": np.array([float(snap.step)])}

    eng = InTransitEngine(str(tmp_path / "db"), [Slow()], domains=2,
                          queue_capacity=1, policy="drop-oldest").start()
    n = 12
    for s in range(1, n + 1):
        eng.submit(s, {"a": np.zeros(16), "b": np.ones(8)}, kind="tensors")
    eng.close()
    cat = Catalog(str(tmp_path / "db"))
    steps = cat.steps()
    assert steps and steps[-1] == n           # freshest step always lands
    for s in steps:
        doms = cat.attrs(s)["insitu"]["domains"]
        obj = cat.query(s, "slow")
        assert sorted(obj) == [f"x{d}" for d in doms]
        for d in doms:
            assert obj[f"x{d}"][0] == s
