"""Prefill and single-token decode with per-family caches.

Caches are stacked along the layer axis and threaded through the layer
scan as xs/ys, so decode compiles as one layer body regardless of depth.

Cache shapes per family (C = cache capacity = min(window, max_seq)):
  attn/moe : {"k","v": (L, B, C, nkv, hd)}
  encdec   : + {"xk","xv": (L, B, F, nkv, hd)} (cross K/V, prefill-computed)
  ssm      : {"conv": (L, B, K-1, DI), "state": (L, B, H, P, N)}
  hybrid   : per-pattern-slot dicts stacked over macro blocks + tail.

``decode_step(..)`` is the `serve_step` lowered in the decode/long dry-run
cells; ``prefill(..)`` is the prefill cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention, layers, moe, rglru, ssm
from .transformer import LM, maybe_scan


def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def cache_capacity(cfg, max_seq: int) -> int:
    return min(cfg.window, max_seq) if cfg.window else max_seq


# ----------------------------------------------------------------- specs

def attn_cache_spec(cfg, batch: int, cap: int):
    nkv, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jax.ShapeDtypeStruct((batch, cap, nkv, hd), _cdt(cfg)),
            "v": jax.ShapeDtypeStruct((batch, cap, nkv, hd), _cdt(cfg))}


def attn_cache_axes():
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def _stack_spec(spec, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), spec)


def _stack_axes(axes, n=None):
    return jax.tree.map(lambda a: (None, *a), axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def cache_specs(lm: LM, batch: int, max_seq: int):
    """Abstract cache pytree + logical axes for a decode session."""
    cfg = lm.cfg
    cap = cache_capacity(cfg, max_seq)
    if cfg.block_pattern:
        per_block = {}
        per_axes = {}
        for i, k in enumerate(cfg.block_pattern):
            if k == "rec":
                per_block[f"sub{i}_rec"] = rglru.rglru_cache_spec(cfg, batch)
                per_axes[f"sub{i}_rec"] = rglru.rglru_cache_axes()
            else:
                per_block[f"sub{i}_attn"] = attn_cache_spec(cfg, batch, cap)
                per_axes[f"sub{i}_attn"] = attn_cache_axes()
        spec = {"blocks": _stack_spec(per_block, lm.n_rep)}
        axes = {"blocks": _stack_axes(per_axes)}
        for i, k in enumerate(lm.tail_kinds):
            if k == "rec":
                spec[f"tail{i}"] = rglru.rglru_cache_spec(cfg, batch)
                axes[f"tail{i}"] = rglru.rglru_cache_axes()
            else:
                spec[f"tail{i}"] = attn_cache_spec(cfg, batch, cap)
                axes[f"tail{i}"] = attn_cache_axes()
        return spec, axes
    if cfg.family == "ssm":
        return (_stack_spec(ssm.ssm_cache_spec(cfg, batch), cfg.n_layers),
                _stack_axes(ssm.ssm_cache_axes()))
    spec = _stack_spec(attn_cache_spec(cfg, batch, cap), cfg.n_layers)
    axes = _stack_axes(attn_cache_axes())
    if cfg.family == "encdec":
        nkv, hd = cfg.n_kv_heads, cfg.hd
        cross = {"xk": jax.ShapeDtypeStruct(
                     (cfg.n_layers, batch, cfg.n_frames, nkv, hd), _cdt(cfg)),
                 "xv": jax.ShapeDtypeStruct(
                     (cfg.n_layers, batch, cfg.n_frames, nkv, hd), _cdt(cfg))}
        spec = {**spec, **cross}
        axes = {**axes,
                "xk": (None, "batch", "frames", "kv_heads", "head_dim"),
                "xv": (None, "batch", "frames", "kv_heads", "head_dim")}
    return spec, axes


def _seed_attn_cache(k, v, cap: int, window: int | None):
    """(B,S,nkv,hd) prefill K/V -> (B,cap,nkv,hd) cache (ring for window)."""
    b, s, nkv, hd = k.shape
    if s == cap:
        return k, v
    if s > cap:  # windowed: keep last `cap`, placed at slot pos%cap
        kw, vw = k[:, s - cap:], v[:, s - cap:]
        roll = (s - cap) % cap
        return jnp.roll(kw, roll, axis=1), jnp.roll(vw, roll, axis=1)
    pad = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


# --------------------------------------------------------------- prefill

def prefill(lm: LM, params, tokens, *, extras=None, max_seq: int):
    """Process the prompt; returns (last-token logits, cache)."""
    cfg = lm.cfg
    extras = extras or {}
    b, s = tokens.shape
    cap = cache_capacity(cfg, max_seq)
    x = layers.embed(params["embed"], tokens, cfg)
    if cfg.family == "vlm" and "patch_embeds" in extras:
        pe = extras["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:, :]], axis=1)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    enc_out = enc_pos = None
    if cfg.family == "encdec":
        enc_out, enc_pos = lm._encode(params, extras["frames"])

    if cfg.block_pattern:
        x, cache = _prefill_hybrid(lm, params, x, positions, cap)
    elif cfg.family == "ssm":
        def body(h, lp):
            h1 = layers.apply_norm(lp["ln1"], h, cfg)
            y, c = ssm.ssm_block(lp["ssm"], h1, cfg)
            return h + y, c
        x, cache = maybe_scan(body, x, params["blocks"], unroll=cfg.unroll_layers)
    else:
        def body(h, lp):
            h1 = layers.apply_norm(lp["ln1"], h, cfg)
            att, (k, v) = attention.multihead(lp["attn"], h1, cfg=cfg,
                                              positions=positions,
                                              return_kv=True)
            h = h + att
            entry = dict(zip(("k", "v"), _seed_attn_cache(k, v, cap, cfg.window)))
            if cfg.family == "encdec":
                hx = layers.apply_norm(lp["lnx"], h, cfg)
                xatt, (xk, xv) = attention.multihead(
                    lp["xattn"], hx, cfg=cfg, positions=positions,
                    kv_x=enc_out, kv_positions=enc_pos, causal=False,
                    return_kv=True)
                h = h + xatt
                entry["xk"], entry["xv"] = xk, xv
            h2 = layers.apply_norm(lp["ln2"], h, cfg)
            if lm.kinds[0] == "moe":
                y, _ = moe.moe_mlp(lp["moe"], h2, cfg)
            else:
                y = layers.mlp(lp["mlp"], h2, cfg)
            return h + y, entry
        x, cache = maybe_scan(body, x, params["blocks"], unroll=cfg.unroll_layers)

    x = layers.apply_norm(params["final_norm"], x[:, -1:, :], cfg)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, cache


def _prefill_hybrid(lm: LM, params, x, positions, cap):
    cfg = lm.cfg

    def body(h, lp):
        caches = {}
        for i, k in enumerate(cfg.block_pattern):
            name = f"sub{i}_{k}"
            h1 = layers.apply_norm(lp[name]["ln1"], h, cfg)
            if k == "rec":
                y, c = rglru.rglru_block(lp[name]["rec"], h1, cfg)
                h = h + y
                caches[name] = c
            else:
                att, (kk, vv) = attention.multihead(
                    lp[name]["attn"], h1, cfg=cfg, positions=positions,
                    return_kv=True)
                h = h + att
                caches[name] = dict(zip(("k", "v"),
                                        _seed_attn_cache(kk, vv, cap, cfg.window)))
            h2 = layers.apply_norm(lp[name]["ln2"], h, cfg)
            h = h + layers.mlp(lp[name]["mlp"], h2, cfg)
        return h, caches
    x, blocks_cache = maybe_scan(body, x, params["blocks"], unroll=cfg.unroll_layers)
    cache = {"blocks": blocks_cache}
    for i, k in enumerate(lm.tail_kinds):
        lp = params[f"tail{i}"]
        h1 = layers.apply_norm(lp["ln1"], x, cfg)
        if k == "rec":
            y, c = rglru.rglru_block(lp["rec"], h1, cfg)
            x = x + y
            cache[f"tail{i}"] = c
        else:
            att, (kk, vv) = attention.multihead(lp["attn"], h1, cfg=cfg,
                                                positions=positions,
                                                return_kv=True)
            x = x + att
            cache[f"tail{i}"] = dict(zip(("k", "v"),
                                         _seed_attn_cache(kk, vv, cap, cfg.window)))
        h2 = layers.apply_norm(lp["ln2"], x, cfg)
        x = x + layers.mlp(lp["mlp"], h2, cfg)
    return x, cache


# ---------------------------------------------------------------- decode

def decode_step(lm: LM, params, token, pos, cache):
    """One decode step. token: (B,), pos: () int32 -> (logits (B,V), cache)."""
    cfg = lm.cfg
    x = layers.embed(params["embed"], token[:, None], cfg)

    if cfg.block_pattern:
        return _decode_hybrid(lm, params, x, pos, cache)

    if cfg.family == "ssm":
        def body(h, inp):
            lp, lc = inp
            h1 = layers.apply_norm(lp["ln1"], h, cfg)
            y, nc = ssm.ssm_block(lp["ssm"], h1, cfg, cache=lc)
            return h + y, nc
        x, new_cache = maybe_scan(body, x, (params["blocks"], cache), unroll=cfg.unroll_layers)
    else:
        def body(h, inp):
            lp, lc = inp
            h1 = layers.apply_norm(lp["ln1"], h, cfg)
            att, nk, nv = attention.decode_kv(lp["attn"], h1, cfg=cfg,
                                              cache_k=lc["k"], cache_v=lc["v"],
                                              pos=pos)
            h = h + att
            entry = {"k": nk, "v": nv}
            if cfg.family == "encdec":
                hx = layers.apply_norm(lp["lnx"], h, cfg)
                h = h + attention.decode_cross(lp["xattn"], hx, cfg=cfg,
                                               enc_k=lc["xk"], enc_v=lc["xv"])
                entry["xk"], entry["xv"] = lc["xk"], lc["xv"]
            h2 = layers.apply_norm(lp["ln2"], h, cfg)
            if lm.kinds[0] == "moe":
                y, _ = moe.moe_mlp(lp["moe"], h2, cfg)
            else:
                y = layers.mlp(lp["mlp"], h2, cfg)
            return h + y, entry
        x, new_cache = maybe_scan(body, x, (params["blocks"], cache), unroll=cfg.unroll_layers)

    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_cache


def _decode_hybrid(lm: LM, params, x, pos, cache):
    cfg = lm.cfg

    def body(h, inp):
        lp, lc = inp
        ncs = {}
        for i, k in enumerate(cfg.block_pattern):
            name = f"sub{i}_{k}"
            h1 = layers.apply_norm(lp[name]["ln1"], h, cfg)
            if k == "rec":
                y, nc = rglru.rglru_block(lp[name]["rec"], h1, cfg,
                                          cache=lc[name])
                h = h + y
            else:
                y, nk, nv = attention.decode_kv(lp[name]["attn"], h1, cfg=cfg,
                                                cache_k=lc[name]["k"],
                                                cache_v=lc[name]["v"], pos=pos)
                h = h + y
                nc = {"k": nk, "v": nv}
            ncs[name] = nc
            h2 = layers.apply_norm(lp[name]["ln2"], h, cfg)
            h = h + layers.mlp(lp[name]["mlp"], h2, cfg)
        return h, ncs
    x, blocks_cache = maybe_scan(body, x, (params["blocks"], cache["blocks"]),
                                 unroll=cfg.unroll_layers)
    new_cache = {"blocks": blocks_cache}
    for i, k in enumerate(lm.tail_kinds):
        lp = params[f"tail{i}"]
        lc = cache[f"tail{i}"]
        h1 = layers.apply_norm(lp["ln1"], x, cfg)
        if k == "rec":
            y, nc = rglru.rglru_block(lp["rec"], h1, cfg, cache=lc)
        else:
            y, nk, nv = attention.decode_kv(lp["attn"], h1, cfg=cfg,
                                            cache_k=lc["k"], cache_v=lc["v"],
                                            pos=pos)
            nc = {"k": nk, "v": nv}
        x = x + y
        new_cache[f"tail{i}"] = nc
        h2 = layers.apply_norm(lp["ln2"], x, cfg)
        x = x + layers.mlp(lp["mlp"], h2, cfg)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_cache
