"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: int | None = None

    # MLP
    mlp_act: str = "swiglu"      # swiglu | relu2 | gelu | geglu

    # attention
    rope_theta: float = 10_000.0
    window: int | None = None    # sliding-window size (SWA / local attn)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1          # dispatch groups (ride the data axis)

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # hybrid (recurrentgemma): repeating layer pattern
    block_pattern: tuple = ()    # e.g. ("rec", "rec", "attn")
    lru_width: int | None = None

    # enc-dec (whisper): encoder stub gets precomputed frame embeddings
    n_enc_layers: int = 0
    n_frames: int = 1500

    # vlm (llava): precomputed patch embeddings prefix
    n_patches: int = 0

    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    # unroll the layer loop (cost-analysis probes: XLA counts scan bodies
    # once, so dryrun probes compile unrolled shallow variants)
    unroll_layers: bool = False
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # training-step behaviour
    num_microbatches: int = 1
    remat: str = "full"          # none | full
    attn_chunk: int = 1024       # flash-style query block for long sequences

    def kv_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> list[str]:
        """Per-layer kind sequence for hybrid models."""
        if not self.block_pattern:
            kind = {"ssm": "ssm", "moe": "moe"}.get(self.family, "attn")
            return [kind] * self.n_layers
        pat = list(self.block_pattern)
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND roofline cross-check)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (nh + 2 * nkv) + nh * hd * d
        if self.mlp_act in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            mlp = mlp * self.n_experts + d * self.n_experts
        ssm = 0
        if self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            ssm = d * (2 * di + 2 * n) + di * d + self.ssm_heads * 2
            attn = mlp = 0
        per_kind = {"attn": attn + mlp, "moe": attn + mlp, "ssm": ssm,
                    "rec": (self.lru_width or d) * d * 3 + mlp}
        total = 0
        for kind in self.layer_kinds():
            total += per_kind.get(kind, attn + mlp)
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec":
            enc_layer = attn + mlp
            dec_cross = d * hd * (nh + 2 * nkv) + nh * hd * d
            total += self.n_enc_layers * enc_layer + self.n_layers * dec_cross
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_one = (3 if self.mlp_act in ("swiglu", "geglu") else 2) * d * f
        dense = self.param_count() - self.n_layers * self.n_experts * mlp_one
        return int(dense + self.n_layers * self.top_k * mlp_one)
