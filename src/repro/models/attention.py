"""Attention: GQA/MQA, RoPE, sliding window, chunked (flash-style) scan,
cross-attention, and single-token decode against a KV cache.

Long sequences never materialize the full S x S score matrix: queries are
processed in ``cfg.attn_chunk`` blocks inside a ``lax.scan`` (block scores
live only inside one scan step — the TPU-friendly stand-in for a fused
flash kernel; the quadratic FLOPs stay visible to ``cost_analysis``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from . import layers
from .layers import ParamSpec


def attn_spec(cfg, cross: bool = False) -> dict:
    d, hd, nh, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": ParamSpec((d, nh, hd), ("fsdp", "heads", "head_dim")),
        "wk": ParamSpec((d, nkv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, nkv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((nh, hd, d), ("heads", "head_dim", "fsdp")),
    }


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _mask_bias(q_pos, k_pos, window):
    """(…, Sq, Sk) additive mask: causal + optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q: (B,Sq,H,hd) k/v: (B,Sk,H,hd); bias: (Sq,Sk) or None."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def multihead(p, x, *, cfg, positions, kv_x=None, kv_positions=None,
              causal=True, return_kv=False):
    """Full attention over a sequence (training / prefill / cross).

    x: (B, S, D). kv_x (cross-attention source) defaults to x.
    With ``return_kv`` also returns the (pre-GQA-repeat, post-RoPE)
    (B, S, nkv, hd) K/V for cache seeding at prefill.
    """
    b, s, _ = x.shape
    dt = x.dtype
    wq = layers.wcast(p["wq"], dt, "fsdp", "heads", "head_dim")
    wk = layers.wcast(p["wk"], dt, "fsdp", "kv_heads", "head_dim")
    wv = layers.wcast(p["wv"], dt, "fsdp", "kv_heads", "head_dim")
    q = jnp.einsum("bsd,dhk->bshk", x, wq,
                   preferred_element_type=jnp.float32).astype(dt)
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, wk,
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bsd,dhk->bshk", src, wv,
                   preferred_element_type=jnp.float32).astype(dt)
    kpos = positions if kv_positions is None else kv_positions
    if causal:  # cross-attention skips RoPE on purpose (whisper-style)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, kpos, cfg.rope_theta)
    q = sharding.constrain(q, "batch", "seq", "heads", "head_dim")
    k = sharding.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = sharding.constrain(v, "batch", "seq", "kv_heads", "head_dim")
    kv_raw = (k, v)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)

    sk = k.shape[1]
    if not causal:
        out = _sdpa(q, k, v, None)
    elif s <= cfg.attn_chunk:
        bias = _mask_bias(positions[0] if positions.ndim > 1 else positions,
                          kpos[0] if kpos.ndim > 1 else kpos, cfg.window)
        out = _sdpa(q, k, v, bias)
    else:
        # flash-style: scan over query blocks, full KV per block
        nblk = s // cfg.attn_chunk
        assert s % cfg.attn_chunk == 0, (s, cfg.attn_chunk)
        qb = q.reshape(b, nblk, cfg.attn_chunk, *q.shape[2:])
        pos1 = positions[0] if positions.ndim > 1 else positions
        pb = pos1.reshape(nblk, cfg.attn_chunk)
        kpos1 = kpos[0] if kpos.ndim > 1 else kpos

        def step(_, inp):
            qi, pi = inp
            bias = _mask_bias(pi, kpos1, cfg.window)
            return None, _sdpa(qi, k, v, bias)
        _, ob = jax.lax.scan(step, None, (jnp.moveaxis(qb, 1, 0), pb))
        out = jnp.moveaxis(ob, 0, 1).reshape(b, s, *q.shape[2:])

    out = sharding.constrain(out, "batch", "seq", "heads", "head_dim")
    wo = layers.wcast(p["wo"], dt, "heads", "head_dim", "fsdp")
    # bf16 output so the TP all-reduce moves half the bytes (§Perf i6)
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    return (out, kv_raw) if return_kv else out


# ------------------------------------------------------------------ decode

def decode_kv(p, x, *, cfg, cache_k, cache_v, pos):
    """One-token attention against a KV cache.

    x: (B, 1, D); cache_k/v: (B, S_cache, nkv, hd); pos: () current index
    (ring-buffer slot = pos % S_cache when cfg.window is set).
    Returns (out (B,1,D), new_k, new_v).
    """
    b = x.shape[0]
    dt = x.dtype
    s_cache = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt),
                       preferred_element_type=jnp.float32).astype(dt)
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt),
                       preferred_element_type=jnp.float32).astype(dt)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = layers.rope(q, posv, cfg.rope_theta)
    k_new = layers.rope(k_new, posv, cfg.rope_theta)
    slot = pos % s_cache if cfg.window is not None else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    cache_k = sharding.constrain(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = sharding.constrain(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")

    # grouped-query attention WITHOUT materializing the GQA repeat: the
    # repeat reshards the seq-sharded cache to head-sharded, which GSPMD
    # realizes as a full f32 KV all-gather (1 GB/layer measured on
    # internlm2 decode_32k, §Perf i9). Keeping the kv dim in the einsum
    # leaves the cache seq-sharded; only the tiny softmax partials and the
    # (B,1,H,hd) output cross shards.
    n_rep = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, n_rep, q.shape[-1])
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    kidx = jnp.arange(s_cache)
    if cfg.window is not None:
        # ring buffer: slot j holds the token written `(slot - j) % W` steps
        # ago; valid iff that age is within the number of tokens seen so far
        age = (slot - kidx) % s_cache
        valid = age < jnp.minimum(pos + 1, s_cache)
    else:
        valid = kidx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)   # (b,h,r,1,S)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, cache_v,
                     preferred_element_type=jnp.float32).astype(dt)
    out = out.reshape(b, 1, cfg.n_heads, q.shape[-1])
    wo = layers.wcast(p["wo"], dt, "heads", "head_dim", "fsdp")
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    return out, cache_k, cache_v


def decode_cross(p, x, *, cfg, enc_k, enc_v):
    """One-token cross-attention against precomputed encoder K/V."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k = _repeat_kv(enc_k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(enc_v, cfg.n_heads // cfg.n_kv_heads)
    out = _sdpa(q, k.astype(dt), v.astype(dt), None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt),
                      preferred_element_type=jnp.float32).astype(dt)
