"""Mixture-of-Experts FFN with grouped sort-based dispatch (EP).

Token-choice top-k routing. Dispatch is *grouped*: tokens are split into
``cfg.moe_groups`` groups whose leading dim rides the 'data' mesh axis, so
the argsort / position-rank / scatter all stay LOCAL to a data shard (a
global sort over sharded tokens forces all-gathers — measured 2x worse
collectives, EXPERIMENTS.md §Perf i1). Capacity is per-group (standard in
EP systems). The only cross-shard movement is the expert all-to-all that
GSPMD inserts for the bucket resharding:

  * E % model == 0 (granite, 32e): experts='model' -> block-diagonal EP,
    one all-to-all of ~T*d bytes per layer.
  * E % model != 0 (mixtral, 8e): experts replicated, expert_mlp='model'
    -> Megatron TP inside each expert, all-reduce of the FFN output.

Position-in-expert uses segment starts (O(T*k)), not a one-hot cumsum
(O(T*k*E)).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import sharding
from .layers import ParamSpec


def moe_spec(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    gated = cfg.mlp_act in ("swiglu", "geglu")
    spec = {
        "router": ParamSpec((d, e), ("fsdp", None)),
        "wi": ParamSpec((e, d, f), ("experts", "expert_in", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "expert_in")),
    }
    if gated:
        spec["wg"] = ParamSpec((e, d, f), ("experts", "expert_in", "expert_mlp"))
    return spec


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_mlp(p, x, cfg):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss (scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    # group only when the token count is large: grouping exists to localize
    # the big-T sort; at decode scale (T~batch) it just fragments capacity
    # (measured 3x collective regression on mixtral decode_32k, §Perf i8)
    g = math.gcd(getattr(cfg, "moe_groups", 1), t) if t >= 2048 else 1
    tl = t // g                                   # tokens per group (local)
    dt = x.dtype
    xt = x.reshape(g, tl, d)
    xt = sharding.constrain(xt, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(dt),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (g, tl, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style), computed globally
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- grouped sort-based dispatch. GATHER-only formulation: GSPMD
    # replicates batched scatters (measured: 34 GB all-reduces of the
    # dispatch tensors, §Perf i2), but partitions batched gathers fine.
    flat_expert = expert_ids.reshape(g, tl * k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl), k)[None], (g, tl * k))
    flat_gate = gate_vals.reshape(g, tl * k)
    order = jnp.argsort(flat_expert, axis=1)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    sorted_token = jnp.take_along_axis(flat_token, order, axis=1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=1)
    # per-group segment starts: O(tl*k), no one-hot cumsum
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
        sorted_expert)                                       # (g, E)
    seg_end = jnp.concatenate(
        [seg_start[:, 1:], jnp.full((g, 1), tl * k)], axis=1)
    cap = capacity(cfg, tl)

    # bucket slot (e, c) <- the c-th sorted assignment of expert e
    pos = seg_start[:, :, None] + jnp.arange(cap)[None, None, :]  # (g,E,cap)
    valid = pos < seg_end[:, :, None]
    pos_c = jnp.clip(pos, 0, tl * k - 1).reshape(g, e * cap)
    tok_for_slot = jnp.take_along_axis(sorted_token, pos_c, axis=1)
    vals = jnp.take_along_axis(xt, tok_for_slot[..., None], axis=1)
    be = (vals * valid.reshape(g, e * cap, 1).astype(dt)).reshape(g, e, cap, d)
    be = sharding.constrain(be, "batch", "experts", "expert_cap", "expert_in")

    # ---- expert FFN. 3D dots (e, g*cap, .) — group merged into capacity:
    # CPU's DotThunk rejects 4D bf16 batched dots, and the 3D form shards
    # identically (e->model or replicated, capacity->data).
    from .layers import wcast
    bem = be.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    wi = wcast(p["wi"], dt, "experts", "expert_in", "expert_mlp")
    h = jnp.einsum("ecd,edf->ecf", bem, wi,
                   preferred_element_type=jnp.float32)
    if cfg.mlp_act in ("swiglu", "geglu"):
        wg = wcast(p["wg"], dt, "experts", "expert_in", "expert_mlp")
        gg = jnp.einsum("ecd,edf->ecf", bem, wg,
                        preferred_element_type=jnp.float32)
        act = jax.nn.silu(gg) if cfg.mlp_act == "swiglu" else jax.nn.gelu(gg)
        h = act * h
    else:
        h = jnp.square(jax.nn.relu(h)) if cfg.mlp_act == "relu2" else jax.nn.gelu(h)
    h = sharding.constrain(h.astype(dt), "experts", "expert_cap",
                           "expert_mlp")
    wo = wcast(p["wo"], dt, "experts", "expert_mlp", "expert_in")
    out_m = jnp.einsum("ecf,efd->ecd", h, wo,
                       preferred_element_type=jnp.float32).astype(dt)
    out_e = out_m.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    out_e = sharding.constrain(out_e, "batch", "experts", "expert_cap",
                               "expert_in")
    out_flat = out_e.reshape(g, e * cap, d)

    # ---- combine: gather each assignment's slot output, un-sort via the
    # inverse permutation, then sum the k contributions per token
    pos_in_expert = (jnp.arange(tl * k)[None, :]
                     - jnp.take_along_axis(seg_start, sorted_expert, axis=1))
    keep = pos_in_expert < cap
    slot = sorted_expert * cap + jnp.minimum(pos_in_expert, cap - 1)
    contrib = jnp.take_along_axis(out_flat, slot[..., None], axis=1) \
        * (sorted_gate * keep).astype(dt)[..., None]
    inv = jnp.argsort(order, axis=1)
    unsorted = jnp.take_along_axis(contrib, inv[..., None], axis=1)
    yt = unsorted.reshape(g, tl, k, d).sum(axis=2)
    return yt.reshape(b, s, d), aux
