"""LM model zoo: dense/GQA, MoE, SSM (mamba2), RG-LRU hybrid, enc-dec, VLM."""
from .config import ModelConfig  # noqa: F401
