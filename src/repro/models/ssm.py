"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Chunked SSD algorithm: within-chunk "attention-like" term via the decay
matrix L, cross-chunk linear recurrence on the (H, P, N) state via
``lax.scan``. Decode is the O(1) recurrent update — which is what makes the
``long_500k`` cell tractable for this family (DESIGN.md §5).

Layout: x (B, S, H, P) with H = d_inner/head_dim heads, P = head_dim,
shared B/C of state size N (single group), scalar-per-head A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from .layers import ParamSpec


def ssm_spec(cfg) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "in_x": ParamSpec((d, di), ("fsdp", "mlp")),
        "in_z": ParamSpec((d, di), ("fsdp", "mlp")),
        "in_b": ParamSpec((d, n), ("fsdp", "state")),
        "in_c": ParamSpec((d, n), ("fsdp", "state")),
        "in_dt": ParamSpec((d, h), ("fsdp", "heads")),
        "dt_bias": ParamSpec((h,), ("heads",), "zeros"),
        "a_log": ParamSpec((h,), ("heads",), "zeros"),
        "d_skip": ParamSpec((h,), ("heads",), "ones"),
        "conv_w": ParamSpec((cfg.ssm_conv, di), (None, "mlp"), scale=0.5),
        "norm_scale": ParamSpec((di,), ("mlp",), "zeros"),
        "out": ParamSpec((di, d), ("mlp", "fsdp")),
    }


def _proj(x, w):
    return jnp.einsum("...d,dk->...k", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv over seq. x: (B,S,DI), w: (K,DI)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return jax.nn.silu(out), new_state


def _rmsnorm_gated(x, z, scale):
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * (1 + scale.astype(jnp.float32))).astype(x.dtype)


def ssd_chunked(xh, dt, a, bmat, cmat, chunk: int):
    """SSD forward. xh: (B,S,H,P); dt: (B,S,H); a: (H,) (negative);
    bmat/cmat: (B,S,N). Returns y: (B,S,H,P), final state (B,H,P,N)."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    adt = dt * a[None, None, :]                       # (B,S,H) negative
    xdt = xh * dt[..., None]
    # reshape into chunks
    adt_c = adt.reshape(b, nc, chunk, h)
    xdt_c = xdt.reshape(b, nc, chunk, h, p)
    b_c = bmat.reshape(b, nc, chunk, n)
    c_c = cmat.reshape(b, nc, chunk, n)
    cum = jnp.cumsum(adt_c, axis=2)                   # (B,NC,Q,H)
    # within-chunk: L[q,t] = exp(cum[q] - cum[t]) for q >= t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,NC,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqn,bctn->bcqt", c_c, b_c,
                    preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bcqt,bcqth,bcthp->bcqhp", cb, l_mat,
                        xdt_c.astype(jnp.float32))
    # chunk-final states: S_c = sum_t exp(cum[last]-cum[t]) * B_t x_t^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)   # (B,NC,Q,H)
    s_chunk = jnp.einsum("bctn,bcth,bcthp->bchpn", b_c.astype(jnp.float32),
                         decay_to_end, xdt_c.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # (B,NC,H)

    def scan_fn(carry, inp):
        s_c, d_c = inp                                 # (B,H,P,N), (B,H)
        new = carry * d_c[:, :, None, None] + s_c
        return new, carry                              # emit state BEFORE chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B,NC,H,P,N)
    # cross-chunk contribution: C_q exp(cum[q]) h_prev
    decay_in = jnp.exp(cum)                            # (B,NC,Q,H)
    y_cross = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", c_c.astype(jnp.float32),
                         decay_in, prev_states)
    y = (y_diag + y_cross).reshape(b, s, h, p)
    return y.astype(xh.dtype), final


def ssm_block(p, x, cfg, cache=None, pos=None):
    """Full-sequence (cache=None) or one-step decode (cache set).

    cache: {"conv": (B, K-1, DI), "state": (B, H, P, N)}.
    Returns (y, new_cache).
    """
    bsz = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = _proj(x, p["in_x"])
    z = _proj(x, p["in_z"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if cache is None:
        xin, conv_state = _causal_conv(xin, p["conv_w"])
        dt = jax.nn.softplus(_proj(x, p["in_dt"]).astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        bmat = _proj(x, p["in_b"]).astype(jnp.float32)
        cmat = _proj(x, p["in_c"]).astype(jnp.float32)
        xh = xin.reshape(*xin.shape[:2], h, pdim)
        xh = sharding.constrain(xh, "batch", "seq", "heads", None)
        # pad S to the chunk multiple: dt=0 pads are exact no-ops on the
        # state (decay exp(0)=1, contribution 0)
        s_len = xh.shape[1]
        pad = (-s_len) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, b_p, c_p = xh, dt, bmat, cmat
        y, state = ssd_chunked(xh_p, dt_p, a, b_p, c_p, cfg.ssm_chunk)
        y = y[:, :s_len]
        y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(*xin.shape)
        out = _proj(_rmsnorm_gated(y, z, p["norm_scale"]), p["out"])
        new_cache = {"conv": conv_state,
                     "state": state.astype(jnp.float32)}
        return out, new_cache

    # ---- decode: single token, O(1) state update
    conv_state, state = cache["conv"], cache["state"]
    xin1, conv_state = _causal_conv(xin, p["conv_w"], conv_state)
    dt = jax.nn.softplus(_proj(x, p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    bmat = _proj(x, p["in_b"]).astype(jnp.float32)[:, 0]            # (B,N)
    cmat = _proj(x, p["in_c"]).astype(jnp.float32)[:, 0]
    xh = xin1.reshape(bsz, h, pdim).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])                                # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bmat)
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat, state)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, -1).astype(x.dtype)
    out = _proj(_rmsnorm_gated(y, z, p["norm_scale"]), p["out"])
    return out, {"conv": conv_state, "state": state}


def ssm_cache_spec(cfg, batch: int):
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, cfg.d_inner),
                                     jnp.dtype(cfg.compute_dtype)),
        "state": jax.ShapeDtypeStruct((batch, h, pdim, n), jnp.float32),
    }


def ssm_cache_axes():
    return {"conv": ("batch", None, "mlp"), "state": ("batch", "heads", None, None)}
