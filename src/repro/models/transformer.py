"""Model assembly: stacked-layer scan transformers for all families.

One ``LM`` class covers: dense/GQA decoders, MoE, SSM (mamba2), RG-LRU
hybrids (pattern-scan + unrolled tail), encoder-decoder (whisper-style,
frame-embedding stub), and VLM (patch-embedding prefix stub).

Layers are *stacked* (leading layer axis) and applied with ``lax.scan`` so
a 96-layer model compiles as one layer body + loop — essential for the
40-cell dry-run's compile times. ``cfg.remat`` wraps the scan body with
``jax.checkpoint`` for training memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import sharding
from . import attention, layers, moe, rglru, ssm
from .config import ModelConfig
from .layers import ParamSpec


def _stack_specs(spec, n: int):
    """Prepend a layer axis to every ParamSpec in a nested dict."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.axes), s.init, s.scale),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def maybe_scan(body, carry, xs, *, unroll: bool):
    """lax.scan, or a Python unroll (for cost-analysis probe configs —
    XLA's cost analysis counts while-loop bodies once)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        stacked = None
    else:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


class LM:
    """A configured language model (pure functions over a param dict)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()
        if cfg.block_pattern:
            pat = len(cfg.block_pattern)
            self.n_rep = cfg.n_layers // pat
            self.tail_kinds = self.kinds[self.n_rep * pat:]
        else:
            self.n_rep = cfg.n_layers
            self.tail_kinds = []

    # ------------------------------------------------------------- specs
    def _block_spec(self, kind: str) -> dict:
        cfg = self.cfg
        if kind == "attn":
            return {"ln1": layers.norm_spec(cfg),
                    "attn": attention.attn_spec(cfg),
                    "ln2": layers.norm_spec(cfg),
                    "mlp": layers.mlp_spec(cfg)}
        if kind == "moe":
            return {"ln1": layers.norm_spec(cfg),
                    "attn": attention.attn_spec(cfg),
                    "ln2": layers.norm_spec(cfg),
                    "moe": moe.moe_spec(cfg)}
        if kind == "ssm":
            return {"ln1": layers.norm_spec(cfg), "ssm": ssm.ssm_spec(cfg)}
        if kind == "rec":
            return {"ln1": layers.norm_spec(cfg),
                    "rec": rglru.rglru_spec(cfg),
                    "ln2": layers.norm_spec(cfg),
                    "mlp": layers.mlp_spec(cfg)}
        if kind == "xattn":  # enc-dec decoder block
            return {"ln1": layers.norm_spec(cfg),
                    "attn": attention.attn_spec(cfg),
                    "lnx": layers.norm_spec(cfg),
                    "xattn": attention.attn_spec(cfg, cross=True),
                    "ln2": layers.norm_spec(cfg),
                    "mlp": layers.mlp_spec(cfg)}
        raise ValueError(kind)

    def param_specs(self) -> dict:
        cfg = self.cfg
        spec: dict = {"embed": layers.embed_spec(cfg),
                      "final_norm": layers.norm_spec(cfg)}
        if cfg.block_pattern:
            block = {f"sub{i}_{k}": self._block_spec(k)
                     for i, k in enumerate(cfg.block_pattern)}
            spec["blocks"] = _stack_specs(block, self.n_rep)
            for i, k in enumerate(self.tail_kinds):
                spec[f"tail{i}"] = self._block_spec(k)
        elif cfg.family == "encdec":
            spec["enc"] = _stack_specs(self._block_spec("attn"), cfg.n_enc_layers)
            spec["blocks"] = _stack_specs(self._block_spec("xattn"), cfg.n_layers)
            spec["enc_norm"] = layers.norm_spec(cfg)
        else:
            kind = self.kinds[0]
            spec["blocks"] = _stack_specs(self._block_spec(kind), cfg.n_layers)
        return spec

    def param_axes(self):
        return layers.axes_tree(self.param_specs())

    def abstract_params(self):
        return layers.shapes_tree(self.param_specs(),
                                  jnp.dtype(self.cfg.param_dtype))

    def init(self, key):
        return layers.init_tree(self.param_specs(), key,
                                jnp.dtype(self.cfg.param_dtype))

    # ------------------------------------------------------------ blocks
    def _apply_block(self, kind: str, p, x, positions, *, enc_out=None,
                     enc_pos=None, window_override=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind in ("attn", "moe", "xattn"):
            h = layers.apply_norm(p["ln1"], x, cfg)
            win = window_override if window_override is not None else cfg.window
            x = x + attention.multihead(
                p["attn"], h, cfg=self._cfg_with_window(win), positions=positions)
            if kind == "xattn":
                h = layers.apply_norm(p["lnx"], x, cfg)
                x = x + attention.multihead(
                    p["xattn"], h, cfg=cfg, positions=positions,
                    kv_x=enc_out, kv_positions=enc_pos, causal=False)
            h = layers.apply_norm(p["ln2"], x, cfg)
            if kind == "moe":
                y, aux = moe.moe_mlp(p["moe"], h, cfg)
                x = x + y
            else:
                x = x + layers.mlp(p["mlp"], h, cfg)
        elif kind == "ssm":
            h = layers.apply_norm(p["ln1"], x, cfg)
            y, _ = ssm.ssm_block(p["ssm"], h, cfg)
            x = x + y
        elif kind == "rec":
            h = layers.apply_norm(p["ln1"], x, cfg)
            y, _ = rglru.rglru_block(p["rec"], h, cfg)
            x = x + y
            h = layers.apply_norm(p["ln2"], x, cfg)
            x = x + layers.mlp(p["mlp"], h, cfg)
        else:
            raise ValueError(kind)
        x = sharding.constrain(x, "batch", "seq", "embed")
        return x, aux

    @functools.lru_cache(maxsize=8)
    def _cfg_with_window(self, win):
        if win == self.cfg.window:
            return self.cfg
        import dataclasses
        return dataclasses.replace(self.cfg, window=win)

    # ----------------------------------------------------------- forward
    def forward(self, params, tokens, *, extras=None, return_cache=False):
        """Full-sequence forward -> logits (B, S, V) [+ caches].

        ``extras``: {"patch_embeds": (B,P,D)} for vlm, {"frames": (B,F,D)}
        for encdec.
        """
        cfg = self.cfg
        extras = extras or {}
        b, s = tokens.shape
        x = layers.embed(params["embed"], tokens, cfg)
        if cfg.family == "vlm" and "patch_embeds" in extras:
            pe = extras["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:, :]], axis=1)
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
        aux_total = jnp.zeros((), jnp.float32)

        enc_out = enc_pos = None
        if cfg.family == "encdec":
            enc_out, enc_pos = self._encode(params, extras["frames"])

        if cfg.block_pattern:
            x, aux_total = self._hybrid_forward(params, x, positions)
        else:
            kind = "xattn" if cfg.family == "encdec" else self.kinds[0]

            def body(carry, lp):
                h, aux = carry
                h, a = self._apply_block(kind, lp, h, positions,
                                         enc_out=enc_out, enc_pos=enc_pos)
                return (h, aux + a), None
            if cfg.remat == "full":
                body = jax.checkpoint(body)
            (x, aux_total), _ = maybe_scan(body, (x, aux_total),
                                           params["blocks"],
                                           unroll=cfg.unroll_layers)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        logits = layers.unembed(params["embed"], x, cfg)
        return (logits, aux_total)

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.compute_dtype))
        b, f, _ = x.shape
        pos = jnp.arange(f, dtype=jnp.int32)[None, :].repeat(b, 0)

        def body(h, lp):
            h1 = layers.apply_norm(lp["ln1"], h, cfg)
            h = h + attention.multihead(lp["attn"], h1, cfg=cfg,
                                        positions=pos, causal=False)
            h2 = layers.apply_norm(lp["ln2"], h, cfg)
            h = h + layers.mlp(lp["mlp"], h2, cfg)
            return h, None
        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = maybe_scan(body, x, params["enc"], unroll=cfg.unroll_layers)
        x = layers.apply_norm(params["enc_norm"], x, cfg)
        return x, pos

    def _hybrid_forward(self, params, x, positions):
        cfg = self.cfg
        pat = cfg.block_pattern
        aux = jnp.zeros((), jnp.float32)

        def body(carry, lp):
            h, a = carry
            for i, k in enumerate(pat):
                win = cfg.window if k == "attn" else None
                h, ai = self._apply_block(k, lp[f"sub{i}_{k}"], h, positions,
                                          window_override=win)
                a = a + ai
            return (h, a), None
        if cfg.remat == "full":
            body = jax.checkpoint(body)
        (x, aux), _ = maybe_scan(body, (x, aux), params["blocks"],
                                 unroll=cfg.unroll_layers)
        for i, k in enumerate(self.tail_kinds):
            win = cfg.window if k == "attn" else None
            x, ai = self._apply_block(k, params[f"tail{i}"], x, positions,
                                      window_override=win)
            aux = aux + ai
        return x, aux

    # ------------------------------------------------- loss (next token)
    def loss_fn(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"],
                                   extras={k: v for k, v in batch.items()
                                           if k in ("patch_embeds", "frames")})
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - ll) * mask) / jnp.clip(mask.sum(), 1.0)
        return nll + 0.01 * aux, {"loss": nll, "aux": aux}
