"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence:  a_t = exp(-c * softplus(Lambda) * r_t),
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t).
Training uses ``lax.associative_scan`` over the (a, b) pairs (the
recurrence is associative); decode is a single-step update. Combined with
1:2-interleaved local attention in the hybrid transformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ParamSpec

_C = 8.0


def rglru_spec(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "in_x": ParamSpec((d, w), ("fsdp", "state")),
        "in_gate": ParamSpec((d, w), ("fsdp", "state")),
        "conv_w": ParamSpec((4, w), (None, "state"), scale=0.5),
        "gate_r": ParamSpec((w, w), ("fsdp", "state")),
        "gate_i": ParamSpec((w, w), ("fsdp", "state")),
        "lam": ParamSpec((w,), ("state",), "zeros"),
        "out": ParamSpec((w, d), ("state", "fsdp")),
    }


def _proj(x, w):
    return jnp.einsum("...d,dk->...k", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _gates(p, xw):
    r = jax.nn.sigmoid(_proj(xw, p["gate_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_proj(xw, p["gate_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = i * xw.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * gated
    return a, b


def _causal_conv(x, w, state=None):
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    return out, xp[:, -(k - 1):, :]


def rglru_block(p, x, cfg, cache=None, pos=None):
    """x: (B, S, D) full-seq, or (B, 1, D) decode with cache
    {"conv": (B,3,W), "h": (B,W)}. Returns (y, new_cache)."""
    gate_in = jax.nn.gelu(_proj(x, p["in_gate"]).astype(jnp.float32))
    xw = _proj(x, p["in_x"])

    if cache is None:
        xw, conv_state = _causal_conv(xw, p["conv_w"])
        a, b = _gates(p, xw)

        def combine(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])
        a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = (h * gate_in).astype(x.dtype)
        new_cache = {"conv": conv_state, "h": h[:, -1].astype(jnp.float32)}
    else:
        xw, conv_state = _causal_conv(xw, p["conv_w"], cache["conv"])
        a, b = _gates(p, xw)
        h = a[:, 0] * cache["h"] + b[:, 0]
        y = (h[:, None, :] * gate_in).astype(x.dtype)
        new_cache = {"conv": conv_state, "h": h}
    out = _proj(y, p["out"])
    return out, new_cache


def rglru_cache_spec(cfg, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, w), jnp.dtype(cfg.compute_dtype)),
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }


def rglru_cache_axes():
    return {"conv": ("batch", None, "state"), "h": ("batch", "state")}
