"""Shared layers: norms, MLPs, embeddings, RoPE, parameter specs.

Parameters are plain nested dicts built from ``ParamSpec`` tables so that
initialization, abstract shapes (dry-run) and logical sharding axes all
come from one source of truth.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .. import sharding


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple           # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # default: 1/sqrt(fan_in)

    def initializer(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(
            max(1, self.shape[0]))
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(dtype)


def init_tree(specs, key, dtype):
    """Instantiate a nested dict of ParamSpec -> arrays."""
    flat, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(flat))
    vals = [s.initializer(k, dtype) for s, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def shapes_tree(specs, dtype):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def wcast(w, dtype, *axes):
    """Cast a sharded param to compute dtype, pinning the sharded layout.

    Without the constraint XLA may all-gather the f32 master weights and
    convert afterwards; pinning the bf16 copy to the same sharding makes
    the FSDP gather move half the bytes (§Perf i3)."""
    return sharding.constrain(w.astype(dtype), *axes)


# ------------------------------------------------------------------ norms

def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones"),
                "bias": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    return {"scale": ParamSpec((cfg.d_model,), ("embed",), "zeros")}


def apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ------------------------------------------------------------------- MLPs

def mlp_spec(cfg, d_in=None) -> dict:
    d = d_in or cfg.d_model
    f = cfg.d_ff
    gated = cfg.mlp_act in ("swiglu", "geglu")
    spec = {"wi": ParamSpec((d, f), ("fsdp", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "fsdp"))}
    if gated:
        spec["wg"] = ParamSpec((d, f), ("fsdp", "mlp"))
    return spec


def mlp(p, x, cfg):
    wi = wcast(p["wi"], x.dtype, "fsdp", "mlp")
    h = jnp.einsum("...d,df->...f", x, wi,
                   preferred_element_type=jnp.float32)
    if cfg.mlp_act in ("swiglu", "geglu"):
        wg = wcast(p["wg"], x.dtype, "fsdp", "mlp")
        g = jnp.einsum("...d,df->...f", x, wg,
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g)) * h
    elif cfg.mlp_act == "relu2":          # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = sharding.constrain(h.astype(x.dtype), "batch",
                           *(None,) * (x.ndim - 2), "mlp")
    wo = wcast(p["wo"], x.dtype, "mlp", "fsdp")
    # output projection accumulates partial sums ACROSS model ranks: emit
    # in compute dtype so the TP all-reduce moves bf16, not f32 (§Perf i6)
    return jnp.einsum("...f,fd->...d", h, wo)


# ------------------------------------------------------------- embeddings

def embed_spec(cfg) -> dict:
    spec = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model),
                             ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        spec["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                    ("embed", "vocab"))
    return spec


def embed(p, tokens, cfg):
    x = jnp.take(p["tok"].astype(jnp.dtype(cfg.compute_dtype)), tokens, axis=0)
    return sharding.constrain(x, "batch", "seq", "embed")


def unembed(p, x, cfg):
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"])
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return sharding.constrain(logits, *("batch",) + (None,) * (x.ndim - 2) + ("vocab",))


# ------------------------------------------------------------------- RoPE

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
