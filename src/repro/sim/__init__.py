"""RAMSES-like AMR data substrate (Sedov3D + Orion-like generators)."""
