"""AMR octree generation from a refinement criterion (RAMSES-like).

Builds the *global* tree level by level: levels up to ``min_level`` are
fully refined (RAMSES ``levelmin`` uniform base grid); beyond that, a cell
refines when the field criterion triggers (density threshold with
per-level scaling — a stand-in for RAMSES' quasi-Lagrangian refinement).
Leaf fields are evaluated at cell centers; coarse cells get the intensive
restriction (mean of sons), which is the father–son codec's predictor.
"""
from __future__ import annotations

import numpy as np

from ..core.amr import AMRTree, CHILD_OFFSETS
from .fields import Field


def generate_tree(field: Field, *, min_level: int = 3, max_level: int = 8,
                  criterion_field: str = "density",
                  threshold: float = 1.2, level_factor: float = 1.35,
                  rng_jitter: float = 0.0, seed: int = 0) -> AMRTree:
    """Generate a global AMR tree driven by ``criterion_field``.

    A level-l cell refines iff l < min_level, or its center value exceeds
    ``threshold * level_factor**(l - min_level)`` (denser regions refine
    deeper — lognormal fields then give realistic depth distributions).
    """
    rng = np.random.default_rng(seed)
    level_coords = [np.zeros((1, 3), np.int64)]
    level_refine = []
    for l in range(max_level):
        coords = level_coords[l]
        n = coords.shape[0]
        if l < min_level:
            ref = np.ones(n, bool)
        else:
            centers = (coords + 0.5) / (1 << l)
            vals = field(criterion_field, centers)
            thr = threshold * level_factor ** (l - min_level)
            if rng_jitter:
                thr = thr * np.exp(rng_jitter * rng.standard_normal(n))
            ref = vals > thr
        level_refine.append(ref)
        kids = (2 * coords[ref][:, None, :] + CHILD_OFFSETS[None, :, :])
        level_coords.append(kids.reshape(-1, 3))
        if not ref.any():
            level_coords = level_coords[:l + 2]
            break
    level_refine.append(np.zeros(level_coords[-1].shape[0], bool))

    refine = np.concatenate(level_refine)
    coords = np.concatenate(level_coords)
    offsets = np.zeros(len(level_coords) + 1, np.int64)
    for i, c in enumerate(level_coords):
        offsets[i + 1] = offsets[i] + c.shape[0]
    tree = AMRTree(refine=refine.astype(bool),
                   owner=np.ones(refine.shape[0], bool),
                   level_offsets=offsets, coords=coords)
    fill_fields(tree, field)
    return tree


def fill_fields(tree: AMRTree, field: Field) -> None:
    """Evaluate fields at leaf centers, then restrict upward to coarse."""
    levels = tree.levels()
    centers = (tree.coords + 0.5) / (1 << levels.astype(np.int64))[:, None]
    leaves = ~tree.refine
    for name in field.names:
        v = np.zeros(tree.n_nodes)
        v[leaves] = field(name, centers[leaves])
        tree.fields[name] = v
    tree.restrict_fields_upward()
