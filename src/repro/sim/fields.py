"""Analytic physical fields used to drive AMR refinement and fill cells.

Two test cases mirror the paper's:

  * **Sedov3D** — point explosion in a cubic box (paper §3 benchmark case):
    self-similar blast-wave profile with a density/pressure shell at the
    shock radius. Smooth away from the shock, sharp at it.
  * **Orion-like** — lognormal density from multi-octave value noise, a
    proxy for the MHD-turbulence molecular-cloud data (Ntormousi &
    Hennebelle 2019) used for the paper's pruning/compression figures.

All evaluators are vectorized: points are (N, 3) float64 in [0, 1).
"""
from __future__ import annotations

import numpy as np


class Field:
    """Bundle of named scalar evaluators over unit-box points."""

    def __init__(self, evaluators):
        self._ev = dict(evaluators)

    @property
    def names(self):
        return list(self._ev)

    def __call__(self, name: str, pts: np.ndarray) -> np.ndarray:
        return self._ev[name](pts)

    def all(self, pts: np.ndarray) -> dict[str, np.ndarray]:
        return {k: f(pts) for k, f in self._ev.items()}


# ---------------------------------------------------------------- Sedov3D

def sedov(center=(0.5, 0.5, 0.5), r_shock: float = 0.28,
          shell_width: float = 0.02, rho0: float = 1.0,
          jump: float = 4.0) -> Field:
    """Sedov blast wave approximation (strong-shock gamma=5/3 profile)."""
    c = np.asarray(center)

    def radius(pts):
        return np.sqrt(((pts - c) ** 2).sum(axis=1)) + 1e-12

    def density(pts):
        r = radius(pts)
        x = r / r_shock
        inner = rho0 * np.clip(x, 1e-3, 1.0) ** 4.5  # evacuated interior
        shell = rho0 * jump * np.exp(-0.5 * ((r - r_shock) / shell_width) ** 2)
        post = rho0 * np.where(r > r_shock, 1.0, 0.0)
        return np.where(r <= r_shock, inner, post) + shell

    def pressure(pts):
        r = radius(pts)
        x = np.clip(r / r_shock, 1e-3, None)
        return np.where(x <= 1.0, 0.3 + 0.7 * x ** 1.5,
                        1e-3 + 0.3 * np.exp(-4.0 * (x - 1.0)))

    def vel(axis):
        def f(pts):
            r = radius(pts)
            u = (pts[:, axis] - c[axis]) / r
            mag = np.where(r <= r_shock, 0.75 * r / r_shock,
                           0.75 * np.exp(-6.0 * (r / r_shock - 1.0)))
            return mag * u
        return f

    return Field({"density": density, "pressure": pressure,
                  "velocity_x": vel(0), "velocity_y": vel(1),
                  "velocity_z": vel(2)})


# ------------------------------------------------------------- Orion-like

class _ValueNoise:
    """Periodic multi-octave trilinear value noise on the unit box."""

    def __init__(self, seed: int, octaves: int = 6, base_res: int = 4,
                 persistence: float = 0.62):
        rng = np.random.default_rng(seed)
        self.grids = []
        self.persistence = persistence
        res = base_res
        for _ in range(octaves):
            self.grids.append(rng.standard_normal((res, res, res)))
            res *= 2

    def __call__(self, pts: np.ndarray) -> np.ndarray:
        out = np.zeros(pts.shape[0])
        amp = 1.0
        for g in self.grids:
            n = g.shape[0]
            x = pts * n
            i0 = np.floor(x).astype(np.int64) % n
            f = x - np.floor(x)
            i1 = (i0 + 1) % n
            # trilinear blend
            def at(ix, iy, iz):
                return g[ix, iy, iz]
            c000 = at(i0[:, 0], i0[:, 1], i0[:, 2]); c100 = at(i1[:, 0], i0[:, 1], i0[:, 2])
            c010 = at(i0[:, 0], i1[:, 1], i0[:, 2]); c110 = at(i1[:, 0], i1[:, 1], i0[:, 2])
            c001 = at(i0[:, 0], i0[:, 1], i1[:, 2]); c101 = at(i1[:, 0], i0[:, 1], i1[:, 2])
            c011 = at(i0[:, 0], i1[:, 1], i1[:, 2]); c111 = at(i1[:, 0], i1[:, 1], i1[:, 2])
            fx, fy, fz = f[:, 0], f[:, 1], f[:, 2]
            c00 = c000 * (1 - fx) + c100 * fx
            c10 = c010 * (1 - fx) + c110 * fx
            c01 = c001 * (1 - fx) + c101 * fx
            c11 = c011 * (1 - fx) + c111 * fx
            c0 = c00 * (1 - fy) + c10 * fy
            c1 = c01 * (1 - fy) + c11 * fy
            out += amp * (c0 * (1 - fz) + c1 * fz)
            amp *= self.persistence
        return out


def orion(seed: int = 7, sigma: float = 1.6) -> Field:
    """Lognormal turbulent cloud proxy with velocity components."""
    s = _ValueNoise(seed)
    vxn = _ValueNoise(seed + 1, octaves=5)
    vyn = _ValueNoise(seed + 2, octaves=5)
    vzn = _ValueNoise(seed + 3, octaves=5)

    def density(pts):
        return np.exp(sigma * s(pts))  # lognormal PDF of supersonic turbulence

    return Field({"density": density,
                  "velocity_x": lambda p: 0.8 * vxn(p),
                  "velocity_y": lambda p: 0.8 * vyn(p),
                  "velocity_z": lambda p: 0.8 * vzn(p),
                  "pressure": lambda p: density(p) ** (5.0 / 3.0)})
