"""Pallas TPU kernels for in-transit AMR rasterization (DESIGN.md §14).

The three hot reducers of the in-situ flow — axis-aligned slice,
projection (weighted axis sum) and per-level histogram — as on-device
kernels, so device-resident staging (``insitu.device``) transfers only
the *reduced* objects across the device→host boundary instead of the
full snapshot.

All three operate on a flat **leaf table** derived from the BFS tree
arrays (``ops.py`` builds it): per-leaf pixel origin ``(u0, v0)``,
rectangle size ``px``, level, value/contribution and a validity mask
(leaf ∧ owned ∧ slice-plane hit ∧ not padding). Pixel math is pure
integer arithmetic — the image resolution is required to be a power of
two, so ``u0 = c << (k-l)`` (or ``>> (l-k)``) and ``px = max(1, R >>
l)`` reproduce the host reducers' float ``floor``/``round`` results bit
for bit; non-pow2 resolutions take the host fallback in
``insitu.device``.

Kernel shape: leaves ride the lane axis in ``(1, BLOCK_N)`` tables; the
grid walks leaf blocks *sequentially* while the full output image (or
histogram) stays resident in VMEM across grid steps (constant
``index_map``, initialized on the first step). Inside a block the
slice/projection kernels ``fori_loop`` over leaves, each iteration
updating the output tile through a broadcast rectangle mask — masked
``where`` updates, never scatter, so per-pixel update *order* equals
the host reducers' BFS traversal and float accumulation is
bit-identical, not just close. The histogram kernel is fully
vectorized: a (BLOCK, B+1) edge-compare reproduces
``np.searchsorted(edges, v, "right")`` and a (BLOCK, L·B) one-hot
contraction is the blocked scatter-add (integer counts — order-free).

Like the fpdelta kernels, every entry point takes ``interpret=`` so CPU
CI exercises the exact kernel path (``backend="pallas_interpret"`` in
``ops.py``); the pure-jnp twins in ``ref.py`` use vectorized per-level
scatters instead (fast CPU path) and are bit-identical by the same
ordering argument (XLA CPU applies scatter updates in order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: leaves per grid step (lane-dim multiple of 128)
DEFAULT_BLOCK_N = 512


# ----------------------------------------------------------- leaf tables

def leaf_table(coords, levels, *, resolution: int):
    """Integer pixel geometry of every node: (u0, v0, px) per axis-pair.

    ``coords`` is the (N, 2) slice-plane projection of the node coords
    (caller drops the slice/projection axis); ``resolution`` must be a
    power of two (asserted by ops.py). Exact integer forms of the host
    reducers' ``floor(c * size * res)`` and ``round(size * res)``.
    """
    k = resolution.bit_length() - 1
    lvl = levels.astype(jnp.int32)
    up = jnp.maximum(k - lvl, 0)
    dn = jnp.maximum(lvl - k, 0)
    c = coords.astype(jnp.int32)
    u0 = (c[:, 0] << up) >> dn
    v0 = (c[:, 1] << up) >> dn
    px = jnp.maximum(resolution >> jnp.minimum(lvl, 30), 1).astype(jnp.int32)
    return u0, v0, px


def plane_hit(coords_axis, levels, position: float, dtype):
    """Host-exact slice-plane test: ``lo <= position < lo + size``.

    Both bounds are exact dyadic rationals in float64 (c/2^l), so the
    comparison reproduces ``analysis.slice_image`` bit for bit.
    """
    size = jnp.asarray(2.0, dtype) ** (-levels.astype(dtype))
    lo = coords_axis.astype(dtype) * size
    return (lo <= position) & (position < lo + size)


# ------------------------------------------------------------ slice kernel

def _slice_body(u0_ref, v0_ref, px_ref, lvl_ref, val_ref, ok_ref,
                img_ref, depth_ref, *, block_n: int, resolution: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (resolution, resolution), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (resolution, resolution), 1)

    def body(i, _):
        u0, v0, px = u0_ref[0, i], v0_ref[0, i], px_ref[0, i]
        lvl, val, ok = lvl_ref[0, i], val_ref[0, i], ok_ref[0, i]
        rect = ((rows >= u0) & (rows < u0 + px)
                & (cols >= v0) & (cols < v0 + px))
        # deepest leaf wins; equal level repaints (leaves arrive in BFS
        # order, so this is exactly the host painter's later-overrides)
        mask = rect & (ok != 0) & (lvl >= depth_ref[...])
        img_ref[...] = jnp.where(mask, val, img_ref[...])
        depth_ref[...] = jnp.where(mask, lvl, depth_ref[...])
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)


def _slice_kernel(u0_ref, v0_ref, px_ref, lvl_ref, val_ref, ok_ref,
                  img_ref, depth_ref, *, block_n: int, resolution: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        img_ref[...] = jnp.full((resolution, resolution), jnp.nan,
                                img_ref.dtype)
        depth_ref[...] = jnp.full((resolution, resolution), -1, jnp.int32)

    _slice_body(u0_ref, v0_ref, px_ref, lvl_ref, val_ref, ok_ref,
                img_ref, depth_ref, block_n=block_n, resolution=resolution)


def _slice_carry_kernel(u0_ref, v0_ref, px_ref, lvl_ref, val_ref, ok_ref,
                        img0_ref, depth0_ref, img_ref, depth_ref, *,
                        block_n: int, resolution: int):
    """Slice kernel seeded from a carried (image, depth) pair.

    The seed is the partial result of earlier leaf-table tiles (the
    tiled-gather formulation) — semantically the kernel behaves as if
    the seed's leaves had been painted first, which they were.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        img_ref[...] = img0_ref[...]
        depth_ref[...] = depth0_ref[...]

    _slice_body(u0_ref, v0_ref, px_ref, lvl_ref, val_ref, ok_ref,
                img_ref, depth_ref, block_n=block_n, resolution=resolution)


@functools.partial(jax.jit, static_argnames=("resolution", "block_n",
                                             "interpret"))
def slice_raster(u0, v0, px, lvl, val, ok, *, resolution: int,
                 block_n: int = DEFAULT_BLOCK_N, interpret: bool = False):
    """Rasterize the slice from a padded (1, N) leaf table.

    ``ok`` already folds leaf/owner/plane-hit/padding; N must be a
    multiple of ``block_n`` (ops.py pads). Returns the (R, R) image
    (deepest-covering-leaf semantics, NaN where uncovered).
    """
    n = u0.shape[-1]
    assert n % block_n == 0, f"N={n} not padded to {block_n}"
    grid = (n // block_n,)
    tbl = pl.BlockSpec((1, block_n), lambda i: (0, i))
    out = pl.BlockSpec((resolution, resolution), lambda i: (0, 0))
    img, _ = pl.pallas_call(
        functools.partial(_slice_kernel, block_n=block_n,
                          resolution=resolution),
        grid=grid,
        in_specs=[tbl] * 6,
        out_specs=[out, out],
        out_shape=[
            jax.ShapeDtypeStruct((resolution, resolution), val.dtype),
            jax.ShapeDtypeStruct((resolution, resolution), jnp.int32),
        ],
        interpret=interpret,
    )(u0, v0, px, lvl, val, ok)
    return img


@functools.partial(jax.jit, static_argnames=("resolution", "block_n",
                                             "interpret"))
def slice_raster_carry(u0, v0, px, lvl, val, ok, img0, depth0, *,
                       resolution: int, block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = False):
    """Seeded slice raster: paint one leaf-table tile over (img0, depth0).

    Returns the updated ``(image, depth)`` pair. Seeding with an all-NaN
    image and an all ``-1`` depth reproduces :func:`slice_raster` while
    also returning the depth buffer (the mesh path's depth-resolve merge
    needs it); chaining tiles in BFS order is bit-identical to one call
    over the concatenated table.
    """
    n = u0.shape[-1]
    assert n % block_n == 0, f"N={n} not padded to {block_n}"
    grid = (n // block_n,)
    tbl = pl.BlockSpec((1, block_n), lambda i: (0, i))
    out = pl.BlockSpec((resolution, resolution), lambda i: (0, 0))
    img, depth = pl.pallas_call(
        functools.partial(_slice_carry_kernel, block_n=block_n,
                          resolution=resolution),
        grid=grid,
        in_specs=[tbl] * 6 + [out, out],
        out_specs=[out, out],
        out_shape=[
            jax.ShapeDtypeStruct((resolution, resolution), val.dtype),
            jax.ShapeDtypeStruct((resolution, resolution), jnp.int32),
        ],
        interpret=interpret,
    )(u0, v0, px, lvl, val, ok, img0, depth0)
    return img, depth


# ------------------------------------------------------- projection kernel

def _proj_body(u0_ref, v0_ref, px_ref, contrib_ref, ok_ref, img_ref, *,
               block_n: int, resolution: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (resolution, resolution), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (resolution, resolution), 1)

    def body(i, _):
        u0, v0, px = u0_ref[0, i], v0_ref[0, i], px_ref[0, i]
        contrib, ok = contrib_ref[0, i], ok_ref[0, i]
        mask = ((rows >= u0) & (rows < u0 + px)
                & (cols >= v0) & (cols < v0 + px) & (ok != 0))
        # where-guarded add: pixels outside the rectangle are untouched
        # (no +0.0), and per-pixel adds run in BFS leaf order — the same
        # float accumulation sequence as the host reducer
        img_ref[...] = jnp.where(mask, img_ref[...] + contrib, img_ref[...])
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)


def _proj_kernel(u0_ref, v0_ref, px_ref, contrib_ref, ok_ref, img_ref, *,
                 block_n: int, resolution: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        img_ref[...] = jnp.zeros((resolution, resolution), img_ref.dtype)

    _proj_body(u0_ref, v0_ref, px_ref, contrib_ref, ok_ref, img_ref,
               block_n=block_n, resolution=resolution)


def _proj_carry_kernel(u0_ref, v0_ref, px_ref, contrib_ref, ok_ref,
                       img0_ref, img_ref, *, block_n: int, resolution: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        img_ref[...] = img0_ref[...]

    _proj_body(u0_ref, v0_ref, px_ref, contrib_ref, ok_ref, img_ref,
               block_n=block_n, resolution=resolution)


@functools.partial(jax.jit, static_argnames=("resolution", "block_n",
                                             "interpret"))
def projection_raster(u0, v0, px, contrib, ok, *, resolution: int,
                      block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = False):
    """Column-density accumulation from a padded (1, N) leaf table.

    ``contrib`` is the per-leaf field·path-length product (value ·
    2^-level, computed upstream so the multiply matches the host path).
    """
    n = u0.shape[-1]
    assert n % block_n == 0, f"N={n} not padded to {block_n}"
    grid = (n // block_n,)
    tbl = pl.BlockSpec((1, block_n), lambda i: (0, i))
    out = pl.BlockSpec((resolution, resolution), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_proj_kernel, block_n=block_n,
                          resolution=resolution),
        grid=grid,
        in_specs=[tbl] * 5,
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((resolution, resolution),
                                       contrib.dtype),
        interpret=interpret,
    )(u0, v0, px, contrib, ok)


@functools.partial(jax.jit, static_argnames=("resolution", "block_n",
                                             "interpret"))
def projection_raster_carry(u0, v0, px, contrib, ok, img0, *,
                            resolution: int, block_n: int = DEFAULT_BLOCK_N,
                            interpret: bool = False):
    """Seeded projection raster: accumulate one tile over ``img0``.

    Per-pixel adds still run in BFS leaf order, so chaining tiles in BFS
    order reproduces :func:`projection_raster` over the concatenated
    table bit for bit (same float accumulation sequence).
    """
    n = u0.shape[-1]
    assert n % block_n == 0, f"N={n} not padded to {block_n}"
    grid = (n // block_n,)
    tbl = pl.BlockSpec((1, block_n), lambda i: (0, i))
    out = pl.BlockSpec((resolution, resolution), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_proj_carry_kernel, block_n=block_n,
                          resolution=resolution),
        grid=grid,
        in_specs=[tbl] * 5 + [out],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((resolution, resolution),
                                       contrib.dtype),
        interpret=interpret,
    )(u0, v0, px, contrib, ok, img0)


# -------------------------------------------------------- histogram kernel

def _hist_kernel(val_ref, lvl_ref, ok_ref, edges_ref, hist_ref, *,
                 n_levels: int, bins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros((n_levels, bins), jnp.int32)

    v = val_ref[0, :]                       # (BLOCK,)
    lvl = lvl_ref[0, :].astype(jnp.int32)
    edges = edges_ref[0, :]                 # (bins + 1,)
    # searchsorted(edges, v, side="right") == #edges <= v, vectorized as
    # an edge-compare reduction (no in-kernel gather/scatter)
    idx = jnp.sum((edges[:, None] <= v[None, :]).astype(jnp.int32),
                  axis=0, dtype=jnp.int32) - 1
    b = jnp.where(v == edges[-1], bins - 1, idx)    # top edge inclusive
    good = ((ok_ref[0, :] != 0) & (v >= edges[0]) & (v <= edges[-1])
            & (lvl >= 0) & (lvl < n_levels))
    flat = jnp.where(good, lvl * bins + b, -1)      # (BLOCK,)
    cells = jax.lax.broadcasted_iota(jnp.int32, (1, n_levels * bins), 1)
    onehot = (flat[:, None] == cells).astype(jnp.int32)   # (BLOCK, L*B)
    hist_ref[...] = hist_ref[...] + jnp.sum(
        onehot, axis=0, dtype=jnp.int32).reshape(n_levels, bins)


@functools.partial(jax.jit, static_argnames=("n_levels", "bins", "block_n",
                                             "interpret"))
def level_hist(val, lvl, ok, edges, *, n_levels: int, bins: int,
               block_n: int = DEFAULT_BLOCK_N, interpret: bool = False):
    """(L, B) per-level histogram via blocked one-hot scatter-add.

    Bin assignment reproduces ``np.histogram(v, bins=edges)`` exactly
    (right-open bins, top edge inclusive, out-of-range excluded);
    integer counts make accumulation order-free.
    """
    n = val.shape[-1]
    assert n % block_n == 0, f"N={n} not padded to {block_n}"
    grid = (n // block_n,)
    tbl = pl.BlockSpec((1, block_n), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_hist_kernel, n_levels=n_levels, bins=bins),
        grid=grid,
        in_specs=[tbl, tbl, tbl,
                  pl.BlockSpec((1, edges.shape[-1]), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n_levels, bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_levels, bins), jnp.int32),
        interpret=interpret,
    )(val, lvl, ok, edges)
