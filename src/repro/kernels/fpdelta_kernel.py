"""Pallas TPU kernel for father–son XOR-delta encoding (paper §2.3).

Hot loop of the codec: XOR each son with its predictor, OR-reduce the
group, count shared leading zeros. The paper runs this sequentially on one
core ("it could be trivially parallelized/vectorized using multiple seed of
father cells values"); here *every father is a seed*: the group axis G maps
to TPU lanes, the S=8 sons map to sublanes — one (8, BG) VMEM tile per
grid step, all-VPU arithmetic, no MXU needed.

CLZ is built from bit-smearing + SWAR popcount (Mosaic has no clz op);
the pure-jnp oracle in ``ref.py`` uses the same formulation.

Layout note: 64-bit payloads travel as (hi, lo) uint32 pairs — TPUs have
no int64 (DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-dim block: multiple of 128 lanes; 8 sublanes = one int32 tile.
DEFAULT_BLOCK_G = 1024


def _clz32(x):
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    pop = (x * jnp.uint32(0x01010101)) >> 24
    return (jnp.uint32(32) - pop).astype(jnp.int32)


def _encode_kernel(pred_hi_ref, pred_lo_ref, son_hi_ref, son_lo_ref,
                   res_hi_ref, res_lo_ref, nlz_ref, *, zbits: int, width: int):
    res_hi = son_hi_ref[...] ^ pred_hi_ref[...]
    res_lo = son_lo_ref[...] ^ pred_lo_ref[...]
    res_hi_ref[...] = res_hi
    res_lo_ref[...] = res_lo
    # OR-reduce over the son (sublane) axis, keepdims for a (1, BG) store.
    m_hi = jnp.bitwise_or.reduce(res_hi, axis=0, keepdims=True)
    m_lo = jnp.bitwise_or.reduce(res_lo, axis=0, keepdims=True)
    if width == 64:
        nlz = jnp.where(m_hi != 0, _clz32(m_hi), 32 + _clz32(m_lo))
    elif width == 32:
        nlz = _clz32(m_lo)
    else:  # 16-bit payload in the low word
        nlz = _clz32(m_lo) - 16
    nlz_ref[...] = jnp.minimum(nlz, (1 << zbits) - 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("zbits", "width", "block_g", "interpret"))
def encode_groups(pred_hi: jnp.ndarray, pred_lo: jnp.ndarray,
                  son_hi: jnp.ndarray, son_lo: jnp.ndarray,
                  *, zbits: int = 4, width: int = 64,
                  block_g: int = DEFAULT_BLOCK_G, interpret: bool = False):
    """Residues + clamped group leading-zero counts.

    Args: (S, G) uint32 arrays (sons on sublanes, groups on lanes); G must
    be padded to a multiple of ``block_g`` by the caller (ops.py does).
    Returns (res_hi (S,G), res_lo (S,G), nlz (1,G) int32).
    """
    s, g = son_hi.shape
    assert g % block_g == 0, f"G={g} not padded to {block_g}"
    grid = (g // block_g,)
    tile = pl.BlockSpec((s, block_g), lambda i: (0, i))
    out_tile = pl.BlockSpec((1, block_g), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_encode_kernel, zbits=zbits, width=width),
        grid=grid,
        in_specs=[tile, tile, tile, tile],
        out_specs=[tile, tile, out_tile],
        out_shape=[
            jax.ShapeDtypeStruct((s, g), jnp.uint32),
            jax.ShapeDtypeStruct((s, g), jnp.uint32),
            jax.ShapeDtypeStruct((1, g), jnp.int32),
        ],
        interpret=interpret,
    )(pred_hi, pred_lo, son_hi, son_lo)


def _decode_kernel(res_hi_ref, res_lo_ref, pred_hi_ref, pred_lo_ref,
                   son_hi_ref, son_lo_ref):
    son_hi_ref[...] = res_hi_ref[...] ^ pred_hi_ref[...]
    son_lo_ref[...] = res_lo_ref[...] ^ pred_lo_ref[...]


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def decode_groups(res_hi: jnp.ndarray, res_lo: jnp.ndarray,
                  pred_hi: jnp.ndarray, pred_lo: jnp.ndarray,
                  *, block_g: int = DEFAULT_BLOCK_G, interpret: bool = False):
    """XOR residues with predictors -> son bit patterns ((S, G) uint32)."""
    s, g = res_hi.shape
    assert g % block_g == 0
    grid = (g // block_g,)
    tile = pl.BlockSpec((s, block_g), lambda i: (0, i))
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((s, g), jnp.uint32),
            jax.ShapeDtypeStruct((s, g), jnp.uint32),
        ],
        interpret=interpret,
    )(res_hi, res_lo, pred_hi, pred_lo)
