"""Public jit'd wrappers for the compression kernels.

Backend selection: ``pallas`` on TPU, ``ref`` (pure jnp, same math) on CPU,
``pallas_interpret`` for kernel-correctness tests. 64-bit payloads travel
as (hi, lo) uint32 pairs; float32/bfloat16 get bitcast convenience entry
points. ``compress_bits`` is the full jit'd encode pipeline (kernel ->
cumsum -> segment-sum packing) used by the speed benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import bitstream as bs
from . import bitpack_kernel, fpdelta_kernel, ref

BLOCK_G = fpdelta_kernel.DEFAULT_BLOCK_G


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str | None) -> str:
    if backend in (None, "auto"):
        return default_backend()
    assert backend in ("pallas", "pallas_interpret", "ref"), backend
    return backend


def _pad_lanes(x: jnp.ndarray, mult: int, fill) -> jnp.ndarray:
    g = x.shape[-1]
    pad = (-g) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], constant_values=fill)


def encode_groups_bits(pred_hi, pred_lo, son_hi, son_lo, *, zbits: int = 4,
                       width: int = 64, backend: str | None = None):
    """Residues + group nlz from (S, G) uint32 bit patterns.

    Pads G internally to the kernel block; returns unpadded (S, G) residues
    and (G,) nlz.
    """
    backend = _resolve(backend)
    s, g = son_hi.shape
    ph = _pad_lanes(jnp.asarray(pred_hi, jnp.uint32), BLOCK_G, 0)
    plo = _pad_lanes(jnp.asarray(pred_lo, jnp.uint32), BLOCK_G, 0)
    sh = _pad_lanes(jnp.asarray(son_hi, jnp.uint32), BLOCK_G, 0)
    slo = _pad_lanes(jnp.asarray(son_lo, jnp.uint32), BLOCK_G, 0)
    if backend == "ref":
        res_hi, res_lo, nlz = ref.group_residues_ref(ph, plo, sh, slo, zbits, width)
        nlz = nlz[None, :]
    else:
        res_hi, res_lo, nlz = fpdelta_kernel.encode_groups(
            ph, plo, sh, slo, zbits=zbits, width=width,
            interpret=(backend == "pallas_interpret"))
    return res_hi[:, :g], res_lo[:, :g], nlz[0, :g] if nlz.ndim == 2 else nlz[:g]


def decode_groups_bits(res_hi, res_lo, pred_hi, pred_lo, *,
                       backend: str | None = None):
    backend = _resolve(backend)
    s, g = res_hi.shape
    rh = _pad_lanes(jnp.asarray(res_hi, jnp.uint32), BLOCK_G, 0)
    rl = _pad_lanes(jnp.asarray(res_lo, jnp.uint32), BLOCK_G, 0)
    ph = _pad_lanes(jnp.asarray(pred_hi, jnp.uint32), BLOCK_G, 0)
    plo = _pad_lanes(jnp.asarray(pred_lo, jnp.uint32), BLOCK_G, 0)
    if backend == "ref":
        sh, slo = ref.decode_residues_ref(rh, rl, ph, plo)
    else:
        sh, slo = fpdelta_kernel.decode_groups(
            rh, rl, ph, plo, interpret=(backend == "pallas_interpret"))
    return sh[:, :g], slo[:, :g]


# --------------------------------------------------------- full pipelines

@functools.partial(jax.jit, static_argnames=("zbits", "width", "backend"))
def compress_bits(pred_hi, pred_lo, son_hi, son_lo, *, zbits: int = 4,
                  width: int = 64, backend: str = "ref"):
    """End-to-end jit'd encode: kernel -> pack codes & payload streams.

    Inputs (S, G) uint32 (G already padded to the kernel block by caller).
    Returns (code_words, payload_words, code_bits, payload_bits); the word
    arrays are sized at their static upper bounds, callers truncate with
    the bit counts.
    """
    s, g = son_hi.shape
    if backend == "ref":
        res_hi, res_lo, nlz = ref.group_residues_ref(
            pred_hi, pred_lo, son_hi, son_lo, zbits, width)
    else:
        res_hi, res_lo, nlz = fpdelta_kernel.encode_groups(
            pred_hi, pred_lo, son_hi, son_lo, zbits=zbits, width=width,
            interpret=(backend == "pallas_interpret"))
        nlz = nlz[0]
    if nlz.ndim == 2:
        nlz = nlz[0]
    code_words, code_bits = bs.pack_bits(
        nlz.astype(jnp.uint32), jnp.full((g,), zbits, jnp.int32),
        num_words=max(1, (g * zbits + 31) // 32))
    nbits = (width - nlz).astype(jnp.int32)
    if width == 64:
        # interleave (lo, hi) entries son-major: [lo00, hi00, lo10, hi10, ...]
        nb = jnp.repeat(nbits[None, :], s, axis=0)            # (S, G)
        lo_bits = jnp.minimum(nb, 32)
        hi_bits = jnp.maximum(nb - 32, 0)
        vals = jnp.stack([res_lo, res_hi], axis=1).reshape(2 * s, g)   # pairs per son
        lens = jnp.stack([lo_bits, hi_bits], axis=1).reshape(2 * s, g)
        # order: group-major then son-major then (lo,hi): transpose to (G, S*2)
        vals = vals.T.reshape(-1)
        lens = lens.T.reshape(-1)
        max_words = max(1, (g * s * 64 + 31) // 32)
    else:
        nb = jnp.minimum(jnp.repeat(nbits[None, :], s, axis=0), width)
        vals = res_lo.T.reshape(-1)
        lens = nb.T.reshape(-1)
        max_words = max(1, (g * s * width + 31) // 32)
    payload_words, payload_bits = bs.pack_bits(vals, lens, num_words=max_words)
    return code_words, payload_words, code_bits, payload_bits


# ------------------------------------------------------------- bitfields

def bitfield_pack(bits, *, backend: str | None = None) -> jnp.ndarray:
    """(N,) {0,1} -> ceil(N/32) uint32 words (bit i of word w = bits[32w+i])."""
    backend = _resolve(backend)
    bits = jnp.asarray(bits).astype(jnp.uint32).reshape(-1)
    n = bits.shape[0]
    pad = (-n) % (32 * bitpack_kernel.DEFAULT_BLOCK_W)
    bits = jnp.pad(bits, (0, pad))
    arr = bits.reshape(-1, 32).T  # (32, W)
    if backend == "ref":
        words = ref.bitpack_ref(arr)[None, :]
    else:
        words = bitpack_kernel.pack(arr, interpret=(backend == "pallas_interpret"))
    return words[0, : (n + 31) // 32]


def bitfield_unpack(words, n: int, *, backend: str | None = None) -> jnp.ndarray:
    backend = _resolve(backend)
    words = jnp.asarray(words, jnp.uint32).reshape(-1)
    pad = (-words.shape[0]) % bitpack_kernel.DEFAULT_BLOCK_W
    words = jnp.pad(words, (0, pad))[None, :]
    if backend == "ref":
        bits = ref.bitunpack_ref(words[0])
    else:
        bits = bitpack_kernel.unpack(words, interpret=(backend == "pallas_interpret"))
    return bits.T.reshape(-1)[:n]


# -------------------------------------------------------- f32 conveniences

def f32_bits(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)


def bits_f32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.uint32), jnp.float32)


def bf16_bits(x: jnp.ndarray) -> jnp.ndarray:
    u16 = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.bfloat16), jnp.uint16)
    return u16.astype(jnp.uint32)


def bits_bf16(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(
        jnp.asarray(x, jnp.uint32).astype(jnp.uint16), jnp.bfloat16)
