"""Public jit'd wrappers for the compression kernels.

Backend selection: ``pallas`` on TPU, ``ref`` (pure jnp, same math) on CPU,
``pallas_interpret`` for kernel-correctness tests. 64-bit payloads travel
as (hi, lo) uint32 pairs; float32/bfloat16 get bitcast convenience entry
points. ``compress_bits`` is the full jit'd encode pipeline (kernel ->
cumsum -> segment-sum packing) used by the speed benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import bitstream as bs
from . import bitpack_kernel, fpdelta_kernel, raster_kernel, ref

BLOCK_G = fpdelta_kernel.DEFAULT_BLOCK_G
BLOCK_N = raster_kernel.DEFAULT_BLOCK_N


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(backend: str | None) -> str:
    if backend in (None, "auto"):
        return default_backend()
    assert backend in ("pallas", "pallas_interpret", "ref"), backend
    return backend


def _pad_lanes(x: jnp.ndarray, mult: int, fill) -> jnp.ndarray:
    g = x.shape[-1]
    pad = (-g) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], constant_values=fill)


def encode_groups_bits(pred_hi, pred_lo, son_hi, son_lo, *, zbits: int = 4,
                       width: int = 64, backend: str | None = None):
    """Residues + group nlz from (S, G) uint32 bit patterns.

    Pads G internally to the kernel block; returns unpadded (S, G) residues
    and (G,) nlz.
    """
    backend = _resolve(backend)
    s, g = son_hi.shape
    ph = _pad_lanes(jnp.asarray(pred_hi, jnp.uint32), BLOCK_G, 0)
    plo = _pad_lanes(jnp.asarray(pred_lo, jnp.uint32), BLOCK_G, 0)
    sh = _pad_lanes(jnp.asarray(son_hi, jnp.uint32), BLOCK_G, 0)
    slo = _pad_lanes(jnp.asarray(son_lo, jnp.uint32), BLOCK_G, 0)
    if backend == "ref":
        res_hi, res_lo, nlz = ref.group_residues_ref(ph, plo, sh, slo, zbits, width)
        nlz = nlz[None, :]
    else:
        res_hi, res_lo, nlz = fpdelta_kernel.encode_groups(
            ph, plo, sh, slo, zbits=zbits, width=width,
            interpret=(backend == "pallas_interpret"))
    return res_hi[:, :g], res_lo[:, :g], nlz[0, :g] if nlz.ndim == 2 else nlz[:g]


def decode_groups_bits(res_hi, res_lo, pred_hi, pred_lo, *,
                       backend: str | None = None):
    backend = _resolve(backend)
    s, g = res_hi.shape
    rh = _pad_lanes(jnp.asarray(res_hi, jnp.uint32), BLOCK_G, 0)
    rl = _pad_lanes(jnp.asarray(res_lo, jnp.uint32), BLOCK_G, 0)
    ph = _pad_lanes(jnp.asarray(pred_hi, jnp.uint32), BLOCK_G, 0)
    plo = _pad_lanes(jnp.asarray(pred_lo, jnp.uint32), BLOCK_G, 0)
    if backend == "ref":
        sh, slo = ref.decode_residues_ref(rh, rl, ph, plo)
    else:
        sh, slo = fpdelta_kernel.decode_groups(
            rh, rl, ph, plo, interpret=(backend == "pallas_interpret"))
    return sh[:, :g], slo[:, :g]


# --------------------------------------------------------- full pipelines

@functools.partial(jax.jit, static_argnames=("zbits", "width", "backend"))
def compress_bits(pred_hi, pred_lo, son_hi, son_lo, *, zbits: int = 4,
                  width: int = 64, backend: str = "ref"):
    """End-to-end jit'd encode: kernel -> pack codes & payload streams.

    Inputs (S, G) uint32 (G already padded to the kernel block by caller).
    Returns (code_words, payload_words, code_bits, payload_bits); the word
    arrays are sized at their static upper bounds, callers truncate with
    the bit counts.
    """
    s, g = son_hi.shape
    if backend == "ref":
        res_hi, res_lo, nlz = ref.group_residues_ref(
            pred_hi, pred_lo, son_hi, son_lo, zbits, width)
    else:
        res_hi, res_lo, nlz = fpdelta_kernel.encode_groups(
            pred_hi, pred_lo, son_hi, son_lo, zbits=zbits, width=width,
            interpret=(backend == "pallas_interpret"))
        nlz = nlz[0]
    if nlz.ndim == 2:
        nlz = nlz[0]
    code_words, code_bits = bs.pack_bits(
        nlz.astype(jnp.uint32), jnp.full((g,), zbits, jnp.int32),
        num_words=max(1, (g * zbits + 31) // 32))
    nbits = (width - nlz).astype(jnp.int32)
    if width == 64:
        # interleave (lo, hi) entries son-major: [lo00, hi00, lo10, hi10, ...]
        nb = jnp.repeat(nbits[None, :], s, axis=0)            # (S, G)
        lo_bits = jnp.minimum(nb, 32)
        hi_bits = jnp.maximum(nb - 32, 0)
        vals = jnp.stack([res_lo, res_hi], axis=1).reshape(2 * s, g)   # pairs per son
        lens = jnp.stack([lo_bits, hi_bits], axis=1).reshape(2 * s, g)
        # order: group-major then son-major then (lo,hi): transpose to (G, S*2)
        vals = vals.T.reshape(-1)
        lens = lens.T.reshape(-1)
        max_words = max(1, (g * s * 64 + 31) // 32)
    else:
        nb = jnp.minimum(jnp.repeat(nbits[None, :], s, axis=0), width)
        vals = res_lo.T.reshape(-1)
        lens = nb.T.reshape(-1)
        max_words = max(1, (g * s * width + 31) // 32)
    payload_words, payload_bits = bs.pack_bits(vals, lens, num_words=max_words)
    return code_words, payload_words, code_bits, payload_bits


# ------------------------------------------------------------- bitfields

def bitfield_pack(bits, *, backend: str | None = None) -> jnp.ndarray:
    """(N,) {0,1} -> ceil(N/32) uint32 words (bit i of word w = bits[32w+i])."""
    backend = _resolve(backend)
    bits = jnp.asarray(bits).astype(jnp.uint32).reshape(-1)
    n = bits.shape[0]
    pad = (-n) % (32 * bitpack_kernel.DEFAULT_BLOCK_W)
    bits = jnp.pad(bits, (0, pad))
    arr = bits.reshape(-1, 32).T  # (32, W)
    if backend == "ref":
        words = ref.bitpack_ref(arr)[None, :]
    else:
        words = bitpack_kernel.pack(arr, interpret=(backend == "pallas_interpret"))
    return words[0, : (n + 31) // 32]


def bitfield_unpack(words, n: int, *, backend: str | None = None) -> jnp.ndarray:
    backend = _resolve(backend)
    words = jnp.asarray(words, jnp.uint32).reshape(-1)
    pad = (-words.shape[0]) % bitpack_kernel.DEFAULT_BLOCK_W
    words = jnp.pad(words, (0, pad))[None, :]
    if backend == "ref":
        bits = ref.bitunpack_ref(words[0])
    else:
        bits = bitpack_kernel.unpack(words, interpret=(backend == "pallas_interpret"))
    return bits.T.reshape(-1)[:n]


# ------------------------------------------------------- AMR rasterization
#
# Device-reduction entry points (DESIGN.md §14): each takes flat BFS
# node arrays — coords (N, 3) int, levels (N,) int, values (N,) float,
# ok (N,) bool (leaf ∧ owner ∧ not-padding) — plus the reducer params,
# and returns the reduced object with bits identical to the host numpy
# reducers (``insitu.reducers``/``hercule.analysis``). ``resolution``
# must be a power of two (the integer pixel-geometry fast path;
# ``insitu.device`` falls back to host reducers otherwise). ``ref`` is
# the fast vectorized CPU path, ``pallas``/``pallas_interpret`` run the
# raster kernels.

def _axes_uv(axis: int) -> tuple[int, int]:
    ax_u, ax_v = (a for a in range(3) if a != axis)
    return ax_u, ax_v


def _pad_leaf(x, fill, block_n: int):
    return _pad_lanes(x[None, :], block_n, fill)


def _assert_pow2(resolution: int) -> None:
    if resolution <= 0 or resolution & (resolution - 1):
        raise ValueError(
            f"raster kernels need a power-of-two resolution, got "
            f"{resolution} (use the host reducer for arbitrary sizes)")


@functools.partial(jax.jit, static_argnames=(
    "axis", "position", "resolution", "n_levels", "backend", "block_n"))
def raster_slice(coords, levels, values, ok, *, axis: int, position: float,
                 resolution: int, n_levels: int, backend: str | None = None,
                 block_n: int = BLOCK_N):
    """Axis-aligned slice image (deepest covering leaf, NaN elsewhere)."""
    backend = _resolve(backend)
    _assert_pow2(resolution)
    ax_u, ax_v = _axes_uv(axis)
    coords2 = jnp.stack([coords[:, ax_u], coords[:, ax_v]], 1
                        ).astype(jnp.int32)
    levels = levels.astype(jnp.int32)
    if backend == "ref":
        return ref.slice_raster_ref(
            coords2, coords[:, axis], levels, values, ok,
            position=position, resolution=resolution, n_levels=n_levels)
    hit = raster_kernel.plane_hit(coords[:, axis], levels, position,
                                  values.dtype)
    u0, v0, px = raster_kernel.leaf_table(coords2, levels,
                                          resolution=resolution)
    good = (ok & hit).astype(jnp.int32)
    return raster_kernel.slice_raster(
        _pad_leaf(u0, 0, block_n), _pad_leaf(v0, 0, block_n),
        _pad_leaf(px, 1, block_n), _pad_leaf(levels, 0, block_n),
        _pad_leaf(values, 0, block_n), _pad_leaf(good, 0, block_n),
        resolution=resolution, block_n=block_n,
        interpret=(backend == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=(
    "axis", "resolution", "n_levels", "backend", "block_n"))
def raster_projection(coords, levels, values, ok, *, axis: int,
                      resolution: int, n_levels: int,
                      backend: str | None = None, block_n: int = BLOCK_N):
    """Column density: per-leaf value · path length summed along ``axis``."""
    backend = _resolve(backend)
    _assert_pow2(resolution)
    ax_u, ax_v = _axes_uv(axis)
    coords2 = jnp.stack([coords[:, ax_u], coords[:, ax_v]], 1
                        ).astype(jnp.int32)
    levels = levels.astype(jnp.int32)
    if backend == "ref":
        return ref.projection_raster_ref(
            coords2, levels, values, ok, resolution=resolution,
            n_levels=n_levels)
    u0, v0, px = raster_kernel.leaf_table(coords2, levels,
                                          resolution=resolution)
    size = jnp.asarray(2.0, values.dtype) ** (-levels.astype(values.dtype))
    contrib = values * size          # the host reducer's v[sel] * size
    return raster_kernel.projection_raster(
        _pad_leaf(u0, 0, block_n), _pad_leaf(v0, 0, block_n),
        _pad_leaf(px, 1, block_n), _pad_leaf(contrib, 0, block_n),
        _pad_leaf(ok.astype(jnp.int32), 0, block_n),
        resolution=resolution, block_n=block_n,
        interpret=(backend == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("n_levels", "backend",
                                             "block_n"))
def raster_level_hist(values, levels, ok, edges, *, n_levels: int,
                      backend: str | None = None, block_n: int = BLOCK_N):
    """(n_levels, bins) int64 per-level histogram over ``edges``."""
    backend = _resolve(backend)
    levels = levels.astype(jnp.int32)
    if backend == "ref":
        hist = ref.level_hist_ref(values, levels, ok, edges,
                                  n_levels=n_levels)
    else:
        hist = raster_kernel.level_hist(
            _pad_leaf(values, jnp.nan if values.dtype.kind == "f" else 0,
                      block_n),
            _pad_leaf(levels, 0, block_n),
            _pad_leaf(ok.astype(jnp.int32), 0, block_n),
            edges[None, :], n_levels=n_levels, bins=edges.shape[-1] - 1,
            block_n=block_n, interpret=(backend == "pallas_interpret"))
    return hist.astype(jnp.int64)


# ------------------------------------------- partial (sharded/tiled) rasters
#
# Building blocks for the mesh path (``insitu.mesh_reduce``): rasterize
# an arbitrary BFS-ordered *subset* of the leaf table into a partial
# image — callers merge partials on-device (depth-resolve / ordered sum
# / psum). Unlike the full entry points these are not jitted here: they
# run inside the caller's ``shard_map``/jit. ``tile_n`` enables the
# tiled-gather formulation: the table is processed in fixed-size tiles
# gathered with ``dynamic_slice``, carrying the partial image between
# tiles — one compiled kernel at the tile shape serves any table length
# (bounded retraces) and the kernel working set stays at the padded
# bucket budget. Chaining tiles in BFS order is bit-identical to the
# single-shot call (see the carry kernels / seeded oracles).

def _ceil_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _pad_rows(x, n_to: int, fill):
    n = x.shape[0]
    if n == n_to:
        return x
    width = [(0, n_to - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=fill)


def raster_slice_partial(coords, levels, values, ok, *, axis: int,
                         position: float, resolution: int, n_levels: int,
                         backend: str | None = None, block_n: int = BLOCK_N,
                         tile_n: int | None = None):
    """Partial slice raster: returns ``(image, depth)`` for a leaf subset.

    ``depth`` is the painting leaf's level (-1 where uncovered) — the
    on-device depth-resolve merge key. Seeding an all-NaN/-1 pair and
    running the full table reproduces :func:`raster_slice` bit for bit.
    """
    backend = _resolve(backend)
    _assert_pow2(resolution)
    ax_u, ax_v = _axes_uv(axis)
    coords2 = jnp.stack([coords[:, ax_u], coords[:, ax_v]], 1
                        ).astype(jnp.int32)
    c_axis = coords[:, axis]
    levels = levels.astype(jnp.int32)

    def tile(c2, ca, lv, val, okk, img, depth):
        if backend == "ref":
            return ref.slice_raster_depth_ref(
                c2, ca, lv, val, okk, position=position,
                resolution=resolution, n_levels=n_levels,
                init=(img, depth))
        hit = raster_kernel.plane_hit(ca, lv, position, val.dtype)
        u0, v0, px = raster_kernel.leaf_table(c2, lv, resolution=resolution)
        good = (okk & hit).astype(jnp.int32)
        return raster_kernel.slice_raster_carry(
            _pad_leaf(u0, 0, block_n), _pad_leaf(v0, 0, block_n),
            _pad_leaf(px, 1, block_n), _pad_leaf(lv, 0, block_n),
            _pad_leaf(val, 0, block_n), _pad_leaf(good, 0, block_n),
            img, depth, resolution=resolution, block_n=block_n,
            interpret=(backend == "pallas_interpret"))

    seed = (jnp.full((resolution, resolution), jnp.nan, values.dtype),
            jnp.full((resolution, resolution), -1, jnp.int32))
    return _run_tiles(tile, (coords2, c_axis, levels, values, ok), seed,
                      tile_n=tile_n, block_n=block_n)


def raster_projection_partial(coords, levels, values, ok, *, axis: int,
                              resolution: int, n_levels: int,
                              backend: str | None = None,
                              block_n: int = BLOCK_N,
                              tile_n: int | None = None):
    """Partial projection raster: per-subset column-density image."""
    backend = _resolve(backend)
    _assert_pow2(resolution)
    ax_u, ax_v = _axes_uv(axis)
    coords2 = jnp.stack([coords[:, ax_u], coords[:, ax_v]], 1
                        ).astype(jnp.int32)
    levels = levels.astype(jnp.int32)

    def tile(c2, lv, val, okk, img):
        if backend == "ref":
            return (ref.projection_raster_ref(
                c2, lv, val, okk, resolution=resolution,
                n_levels=n_levels, init=img),)
        u0, v0, px = raster_kernel.leaf_table(c2, lv, resolution=resolution)
        size = jnp.asarray(2.0, val.dtype) ** (-lv.astype(val.dtype))
        contrib = val * size
        return (raster_kernel.projection_raster_carry(
            _pad_leaf(u0, 0, block_n), _pad_leaf(v0, 0, block_n),
            _pad_leaf(px, 1, block_n), _pad_leaf(contrib, 0, block_n),
            _pad_leaf(okk.astype(jnp.int32), 0, block_n),
            img, resolution=resolution, block_n=block_n,
            interpret=(backend == "pallas_interpret")),)

    seed = (jnp.zeros((resolution, resolution), values.dtype),)
    return _run_tiles(tile, (coords2, levels, values, ok), seed,
                      tile_n=tile_n, block_n=block_n)[0]


def raster_level_hist_partial(values, levels, ok, edges, *, n_levels: int,
                              backend: str | None = None,
                              block_n: int = BLOCK_N):
    """Partial per-level histogram: (L, B) int32 counts for a subset.

    Integer counts are order-free, so partials merge with ``psum``. No
    ``tile_n``: the kernel's grid already streams the table block by
    block with an O(L·B) working set.
    """
    backend = _resolve(backend)
    levels = levels.astype(jnp.int32)
    if backend == "ref":
        return ref.level_hist_ref(values, levels, ok, edges,
                                  n_levels=n_levels)
    return raster_kernel.level_hist(
        _pad_leaf(values, jnp.nan if values.dtype.kind == "f" else 0,
                  block_n),
        _pad_leaf(levels, 0, block_n),
        _pad_leaf(ok.astype(jnp.int32), 0, block_n),
        edges[None, :], n_levels=n_levels, bins=edges.shape[-1] - 1,
        block_n=block_n, interpret=(backend == "pallas_interpret"))


def _run_tiles(tile_fn, arrays, seed, *, tile_n: int | None, block_n: int):
    """Drive ``tile_fn`` over the table once, or tiled with a carry."""
    n = arrays[0].shape[0]
    if tile_n is None or n <= tile_n:
        return tile_fn(*arrays, *seed)
    if tile_n % block_n:
        raise ValueError(f"tile_n={tile_n} not a multiple of "
                         f"block_n={block_n}")
    tiles = -(-n // tile_n)
    padded = [_pad_rows(a, tiles * tile_n,
                        False if a.dtype == jnp.bool_ else 0)
              for a in arrays]

    def body(t, carry):
        cut = [jax.lax.dynamic_slice_in_dim(a, t * tile_n, tile_n, 0)
               for a in padded]
        return tuple(tile_fn(*cut, *carry))

    return jax.lax.fori_loop(0, tiles, body, tuple(seed))


# -------------------------------------------------------- f32 conveniences

def f32_bits(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)


def bits_f32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.uint32), jnp.float32)


def bf16_bits(x: jnp.ndarray) -> jnp.ndarray:
    u16 = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.bfloat16), jnp.uint16)
    return u16.astype(jnp.uint32)


def bits_bf16(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(
        jnp.asarray(x, jnp.uint32).astype(jnp.uint16), jnp.bfloat16)
