"""Pallas TPU kernel: boolean <-> bitfield packing (paper §2.2 substrate).

The boolean refinement/ownership arrays are compared against (and, before
RLE, stored as) bitfields. Packing 32 boolean sublanes into one uint32 word
per lane is a pure-VPU shift-and-accumulate over an (32, BW) VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 1024


def _pack_kernel(bits_ref, words_ref):
    bits = bits_ref[...].astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 0)
    words_ref[...] = jnp.sum(bits << shifts, axis=0, keepdims=True,
                             dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def pack(bits: jnp.ndarray, *, block_w: int = DEFAULT_BLOCK_W,
         interpret: bool = False) -> jnp.ndarray:
    """(32, W) {0,1} uint32 -> (1, W) uint32 words; W padded to block_w."""
    s, w = bits.shape
    assert s == 32 and w % block_w == 0
    grid = (w // block_w,)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((32, block_w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint32),
        interpret=interpret,
    )(bits)


def _unpack_kernel(words_ref, bits_ref):
    words = words_ref[...]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, words.shape[1]), 0)
    bits_ref[...] = (jnp.broadcast_to(words, (32, words.shape[1])) >> shifts) & jnp.uint32(1)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def unpack(words: jnp.ndarray, *, block_w: int = DEFAULT_BLOCK_W,
           interpret: bool = False) -> jnp.ndarray:
    """(1, W) uint32 words -> (32, W) {0,1} uint32."""
    _, w = words.shape
    assert w % block_w == 0
    grid = (w // block_w,)
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((32, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((32, w), jnp.uint32),
        interpret=interpret,
    )(words)
