"""Pure-jnp oracles for the Pallas kernels (also the fast CPU path)."""
from __future__ import annotations

import jax.numpy as jnp


def clz32_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Count leading zeros of uint32 via bit-smear + SWAR popcount.

    Identical to the kernel's formulation so both lower to the same ops on
    TPU (Mosaic has no native clz; jax.lax.clz is avoided on purpose).
    """
    x = x.astype(jnp.uint32)
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    # SWAR popcount
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    pop = (x * jnp.uint32(0x01010101)) >> 24
    return (jnp.uint32(32) - pop).astype(jnp.int32)


def group_residues_ref(pred_hi, pred_lo, son_hi, son_lo, zbits: int, width: int):
    """Oracle for the fpdelta encode kernel.

    Layout is (S, G): sons down the sublane axis, groups across lanes
    (TPU-native — see DESIGN.md §2). Returns res_hi, res_lo (S, G) and the
    clamped shared-leading-zero count nlz (G,).
    """
    res_hi = son_hi ^ pred_hi
    res_lo = son_lo ^ pred_lo
    m_hi = jnp.bitwise_or.reduce(res_hi, axis=0)
    m_lo = jnp.bitwise_or.reduce(res_lo, axis=0)
    if width == 64:
        nlz = jnp.where(m_hi != 0, clz32_ref(m_hi), 32 + clz32_ref(m_lo))
    elif width == 32:
        nlz = clz32_ref(m_lo)
    else:
        nlz = clz32_ref(m_lo) - 16
    nlz = jnp.minimum(nlz, (1 << zbits) - 1).astype(jnp.int32)
    return res_hi, res_lo, nlz


def decode_residues_ref(res_hi, res_lo, pred_hi, pred_lo):
    """Oracle for the fpdelta decode kernel (XOR with predictor)."""
    return res_hi ^ pred_hi, res_lo ^ pred_lo


def bitpack_ref(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a (32, W) {0,1} uint32 array into (W,) uint32 words (bit b of
    word w = bits[b, w]) — oracle for the bitpack kernel."""
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[:, None]
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=0,
                   dtype=jnp.uint32)


def bitunpack_ref(words: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`bitpack_ref`: (W,) uint32 -> (32, W) {0,1}."""
    shifts = jnp.arange(32, dtype=jnp.uint32)[:, None]
    return ((words[None, :] >> shifts) & jnp.uint32(1)).astype(jnp.uint32)


# ------------------------------------------------------ raster oracles
#
# Fast CPU twins of raster_kernel.py: vectorized per-level scatters
# instead of the kernels' in-block leaf loop. Bit-identical to the host
# numpy reducers (and to the kernels) by construction: levels are
# processed ascending, per-pixel float updates keep the host's BFS leaf
# order (XLA CPU applies scatter updates sequentially, like np.add.at),
# coarse levels (cell rectangle >= 1 pixel) have unique cell->pixel
# maps so their scatter collapses to one update per pixel, and all
# pixel geometry is the same exact integer arithmetic. Invalid rows are
# dumped into a trailing trash slot instead of masked gathers.

def _level_pix(coords2, resolution: int, lvl: int):
    """Flat full-res pixel index of each node at one level (px == 1)."""
    k = resolution.bit_length() - 1
    u0 = coords2[:, 0] >> (lvl - k) if lvl > k else coords2[:, 0] << (k - lvl)
    v0 = coords2[:, 1] >> (lvl - k) if lvl > k else coords2[:, 1] << (k - lvl)
    return u0 * resolution + v0


def slice_raster_ref_unfused(coords2, c_axis, levels, values, ok, *,
                             position: float, resolution: int,
                             n_levels: int):
    """Per-level-scatter slice oracle (the pre-fusion formulation).

    Kept for the bench's before/after record and as a parity
    cross-check: every level runs a full-table scatter, so the cost is
    ``n_levels`` sequential passes over all N rows —
    :func:`slice_raster_ref` fuses them into one.
    """
    r = resolution
    k = r.bit_length() - 1
    img = jnp.full((r, r), jnp.nan, values.dtype)
    for lvl in range(n_levels):
        size = 1.0 / (1 << lvl)
        lo = c_axis.astype(values.dtype) * size
        sel = ok & (levels == lvl) & (lo <= position) & (position < lo + size)
        if lvl <= k:
            g, px = 1 << lvl, r >> lvl
            idx = jnp.where(sel, coords2[:, 0] * g + coords2[:, 1], g * g)
            coarse = jnp.full(g * g + 1, jnp.nan, values.dtype
                              ).at[idx].set(values)
            painted = jnp.zeros(g * g + 1, bool).at[idx].set(sel)
            up_val = jnp.repeat(jnp.repeat(coarse[:-1].reshape(g, g),
                                           px, 0), px, 1)
            up_hit = jnp.repeat(jnp.repeat(painted[:-1].reshape(g, g),
                                           px, 0), px, 1)
            img = jnp.where(up_hit, up_val, img)
        else:
            idx = jnp.where(sel, _level_pix(coords2, r, lvl), r * r)
            flat = jnp.concatenate(
                [img.reshape(-1), jnp.zeros(1, values.dtype)])
            img = flat.at[idx].set(values)[:-1].reshape(r, r)
    return img


def _slice_pyramid(coords2, c_axis, levels, values, ok, *,
                   position: float, resolution: int, n_levels: int):
    """One fused scatter of every node into a per-level pyramid buffer.

    The per-level formulation above runs ``n_levels`` full-table scatter
    passes (each O(N) sequential updates on CPU) — the dominant cost at
    512² on multi-million-node trees. Here every node computes its own
    (level-base + cell) target up front, so a *single* value scatter and
    a single painted-mask scatter cover all levels; XLA CPU applies the
    duplicate updates (fine levels, trash slot) in row order, preserving
    the BFS later-overrides semantics exactly. Returns the flat value
    buffer, painted buffer and the static per-level base offsets.
    """
    r = resolution
    k = r.bit_length() - 1
    bases, off = [], 0
    for lvl in range(n_levels):
        g = 1 << min(lvl, k)
        bases.append(off)
        off += g * g
    lvl32 = levels.astype(jnp.int32)
    size = jnp.asarray(2.0, values.dtype) ** (-lvl32.astype(values.dtype))
    lo = c_axis.astype(values.dtype) * size
    sel = (ok & (lo <= position) & (position < lo + size)
           & (lvl32 >= 0) & (lvl32 < n_levels))
    safe = jnp.clip(lvl32, 0, n_levels - 1)
    dn = jnp.maximum(safe - k, 0)
    g_l = jnp.int32(1) << jnp.minimum(safe, k)
    cell = ((coords2[:, 0].astype(jnp.int32) >> dn) * g_l
            + (coords2[:, 1].astype(jnp.int32) >> dn))
    base = jnp.asarray(bases, jnp.int32)[safe]
    idx = jnp.where(sel, base + cell, off)
    buf = jnp.full(off + 1, jnp.nan, values.dtype).at[idx].set(values)
    hit = jnp.zeros(off + 1, bool).at[idx].set(sel)
    return buf, hit, bases


def slice_raster_ref(coords2, c_axis, levels, values, ok, *,
                     position: float, resolution: int, n_levels: int):
    """Oracle for the slice kernel: deepest-covering-leaf painting.

    ``coords2`` is the (N, 2) in-plane coords, ``c_axis`` the (N,) coord
    along the slice axis. Resolution must be a power of two. Fused
    single-scatter formulation (see :func:`_slice_pyramid`); composing
    the pyramid coarse-to-fine with a painted-mask ``where`` reproduces
    the per-level ascending overrides bit for bit.
    """
    img, _ = slice_raster_depth_ref(
        coords2, c_axis, levels, values, ok, position=position,
        resolution=resolution, n_levels=n_levels)
    return img


def slice_raster_depth_ref(coords2, c_axis, levels, values, ok, *,
                           position: float, resolution: int, n_levels: int,
                           init=None):
    """Depth-tracking slice oracle, optionally seeded from ``init``.

    Returns ``(image, depth)`` where ``depth`` holds the painting leaf's
    level (-1 where unpainted) — the mesh path's depth-resolve merge and
    the tiled-gather carry both need it. ``init=(img0, depth0)`` seeds
    the paint: a level-``l`` candidate only lands where ``l >= depth0``,
    which is exactly the carry kernel's gate (within one level every
    candidate shares ``l``, so per-pixel gating is uniform and the
    last-set-in-BFS-order winner is unchanged).
    """
    r = resolution
    k = r.bit_length() - 1
    buf, hitbuf, bases = _slice_pyramid(
        coords2, c_axis, levels, values, ok, position=position,
        resolution=resolution, n_levels=n_levels)
    if init is None:
        img = jnp.full((r, r), jnp.nan, values.dtype)
        depth = jnp.full((r, r), -1, jnp.int32)
    else:
        img, depth = init
    for lvl in range(n_levels):
        g = 1 << min(lvl, k)
        px = r // g
        grid = buf[bases[lvl]:bases[lvl] + g * g].reshape(g, g)
        hit = hitbuf[bases[lvl]:bases[lvl] + g * g].reshape(g, g)
        up_val = jnp.repeat(jnp.repeat(grid, px, 0), px, 1)
        up_hit = jnp.repeat(jnp.repeat(hit, px, 0), px, 1)
        take = up_hit & (lvl >= depth)
        img = jnp.where(take, up_val, img)
        depth = jnp.where(take, jnp.int32(lvl), depth)
    return img, depth


def projection_raster_ref(coords2, levels, values, ok, *,
                          resolution: int, n_levels: int, init=None):
    """Oracle for the projection kernel: field * path-length column sum.

    Unlike the slice, a projection collapses one axis: several leaves
    of the *same* level can land on the same pixel (they differ along
    the projection axis), so per-pixel adds must run leaf by leaf in
    BFS order to match the host reducer's float accumulation. At coarse
    levels (cell rectangle >= 1 pixel) the scatter-add therefore
    targets a **coarse view** of the running image — exact, because all
    earlier (coarser) levels wrote values constant over this level's
    cells — and the result is replicated back; XLA CPU applies the
    scatter's duplicate updates in order, like ``np.add.at``.

    ``init`` seeds the accumulator (tiled-gather carry). The coarse
    view then requires the seed to be constant over the cells this
    pass actually *touches* — true for tile chaining, where the seed is
    the same rasterization's earlier-tile partial (BFS order ⇒ the seed
    holds only coarser-or-equal levels than any selected row); cells no
    selected row touches keep their pixels verbatim instead.
    """
    r = resolution
    k = r.bit_length() - 1
    img = jnp.zeros((r, r), values.dtype) if init is None else init
    zero = jnp.zeros((), values.dtype)
    for lvl in range(n_levels):
        sel = ok & (levels == lvl)
        contrib = values * jnp.asarray(1.0 / (1 << lvl), values.dtype)
        if lvl <= k:
            g, px = 1 << lvl, r >> lvl
            idx = jnp.where(sel, coords2[:, 0] * g + coords2[:, 1], g * g)
            flat = jnp.concatenate([img[::px, ::px].reshape(-1),
                                    jnp.zeros(1, values.dtype)])
            flat = flat.at[idx].add(jnp.where(sel, contrib, zero))
            # replicate only into cells some selected leaf touched: an
            # untouched cell keeps its running pixels verbatim, so a
            # carry seed holding *finer* levels than this pass (tile
            # chaining starts the level loop from 0 every tile) is never
            # flattened to its top-left subsample
            hit = jnp.zeros(g * g + 1, bool).at[idx].max(sel)
            up = jnp.repeat(jnp.repeat(flat[:-1].reshape(g, g), px, 0),
                            px, 1)
            uph = jnp.repeat(jnp.repeat(hit[:-1].reshape(g, g), px, 0),
                             px, 1)
            img = jnp.where(uph, up, img)
        else:
            idx = jnp.where(sel, _level_pix(coords2, r, lvl), r * r)
            flat = jnp.concatenate(
                [img.reshape(-1), jnp.zeros(1, values.dtype)])
            img = flat.at[idx].add(jnp.where(sel, contrib, zero)
                                   )[:-1].reshape(r, r)
    return img


def level_hist_ref(values, levels, ok, edges, *, n_levels: int):
    """Oracle for the histogram kernel (np.histogram bin semantics)."""
    bins = edges.shape[-1] - 1
    idx = jnp.searchsorted(edges, values, side="right") - 1
    b = jnp.where(values == edges[-1], bins - 1, idx)
    good = (ok & (values >= edges[0]) & (values <= edges[-1])
            & (levels >= 0) & (levels < n_levels))
    flat = jnp.where(good, levels * bins + b, n_levels * bins)
    hist = jnp.zeros(n_levels * bins + 1, jnp.int32).at[flat].add(
        good.astype(jnp.int32))
    return hist[:-1].reshape(n_levels, bins)
