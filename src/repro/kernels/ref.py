"""Pure-jnp oracles for the Pallas kernels (also the fast CPU path)."""
from __future__ import annotations

import jax.numpy as jnp


def clz32_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Count leading zeros of uint32 via bit-smear + SWAR popcount.

    Identical to the kernel's formulation so both lower to the same ops on
    TPU (Mosaic has no native clz; jax.lax.clz is avoided on purpose).
    """
    x = x.astype(jnp.uint32)
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    # SWAR popcount
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    pop = (x * jnp.uint32(0x01010101)) >> 24
    return (jnp.uint32(32) - pop).astype(jnp.int32)


def group_residues_ref(pred_hi, pred_lo, son_hi, son_lo, zbits: int, width: int):
    """Oracle for the fpdelta encode kernel.

    Layout is (S, G): sons down the sublane axis, groups across lanes
    (TPU-native — see DESIGN.md §2). Returns res_hi, res_lo (S, G) and the
    clamped shared-leading-zero count nlz (G,).
    """
    res_hi = son_hi ^ pred_hi
    res_lo = son_lo ^ pred_lo
    m_hi = jnp.bitwise_or.reduce(res_hi, axis=0)
    m_lo = jnp.bitwise_or.reduce(res_lo, axis=0)
    if width == 64:
        nlz = jnp.where(m_hi != 0, clz32_ref(m_hi), 32 + clz32_ref(m_lo))
    elif width == 32:
        nlz = clz32_ref(m_lo)
    else:
        nlz = clz32_ref(m_lo) - 16
    nlz = jnp.minimum(nlz, (1 << zbits) - 1).astype(jnp.int32)
    return res_hi, res_lo, nlz


def decode_residues_ref(res_hi, res_lo, pred_hi, pred_lo):
    """Oracle for the fpdelta decode kernel (XOR with predictor)."""
    return res_hi ^ pred_hi, res_lo ^ pred_lo


def bitpack_ref(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a (32, W) {0,1} uint32 array into (W,) uint32 words (bit b of
    word w = bits[b, w]) — oracle for the bitpack kernel."""
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[:, None]
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=0,
                   dtype=jnp.uint32)


def bitunpack_ref(words: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`bitpack_ref`: (W,) uint32 -> (32, W) {0,1}."""
    shifts = jnp.arange(32, dtype=jnp.uint32)[:, None]
    return ((words[None, :] >> shifts) & jnp.uint32(1)).astype(jnp.uint32)
