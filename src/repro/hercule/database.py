"""Hercule database: contexts, domains, contributor groups, file rollover.

Layout (paper §2, "one file-for-multiple-processes"):

    <root>/db.json                  database manifest (kind, ncf, limits)
    <root>/data/g<G>_<F>.hrc        group G's F-th physical file; contexts
                                    append until max_file_bytes -> rollover
    <root>/ctx_<STEP>/MANIFEST.json per-context object index (atomic)

A simulation with N contributors and NCF=P creates ceil(N/P) files per
rollover generation — the paper's 16x file-count reduction at NCF=16.
Record index entries carry (file, offset, nbytes, dtype, shape, codec,
codec_meta), making every context self-describing: a reader needs nothing
but this directory.

Crash safety: data bytes are appended + flushed first, the context
manifest is written to a temp file, fsync'd, then atomically renamed.
A context without MANIFEST.json is invisible to readers.

Concurrency model: one writer owns a group file at a time (Hercule's
aggregation — the group leader writes for its contributors), so there is
no shared-file locking; different groups write in parallel threads
(`io_threads`), standing in for Lustre stripe_count = NCF (DESIGN.md §8).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import os
import threading

import numpy as np

_DTYPES = {"bool": np.bool_}


def _dtype_of(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(_DTYPES.get(name, name))


@dataclasses.dataclass
class Record:
    name: str
    domain: int
    file: str
    offset: int
    nbytes: int
    dtype: str
    shape: tuple
    codec: str = "raw"
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self):
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        return d

    @staticmethod
    def from_json(d):
        d = dict(d)
        d["shape"] = tuple(d["shape"])
        return Record(**d)


class _GroupFiles:
    """Append-only physical files of one contributor group, with rollover."""

    def __init__(self, data_dir: str, group: int, max_file_bytes: int):
        self.data_dir = data_dir
        self.group = group
        self.max_file_bytes = max_file_bytes
        self.findex = -1
        self.fh = None
        self.offset = 0
        self.lock = threading.Lock()
        # resume after existing files
        while os.path.exists(self._path(self.findex + 1)):
            self.findex += 1
        if self.findex >= 0:
            self.offset = os.path.getsize(self._path(self.findex))

    def _path(self, fi: int) -> str:
        return os.path.join(self.data_dir, f"g{self.group:05d}_{fi:04d}.hrc")

    def _ensure_open(self):
        if self.fh is None or self.offset >= self.max_file_bytes:
            if self.fh is not None:
                self.fh.close()
                self.fh = None
            if self.findex < 0 or self.offset >= self.max_file_bytes:
                self.findex += 1
                self.offset = 0
            self.fh = open(self._path(self.findex), "ab")
            self.offset = self.fh.tell()

    def append(self, payload: bytes) -> tuple[str, int]:
        """Returns (file basename, offset)."""
        with self.lock:
            self._ensure_open()
            off = self.offset
            self.fh.write(payload)
            self.offset += len(payload)
            return os.path.basename(self._path(self.findex)), off

    def flush(self):
        with self.lock:
            if self.fh is not None:
                self.fh.flush()
                os.fsync(self.fh.fileno())

    def close(self):
        with self.lock:
            if self.fh is not None:
                self.fh.close()
                self.fh = None


class HerculeDB:
    """One Hercule database (HProt or HDep flavor via ``kind``)."""

    def __init__(self, root: str, manifest: dict):
        self.root = root
        self.kind = manifest["kind"]
        self.ncf = int(manifest["ncf"])
        self.max_file_bytes = int(manifest["max_file_bytes"])
        self.io_threads = int(manifest.get("io_threads", 4))
        self._groups: dict[int, _GroupFiles] = {}
        self._glock = threading.Lock()
        os.makedirs(os.path.join(root, "data"), exist_ok=True)

    # ------------------------------------------------------------- setup
    @staticmethod
    def create(root: str, *, kind: str = "hprot", ncf: int = 8,
               max_file_bytes: int = 2 << 30, io_threads: int = 4,
               exist_ok: bool = True) -> "HerculeDB":
        assert kind in ("hprot", "hdep")
        os.makedirs(root, exist_ok=exist_ok)
        manifest = {"kind": kind, "ncf": ncf, "max_file_bytes": max_file_bytes,
                    "io_threads": io_threads, "format_version": 1}
        path = os.path.join(root, "db.json")
        if not os.path.exists(path):
            with open(path, "w") as f:
                json.dump(manifest, f, indent=1)
        return HerculeDB(root, manifest)

    @staticmethod
    def open(root: str) -> "HerculeDB":
        with open(os.path.join(root, "db.json")) as f:
            return HerculeDB(root, json.load(f))

    # ------------------------------------------------------------ groups
    def group_of(self, domain: int) -> int:
        return domain // self.ncf

    def _group_files(self, group: int) -> _GroupFiles:
        with self._glock:
            if group not in self._groups:
                self._groups[group] = _GroupFiles(
                    os.path.join(self.root, "data"), group, self.max_file_bytes)
            return self._groups[group]

    def n_files(self) -> int:
        return len([f for f in os.listdir(os.path.join(self.root, "data"))
                    if f.endswith(".hrc")])

    # ---------------------------------------------------------- contexts
    def begin_context(self, step: int) -> "ContextWriter":
        return ContextWriter(self, step)

    def contexts(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("ctx_") and os.path.exists(
                    os.path.join(self.root, d, "MANIFEST.json")):
                out.append(int(d[4:]))
        return sorted(out)

    def latest_context(self) -> int | None:
        cs = self.contexts()
        return cs[-1] if cs else None

    def _ctx_dir(self, step: int) -> str:
        return os.path.join(self.root, f"ctx_{step:08d}")

    def load_index(self, step: int) -> dict:
        with open(os.path.join(self._ctx_dir(step), "MANIFEST.json")) as f:
            raw = json.load(f)
        return {"step": raw["step"],
                "attrs": raw.get("attrs", {}),
                "records": [Record.from_json(r) for r in raw["records"]]}

    # ------------------------------------------------------------ reading
    def read_payload(self, rec: Record) -> bytes:
        with open(os.path.join(self.root, "data", rec.file), "rb") as f:
            f.seek(rec.offset)
            return f.read(rec.nbytes)

    def read(self, step: int, domain: int, name: str) -> np.ndarray:
        idx = self.load_index(step)
        for rec in idx["records"]:
            if rec.domain == domain and rec.name == name:
                return decode_record(self, rec)
        raise KeyError(f"({domain}, {name}) not in context {step}")

    def records(self, step: int, name: str | None = None,
                domain: int | None = None) -> list[Record]:
        idx = self.load_index(step)
        return [r for r in idx["records"]
                if (name is None or r.name == name)
                and (domain is None or r.domain == domain)]

    def close(self):
        for g in self._groups.values():
            g.close()


class ContextWriter:
    """Writer for one context; thread-safe across domains/groups."""

    def __init__(self, db: HerculeDB, step: int):
        self.db = db
        self.step = step
        self.records: list[Record] = []
        self.attrs: dict = {}
        self._lock = threading.Lock()
        self._pool = cf.ThreadPoolExecutor(max_workers=db.io_threads,
                                           thread_name_prefix="hercule-io")
        self._futures: list[cf.Future] = []
        os.makedirs(db._ctx_dir(step), exist_ok=True)

    # ------------------------------------------------------------- write
    def write_bytes(self, domain: int, name: str, payload: bytes, *,
                    dtype: str = "uint8", shape: tuple | None = None,
                    codec: str = "raw", meta: dict | None = None) -> None:
        group = self.db.group_of(domain)
        gf = self.db._group_files(group)
        fname, off = gf.append(payload)
        rec = Record(name=name, domain=domain, file=fname, offset=off,
                     nbytes=len(payload), dtype=dtype,
                     shape=tuple(shape if shape is not None else (len(payload),)),
                     codec=codec, meta=meta or {})
        with self._lock:
            self.records.append(rec)

    def write_array(self, domain: int, name: str, arr: np.ndarray, *,
                    codec: str = "raw", meta: dict | None = None) -> None:
        arr = np.ascontiguousarray(arr)
        self.write_bytes(domain, name, arr.tobytes(), dtype=str(arr.dtype),
                         shape=arr.shape, codec=codec, meta=meta)

    def submit(self, fn, *args) -> None:
        """Queue an I/O closure on the writer pool (parallel group writes)."""
        self._futures.append(self._pool.submit(fn, *args))

    # ---------------------------------------------------------- finalize
    def finalize(self, attrs: dict | None = None) -> None:
        for fut in self._futures:
            fut.result()  # surfaces writer exceptions
        self._pool.shutdown(wait=True)
        for g in self.db._groups.values():
            g.flush()
        manifest = {"step": self.step, "attrs": {**self.attrs, **(attrs or {})},
                    "records": [r.to_json() for r in self.records]}
        path = os.path.join(self.db._ctx_dir(self.step), "MANIFEST.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic commit

    def abort(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)


# ---------------------------------------------------------------- codecs

def decode_record(db: HerculeDB, rec: Record) -> np.ndarray:
    """Decode a record payload according to its codec (self-describing)."""
    payload = db.read_payload(rec)
    if rec.codec == "raw":
        return np.frombuffer(payload, dtype=_dtype_of(rec.dtype)).reshape(rec.shape).copy()
    if rec.codec == "boolrle":
        from ..core import boolcodec
        return boolcodec.decode(payload, n=int(np.prod(rec.shape))).reshape(rec.shape)
    if rec.codec in ("fpdelta-pyramid", "fpdelta-delta"):
        from . import codecs
        return codecs.decode(db, rec, payload)
    raise ValueError(f"unknown codec {rec.codec!r}")
