"""Hercule database: contexts, domains, contributor groups, file rollover.

Layout (paper §2, "one file-for-multiple-processes"):

    <root>/db.json                  database manifest (kind, ncf, limits)
    <root>/data/g<G>_<F>.hrc        group G's F-th physical file; contexts
                                    append until max_file_bytes -> rollover
    <root>/ctx_<STEP>/MANIFEST.json per-context object index (atomic)

A simulation with N contributors and NCF=P creates ceil(N/P) files per
rollover generation — the paper's 16x file-count reduction at NCF=16.
Record index entries carry (file, offset, nbytes, dtype, shape, codec,
codec_meta), making every context self-describing: a reader needs nothing
but this directory.

Crash safety: data bytes are appended + flushed first, the context
manifest is written to a temp file, fsync'd, then atomically renamed.
A context without MANIFEST.json is invisible to readers.

Concurrency model: one writer owns a group file at a time (Hercule's
aggregation — the group leader writes for its contributors), so there is
no shared-file locking; different groups write in parallel threads
(`io_threads`), standing in for Lustre stripe_count = NCF (DESIGN.md §8).
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import json
import os
import re
import threading

import numpy as np

_DTYPES = {"bool": np.bool_}

_CTX_RE = re.compile(r"^ctx_(\d+)$")


def _dtype_of(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(_DTYPES.get(name, name))


# ----------------------------------------------------------- codec registry

@dataclasses.dataclass(frozen=True)
class Codec:
    """One record codec: how payload bytes become an array and back.

    ``decode(db, rec, payload) -> np.ndarray`` must be able to rebuild the
    array from the record alone (codecs with cross-context predictors, like
    ``fpdelta-delta``, may read other contexts through ``db``).
    ``encode(arr, **opts) -> (payload, meta)`` is optional: codecs that
    need out-of-band structure to encode (e.g. ``fpdelta-tree`` needs the
    AMR tree) are write-side-only and are driven by their ObjectKind.
    """
    name: str
    decode: object
    encode: object = None


_CODECS: dict[str, Codec] = {}


def register_codec(name: str, *, decode, encode=None) -> Codec:
    """Register (or replace) a record codec under ``name``."""
    codec = Codec(name=name, decode=decode, encode=encode)
    _CODECS[name] = codec
    return codec


def codec_names() -> list[str]:
    """Names of all registered codecs (importing the standard set)."""
    from . import codecs  # noqa: F401  (registers fpdelta-*/pyramid)
    return sorted(_CODECS)


def get_codec(name: str) -> Codec:
    codec = _CODECS.get(name)
    if codec is None:
        # the fpdelta family registers on first import of .codecs; a bare
        # `from repro.hercule.database import ...` may predate that
        from . import codecs  # noqa: F401
        codec = _CODECS.get(name)
    if codec is None:
        raise ValueError(
            f"unknown codec {name!r}; registered codecs: {sorted(_CODECS)}")
    return codec


def _decode_raw(db, rec, payload: bytes) -> np.ndarray:
    return np.frombuffer(payload, dtype=_dtype_of(rec.dtype)) \
        .reshape(rec.shape).copy()


def _encode_raw(arr: np.ndarray) -> tuple[bytes, dict]:
    return np.ascontiguousarray(arr).tobytes(), {}


def _decode_boolrle(db, rec, payload: bytes) -> np.ndarray:
    from ..core import boolcodec
    return boolcodec.decode(payload, n=int(np.prod(rec.shape))) \
        .reshape(rec.shape)


def _encode_boolrle(arr: np.ndarray) -> tuple[bytes, dict]:
    from ..core import boolcodec
    return boolcodec.encode(np.ascontiguousarray(arr, dtype=bool)), {}


register_codec("raw", decode=_decode_raw, encode=_encode_raw)
register_codec("boolrle", decode=_decode_boolrle, encode=_encode_boolrle)


@dataclasses.dataclass
class Record:
    name: str
    domain: int
    file: str
    offset: int
    nbytes: int
    dtype: str
    shape: tuple
    codec: str = "raw"
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self):
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        return d

    @staticmethod
    def from_json(d):
        d = dict(d)
        d["shape"] = tuple(d["shape"])
        return Record(**d)


class _GroupFiles:
    """Append-only physical files of one contributor group, with rollover."""

    def __init__(self, data_dir: str, group: int, max_file_bytes: int):
        self.data_dir = data_dir
        self.group = group
        self.max_file_bytes = max_file_bytes
        self.findex = -1
        self.fh = None
        self.offset = 0
        self.lock = threading.Lock()
        # resume after existing files
        while os.path.exists(self._path(self.findex + 1)):
            self.findex += 1
        if self.findex >= 0:
            self.offset = os.path.getsize(self._path(self.findex))

    def _path(self, fi: int) -> str:
        return os.path.join(self.data_dir, f"g{self.group:05d}_{fi:04d}.hrc")

    def _ensure_open(self):
        if self.fh is None or self.offset >= self.max_file_bytes:
            if self.fh is not None:
                self.fh.close()
                self.fh = None
            if self.findex < 0 or self.offset >= self.max_file_bytes:
                self.findex += 1
                self.offset = 0
            self.fh = open(self._path(self.findex), "ab")
            self.offset = self.fh.tell()

    def append(self, payload: bytes) -> tuple[str, int]:
        """Returns (file basename, offset)."""
        with self.lock:
            self._ensure_open()
            off = self.offset
            self.fh.write(payload)
            self.offset += len(payload)
            return os.path.basename(self._path(self.findex)), off

    def flush(self, sync: bool = True):
        """Flush buffered appends; ``sync=False`` stops at the page cache
        (enough for another process to fsync the file by path)."""
        with self.lock:
            if self.fh is not None:
                self.fh.flush()
                if sync:
                    os.fsync(self.fh.fileno())

    def close(self):
        with self.lock:
            if self.fh is not None:
                self.fh.close()
                self.fh = None


class HerculeDB:
    """One Hercule database (HProt or HDep flavor via ``kind``)."""

    def __init__(self, root: str, manifest: dict):
        self.root = root
        self.kind = manifest["kind"]
        self.ncf = int(manifest["ncf"])
        self.max_file_bytes = int(manifest["max_file_bytes"])
        self.io_threads = int(manifest.get("io_threads", 4))
        self._groups: dict[int, _GroupFiles] = {}
        self._glock = threading.Lock()
        self._views: collections.OrderedDict = collections.OrderedDict()
        self._view_cache_entries = 16
        self._vlock = threading.Lock()
        self._read_pool: cf.ThreadPoolExecutor | None = None
        os.makedirs(os.path.join(root, "data"), exist_ok=True)

    # ------------------------------------------------------------- setup
    @staticmethod
    def create(root: str, *, kind: str = "hprot", ncf: int = 8,
               max_file_bytes: int = 2 << 30, io_threads: int = 4,
               exist_ok: bool = True) -> "HerculeDB":
        assert kind in ("hprot", "hdep")
        os.makedirs(root, exist_ok=exist_ok)
        manifest = {"kind": kind, "ncf": ncf, "max_file_bytes": max_file_bytes,
                    "io_threads": io_threads, "format_version": 1}
        path = os.path.join(root, "db.json")
        if os.path.exists(path):
            # the database already exists: its on-disk manifest governs
            # (the files were laid out under *that* ncf/rollover) — a
            # handle built from the requested parameters would disagree
            # with every other opener about group->file mapping
            with open(path) as f:
                manifest = json.load(f)
        else:
            with open(path, "w") as f:
                json.dump(manifest, f, indent=1)
        return HerculeDB(root, manifest)

    @staticmethod
    def open(root: str) -> "HerculeDB":
        with open(os.path.join(root, "db.json")) as f:
            return HerculeDB(root, json.load(f))

    # ------------------------------------------------------------ groups
    def group_of(self, domain: int) -> int:
        return domain // self.ncf

    def _group_files(self, group: int) -> _GroupFiles:
        with self._glock:
            if group not in self._groups:
                self._groups[group] = _GroupFiles(
                    os.path.join(self.root, "data"), group, self.max_file_bytes)
            return self._groups[group]

    def n_files(self) -> int:
        return len([f for f in os.listdir(os.path.join(self.root, "data"))
                    if f.endswith(".hrc")])

    # ---------------------------------------------------------- contexts
    def begin_context(self, step: int) -> "ContextWriter":
        return ContextWriter(self, step)

    def contexts(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            m = _CTX_RE.match(d)
            # stray ctx_* directories with non-numeric suffixes (editor
            # droppings, aborted tooling) are not contexts: skip them
            if m and os.path.exists(
                    os.path.join(self.root, d, "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_context(self) -> int | None:
        cs = self.contexts()
        return cs[-1] if cs else None

    def _ctx_dir(self, step: int) -> str:
        return os.path.join(self.root, f"ctx_{step:08d}")

    def load_index(self, step: int) -> dict:
        with open(os.path.join(self._ctx_dir(step), "MANIFEST.json")) as f:
            raw = json.load(f)
        return {"step": raw["step"],
                "attrs": raw.get("attrs", {}),
                "records": [Record.from_json(r) for r in raw["records"]]}

    # ------------------------------------------------------------ reading
    def view(self, step: int):
        """Indexed :class:`~repro.hercule.api.ContextView` of one context.

        The context manifest is parsed once and the view cached (contexts
        are immutable once finalized); every read entry point routes
        through here instead of re-parsing MANIFEST.json per read.
        """
        from .api import ContextView
        with self._vlock:
            v = self._views.get(step)
            if v is not None:
                self._views.move_to_end(step)
                return v
        v = ContextView(self, step)
        with self._vlock:
            v = self._views.setdefault(step, v)
            self._views.move_to_end(step)
            while len(self._views) > self._view_cache_entries:
                self._views.popitem(last=False)
        return v

    def _invalidate_view(self, step: int) -> None:
        with self._vlock:
            self._views.pop(step, None)

    def _reader_pool(self) -> cf.ThreadPoolExecutor:
        """Shared decode pool for batched reads (read-path ``io_threads``)."""
        with self._vlock:
            if self._read_pool is None:
                self._read_pool = cf.ThreadPoolExecutor(
                    max_workers=max(1, self.io_threads),
                    thread_name_prefix="hercule-read")
            return self._read_pool

    def flush_domain(self, domain: int, sync: bool = True) -> None:
        """Flush the group file holding ``domain``'s appended records.

        Lets each contributor flush its own group independently (and in
        parallel with other groups) instead of funneling every group's
        fsync through the single finalize call — the finalize flush then
        finds those pages already clean. ``sync=False`` publishes the
        bytes to the page cache only: a lane process hands durability to
        whoever commits the manifest (see :meth:`fsync_files`).
        """
        with self._glock:
            gf = self._groups.get(self.group_of(domain))
        if gf is not None:
            gf.flush(sync)

    def fsync_files(self, names) -> None:
        """fsync data files by basename — bytes another process appended.

        The multi-process lane runtime's finalize hook: each lane flushes
        its appends to the page cache (``flush_domain(sync=False)``) and
        the manifest committer makes exactly the referenced files durable
        before the atomic manifest rename.
        """
        for name in sorted(set(names)):
            fd = os.open(os.path.join(self.root, "data", name), os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)

    def commit_context(self, step: int, records, attrs: dict | None = None
                       ) -> None:
        """Commit a context manifest for records appended elsewhere.

        The HProt manifest commit protocol (DESIGN.md §16): durability
        strictly before visibility. Writer lanes appended the payloads
        and published them to the page cache (``flush_domain(sync=
        False)``); here exactly the data files the manifest references
        are fsynced, then the manifest is written to a temp file,
        fsynced and atomically renamed — a context either commits
        completely or stays invisible to every reader.
        """
        records = list(records)
        # publish any appends made through *this* handle (DomainWriter
        # in the committing process, e.g. a run-ledger flush) to the
        # page cache first — fsync_files syncs by path and would
        # otherwise durably commit a file whose tail still sits in a
        # user-space buffer
        with self._glock:
            groups = list(self._groups.values())
        for g in groups:
            g.flush(sync=False)
        self.fsync_files(r.file for r in records)
        ctx_dir = self._ctx_dir(step)
        os.makedirs(ctx_dir, exist_ok=True)
        manifest = {"step": int(step), "attrs": dict(attrs or {}),
                    "records": [r.to_json() for r in records]}
        path = os.path.join(ctx_dir, "MANIFEST.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._invalidate_view(step)

    def read_payload(self, rec: Record) -> bytes:
        with open(os.path.join(self.root, "data", rec.file), "rb") as f:
            f.seek(rec.offset)
            return f.read(rec.nbytes)

    def read(self, step: int, domain: int, name: str) -> np.ndarray:
        return self.view(step).read(domain, name)

    def records(self, step: int, name: str | None = None,
                domain: int | None = None) -> list[Record]:
        return self.view(step).select(names=name, domains=domain)

    def close(self):
        for g in self._groups.values():
            g.close()
        with self._vlock:
            pool, self._read_pool = self._read_pool, None
            self._views.clear()
        if pool is not None:
            pool.shutdown(wait=True)


class ContextWriter:
    """Writer for one context; thread-safe across domains/groups."""

    def __init__(self, db: HerculeDB, step: int):
        self.db = db
        self.step = step
        self.records: list[Record] = []
        self.attrs: dict = {}
        self._lock = threading.Lock()
        self._pool = cf.ThreadPoolExecutor(max_workers=db.io_threads,
                                           thread_name_prefix="hercule-io")
        self._futures: list[cf.Future] = []
        os.makedirs(db._ctx_dir(step), exist_ok=True)

    # ------------------------------------------------------------- write
    def write_bytes(self, domain: int, name: str, payload: bytes, *,
                    dtype: str = "uint8", shape: tuple | None = None,
                    codec: str = "raw", meta: dict | None = None) -> None:
        group = self.db.group_of(domain)
        gf = self.db._group_files(group)
        fname, off = gf.append(payload)
        rec = Record(name=name, domain=domain, file=fname, offset=off,
                     nbytes=len(payload), dtype=dtype,
                     shape=tuple(shape if shape is not None else (len(payload),)),
                     codec=codec, meta=meta or {})
        with self._lock:
            self.records.append(rec)

    def write_array(self, domain: int, name: str, arr: np.ndarray, *,
                    codec: str = "raw", meta: dict | None = None) -> None:
        arr = np.ascontiguousarray(arr)
        # hand the buffered writer the array's own buffer: no tobytes()
        # memcpy (which would hold the GIL for the whole copy), and the
        # actual write syscall runs GIL-released — parallel contributor
        # lanes overlap their appends
        try:
            payload = arr.data.cast("B")
        except (TypeError, ValueError, BufferError):
            # zero-in-shape views can't cast; extension dtypes
            # (bfloat16) can't export a buffer at all
            payload = arr.tobytes()
        self.write_bytes(domain, name, payload, dtype=str(arr.dtype),
                         shape=arr.shape, codec=codec, meta=meta)

    def submit(self, fn, *args) -> None:
        """Queue an I/O closure on the writer pool (parallel group writes)."""
        self._futures.append(self._pool.submit(fn, *args))

    # ---------------------------------------------------------- finalize
    def finalize(self, attrs: dict | None = None) -> None:
        for fut in self._futures:
            fut.result()  # surfaces writer exceptions
        self._pool.shutdown(wait=True)
        for g in self.db._groups.values():
            g.flush()
        manifest = {"step": self.step, "attrs": {**self.attrs, **(attrs or {})},
                    "records": [r.to_json() for r in self.records]}
        path = os.path.join(self.db._ctx_dir(self.step), "MANIFEST.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic commit
        self.db._invalidate_view(self.step)  # drop any stale cached view

    def abort(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)


class DomainWriter:
    """Record-collecting writer for one contributor's part of a context.

    The multi-process shape of :class:`ContextWriter`: a lane process
    appends its payloads to its own group files and keeps the
    :class:`Record` entries, but the context *manifest* is committed
    elsewhere (the engine collects every lane's records and finalizes
    once). Quacks like ``ContextWriter`` for the ObjectKind writers; no
    thread pool, no context directory, no finalize.
    """

    def __init__(self, db: HerculeDB, step: int):
        self.db = db
        self.step = step
        self.records: list[Record] = []

    def write_bytes(self, domain: int, name: str, payload: bytes, *,
                    dtype: str = "uint8", shape: tuple | None = None,
                    codec: str = "raw", meta: dict | None = None) -> None:
        gf = self.db._group_files(self.db.group_of(domain))
        fname, off = gf.append(payload)
        self.records.append(Record(
            name=name, domain=domain, file=fname, offset=off,
            nbytes=len(payload), dtype=dtype,
            shape=tuple(shape if shape is not None else (len(payload),)),
            codec=codec, meta=meta or {}))

    write_array = ContextWriter.write_array


# ---------------------------------------------------------------- codecs

def decode_record(db: HerculeDB, rec: Record) -> np.ndarray:
    """Decode a record payload according to its codec (self-describing).

    Dispatches through the codec registry — new codecs plug in via
    :func:`register_codec` instead of growing an if-chain here.
    """
    codec = get_codec(rec.codec)
    if codec.decode is None:
        raise ValueError(
            f"codec {rec.codec!r} is not record-decodable on its own; "
            f"it is assembled by its object kind (see repro.hercule.api)")
    return codec.decode(db, rec, db.read_payload(rec))
