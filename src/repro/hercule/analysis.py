"""Post-processing / visualization over HDep databases (paper §4).

The PyMSES-5 + VTK HyperTreeGrid role: assemble per-domain objects into
the global AMR tree, apply threshold filters, extract axis-aligned slices.
VTK is unavailable offline, so the outputs are dense numpy images /
cell lists with the same semantics as the paper's fig. 8 pipeline
(HyperTreeGrid threshold on the density field).
"""
from __future__ import annotations

import numpy as np

from ..core.amr import AMRTree, morton3
from . import api
from .database import HerculeDB


def assemble(trees: list[AMRTree]) -> AMRTree:
    """Merge per-domain (pruned) trees into one global tree.

    Nodes are matched by (level, coords); structure is the union of the
    domains' structures; owned nodes supply field values (ghost copies are
    ignored — the ownership array is exactly the assembly key, paper §2).
    """
    n_levels = max(t.n_levels for t in trees)
    fields = sorted({f for t in trees for f in t.fields})
    out_refine, out_coords, out_fields = [], [], {f: [] for f in fields}
    for lvl in range(n_levels):
        codes_l, ref_l, own_l, coords_l = [], [], [], []
        fields_l = {f: [] for f in fields}
        for t in trees:
            if lvl >= t.n_levels:
                continue
            sl = t.level_slice(lvl)
            if sl.start == sl.stop:
                continue
            codes_l.append(morton3(t.coords[sl]))
            ref_l.append(t.refine[sl])
            own_l.append(t.owner[sl])
            coords_l.append(t.coords[sl])
            for f in fields:
                fields_l[f].append(t.fields[f][sl])
        if not codes_l:
            out_refine.append(np.zeros(0, bool))
            out_coords.append(np.zeros((0, 3), np.int64))
            for f in fields:
                out_fields[f].append(np.zeros(0))
            continue
        codes = np.concatenate(codes_l)
        ref = np.concatenate(ref_l)
        own = np.concatenate(own_l)
        coords = np.concatenate(coords_l)
        # unique codes in Morton order; merge duplicates vectorized:
        # refine = OR over copies; fields prefer the OWNED copy
        uniq, inv = np.unique(codes, return_inverse=True)
        n = uniq.shape[0]
        refine_m = np.zeros(n, bool)
        np.logical_or.at(refine_m, inv, ref)
        # representative row per unique code, owned copies win
        best = np.full(n, -1, np.int64)
        rows = np.arange(codes.shape[0])
        np.maximum.at(best, inv, np.where(own, rows + codes.shape[0], rows))
        best = np.where(best >= codes.shape[0], best - codes.shape[0], best)
        out_refine.append(refine_m)
        out_coords.append(coords[best])
        for f in fields:
            vals = np.concatenate(fields_l[f])
            out_fields[f].append(vals[best])
    # Morton order within a level == BFS order for Morton-grown trees
    # (parent prefix property), so the concatenation below is valid BFS.
    offsets = np.zeros(len(out_refine) + 1, np.int64)
    for i, r in enumerate(out_refine):
        offsets[i + 1] = offsets[i] + r.shape[0]
    tree = AMRTree(refine=np.concatenate(out_refine),
                   owner=np.ones(int(offsets[-1]), bool),
                   level_offsets=offsets,
                   coords=np.concatenate(out_coords),
                   fields={f: np.concatenate(out_fields[f]) for f in fields})
    return tree


def load_global_tree(db: HerculeDB, step: int) -> AMRTree:
    view = db.view(step)
    return assemble([api.AMR_TREE.assemble(view, d)
                     for d in api.AMR_TREE.domains_in(view)])


def threshold(tree: AMRTree, field: str, lo: float = -np.inf,
              hi: float = np.inf) -> dict[str, np.ndarray]:
    """Leaf cells whose field value lies in [lo, hi] (paper fig. 8 filter)."""
    leaves = ~tree.refine
    v = tree.fields[field]
    sel = leaves & (v >= lo) & (v <= hi)
    levels = tree.levels()
    return {"coords": tree.coords[sel], "level": levels[sel],
            "value": v[sel]}


def slice_image(tree: AMRTree, field: str, *, axis: int = 2,
                position: float = 0.5, resolution: int = 256,
                owned_only: bool = False) -> np.ndarray:
    """Rasterize an axis-aligned slice through the AMR tree.

    Each output pixel takes the value of the deepest leaf covering it —
    the HyperTreeGrid slice semantics. With ``owned_only`` only owned
    leaves paint (contributor-partition trees: per-domain images then
    tile by extent back to the global slice, NaN where not owned).
    """
    img = np.full((resolution, resolution), np.nan)
    depth = np.full((resolution, resolution), -1, np.int32)
    levels = tree.levels()
    v = tree.fields[field]
    leaves = np.flatnonzero(~tree.refine)
    if owned_only:
        leaves = leaves[tree.owner[leaves]]
    ax_u, ax_v = [a for a in range(3) if a != axis]
    for lvl in range(tree.n_levels):
        sel = leaves[levels[leaves] == lvl]
        if sel.size == 0:
            continue
        size = 1.0 / (1 << lvl)
        c = tree.coords[sel]
        lo_w = c[:, axis] * size
        hit = (lo_w <= position) & (position < lo_w + size)
        sel = sel[hit]
        if sel.size == 0:
            continue
        c = tree.coords[sel]
        u0 = np.floor(c[:, ax_u] * size * resolution).astype(int)
        v0 = np.floor(c[:, ax_v] * size * resolution).astype(int)
        px = max(1, int(round(size * resolution)))
        for i, node in enumerate(sel):
            uu, vv = u0[i], v0[i]
            img[uu:uu + px, vv:vv + px] = v[node]
            depth[uu:uu + px, vv:vv + px] = lvl
    return img
