"""Unified Hercule object API: typed kinds, indexed views, selectors.

The paper's formats stay useful because every object is *self-describing
and uniformly addressable*; this module is the single data-access layer
the writers, the in-transit reducers and the viewers all share:

  * **ObjectKind registry** — each object flavor (``amr_tree``,
    ``analysis``, ``reduced``, ``ckpt_shard``) declares its record naming
    schema, its write codecs and its assembly logic. Record-name dispatch
    happens here, once, instead of ``startswith(...)`` chains scattered
    through readers.
  * **ContextView** — an indexed handle over one finalized context. The
    manifest is parsed exactly once (views are cached on the database);
    point reads are hash lookups, batched reads fan out on the database's
    ``io_threads`` pool, and domain-merged reads gather one name across
    contributors.
  * **Selector** — one query object (step ranges, name globs, domain
    sets, kind filters) understood by every read flow: the catalog,
    analysis readers, elastic restore and the :func:`scan` iterator.

Name patterns: a ``names`` entry containing ``*`` or ``?`` is a glob
(``fnmatch`` semantics); anything else is an exact match — checkpoint
record names contain ``[``/``]`` from pytree key paths, which must never
be read as character classes.

The legacy ``hdep`` free functions (``read_domain_tree`` & co.) were
deprecation shims over this module until their two-PR countdown ended;
they are now removed — see DESIGN.md §11 for the migration table.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json

import numpy as np

from . import codecs
from .database import (HerculeDB, Record, _dtype_of, decode_record,
                       get_codec, register_codec)

__all__ = [
    "Selector", "as_selector", "ContextView", "ObjectKind", "KINDS",
    "register_kind", "kind_of", "scan", "RecordRef", "read_object",
    "write_object",
]


# ---------------------------------------------------------------- selector

def _has_glob(pattern: str) -> bool:
    return "*" in pattern or "?" in pattern


def _glob_match(name: str, pattern: str) -> bool:
    """fnmatch honoring only ``*``/``?`` — never ``[...]`` classes.

    Record names carry literal brackets from pytree key paths
    (``['params']['w']``); escaping ``[`` keeps a pattern like
    ``analysis/['dense']*`` matching those names literally.
    """
    return fnmatch.fnmatchcase(name, pattern.replace("[", "[[]"))


def _name_tuple(x) -> tuple[str, ...] | None:
    if x is None:
        return None
    if isinstance(x, str):
        return (x,)
    return tuple(str(n) for n in x)


@dataclasses.dataclass(frozen=True)
class Selector:
    """Uniform query over Hercule records.

    ``steps``: an int, a ``range``, or an iterable of ints (None = all).
    ``names``: glob pattern(s) or exact record name(s) (None = all).
    ``domains``: an int or iterable of ints (None = all).
    ``kinds``: ObjectKind name(s) from :data:`KINDS` (None = all).
    """
    steps: object = None
    names: object = None
    domains: object = None
    kinds: object = None

    def __post_init__(self):
        object.__setattr__(self, "names", _name_tuple(self.names))
        if self.domains is not None and not isinstance(self.domains, frozenset):
            doms = (self.domains,) if isinstance(self.domains, int) \
                else self.domains
            object.__setattr__(self, "domains",
                               frozenset(int(d) for d in doms))
        kinds = self.kinds
        if kinds is not None:
            kinds = (kinds,) if isinstance(kinds, str) else tuple(kinds)
            unknown = [k for k in kinds if k not in KINDS]
            if unknown:
                raise ValueError(f"unknown object kind(s) {unknown}; "
                                 f"registered: {sorted(KINDS)}")
            object.__setattr__(self, "kinds", frozenset(kinds))
        if isinstance(self.steps, (int, np.integer)):
            object.__setattr__(self, "steps", (int(self.steps),))
        elif self.steps is not None and not isinstance(self.steps, range):
            object.__setattr__(self, "steps",
                               frozenset(int(s) for s in self.steps))

    # ---------------------------------------------------------- predicates
    def match_step(self, step: int) -> bool:
        return self.steps is None or step in self.steps

    def match_name(self, name: str) -> bool:
        if self.names is None:
            return True
        return any(_glob_match(name, p) if _has_glob(p)
                   else name == p for p in self.names)

    def match(self, rec: Record) -> bool:
        if self.domains is not None and rec.domain not in self.domains:
            return False
        if not self.match_name(rec.name):
            return False
        if self.kinds is not None and kind_of(rec.name).name not in self.kinds:
            return False
        return True


def as_selector(selector=None, **kw) -> Selector:
    """Coerce ``(selector | keyword fields)`` into one Selector."""
    if selector is None:
        return Selector(**kw)
    if not isinstance(selector, Selector):
        raise TypeError(f"expected Selector, got {type(selector).__name__}")
    if kw:
        return dataclasses.replace(selector, **kw)
    return selector


# ------------------------------------------------------------ context view

class ContextView:
    """Indexed read handle over one finalized context.

    Obtained from :meth:`HerculeDB.view`; the manifest is parsed once and
    hash indexes over ``(domain, name)``, ``name`` and ``domain`` are
    built so repeated reads never re-parse or linearly scan the record
    list. Contexts are immutable once finalized, so views never go stale.
    """

    def __init__(self, db: HerculeDB, step: int):
        self.db = db
        self.step = int(step)
        idx = db.load_index(step)
        self.attrs: dict = idx["attrs"]
        self.records: list[Record] = idx["records"]
        self._by_key: dict[tuple[int, str], Record] = {}
        self._by_name: dict[str, list[Record]] = {}
        self._by_domain: dict[int, list[Record]] = {}
        for rec in self.records:
            self._by_key[(rec.domain, rec.name)] = rec
            self._by_name.setdefault(rec.name, []).append(rec)
            self._by_domain.setdefault(rec.domain, []).append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def __repr__(self) -> str:
        return (f"ContextView(step={self.step}, records={len(self.records)}, "
                f"domains={len(self._by_domain)})")

    # ------------------------------------------------------------- lookup
    def record(self, domain: int, name: str) -> Record:
        try:
            return self._by_key[(domain, name)]
        except KeyError:
            raise KeyError(
                f"({domain}, {name}) not in context {self.step}") from None

    def records_named(self, name: str) -> list[Record]:
        """All domains' records for one exact name (manifest order)."""
        return list(self._by_name.get(name, ()))

    def names(self) -> list[str]:
        return sorted(self._by_name)

    def domains(self, name: str | None = None) -> list[int]:
        if name is None:
            return sorted(self._by_domain)
        return sorted(r.domain for r in self._by_name.get(name, ()))

    def kinds(self) -> list[str]:
        """ObjectKind names present in this context."""
        return sorted({kind_of(n).name for n in self._by_name})

    def select(self, selector: Selector | None = None, **kw) -> list[Record]:
        sel = as_selector(selector, **kw)
        if sel.names is not None and sel.domains is None and \
                all(not _has_glob(p) for p in sel.names):
            recs = [r for p in sel.names for r in self._by_name.get(p, ())]
        elif sel.domains is not None and sel.names is None:
            recs = [r for d in sorted(sel.domains)
                    for r in self._by_domain.get(d, ())]
        else:
            recs = self.records
        return [r for r in recs if sel.match(r)]

    # ------------------------------------------------------------- reading
    def read_record(self, rec: Record) -> np.ndarray:
        return decode_record(self.db, rec)

    def read(self, domain: int, name: str) -> np.ndarray:
        """Point read: hash lookup + decode, no manifest re-parse."""
        return self.read_record(self.record(domain, name))

    #: below this aggregate payload size, pool dispatch costs more than the
    #: decode itself (tiny records are GIL-bound); read sequentially
    PARALLEL_MIN_BYTES = 1 << 20

    def read_records(self, recs: list[Record]) -> list[np.ndarray]:
        """Decode a batch, fanning out on the db's read pool when it pays."""
        if len(recs) <= 1 or self.db.io_threads <= 1 or \
                sum(r.nbytes for r in recs) < self.PARALLEL_MIN_BYTES:
            return [self.read_record(r) for r in recs]
        pool = self.db._reader_pool()
        return list(pool.map(self.read_record, recs))

    def read_many(self, items=None, /, selector: Selector | None = None,
                  **kw) -> dict[tuple[int, str], np.ndarray]:
        """Batched multi-record read.

        ``items`` is an iterable of ``(domain, name)`` pairs; alternatively
        pass a :class:`Selector` (or its keyword fields). Decodes run on
        the database's ``io_threads`` pool.
        """
        if items is not None:
            recs = [self.record(d, n) for d, n in items]
        else:
            recs = self.select(selector, **kw)
        arrays = self.read_records(recs)
        return {(r.domain, r.name): a for r, a in zip(recs, arrays)}

    def read_merged(self, name: str, domains=None
                    ) -> dict[int, np.ndarray]:
        """Domain-merged read: one name across contributors.

        Returns ``{domain: array}`` for every (selected) domain holding
        ``name``, decoded in parallel — the building block for merged
        multi-domain reductions.
        """
        recs = self.records_named(name)
        if domains is not None:
            want = {int(d) for d in domains}
            recs = [r for r in recs if r.domain in want]
        arrays = self.read_records(recs)
        return {r.domain: a for r, a in zip(recs, arrays)}


# ------------------------------------------------------------ object kinds

class ObjectKind:
    """One Hercule object flavor: naming schema + codecs + assembly."""

    #: registry key and default ``kind`` filter value
    name: str = ""
    #: record-name prefix owned by this kind ("" = fallback)
    prefix: str = ""

    def match(self, record_name: str) -> bool:
        return bool(self.prefix) and record_name.startswith(self.prefix)

    def parse(self, record_name: str) -> dict:
        """Split a record name into its schema components."""
        return {"name": record_name}

    def write(self, ctx, domain: int, payload, **opts) -> None:
        raise NotImplementedError(f"kind {self.name!r} has no writer")

    def assemble(self, view: ContextView, domain: int = 0, **opts):
        raise NotImplementedError(f"kind {self.name!r} has no assembler")


KINDS: dict[str, ObjectKind] = {}
_FALLBACK_KIND: list[ObjectKind] = []


def register_kind(kind: ObjectKind, *, fallback: bool = False) -> ObjectKind:
    """Register an ObjectKind; ``fallback=True`` marks the catch-all."""
    KINDS[kind.name] = kind
    if fallback:
        _FALLBACK_KIND[:] = [kind]
    return kind


def kind_of(record_name: str) -> ObjectKind:
    """Classify a record name (falls back to the catch-all kind)."""
    for kind in KINDS.values():
        if kind.match(record_name):
            return kind
    if _FALLBACK_KIND:
        return _FALLBACK_KIND[0]
    raise ValueError(f"no object kind matches record {record_name!r}")


def _write_maybe_compressed(ctx, domain: int, name: str, arr: np.ndarray,
                            compress: bool) -> None:
    """Write one tensor raw, or pyramid-compressed when that shrinks it."""
    arr = np.ascontiguousarray(arr)
    if compress and arr.dtype.kind == "f" and arr.size >= 64:
        payload, meta = get_codec("fpdelta-pyramid").encode(arr)
        if len(payload) < arr.nbytes:
            ctx.write_bytes(domain, name, payload, dtype=str(arr.dtype),
                            shape=arr.shape, codec="fpdelta-pyramid",
                            meta=meta)
            return
    ctx.write_array(domain, name, arr)


class AmrTreeKind(ObjectKind):
    """Self-describing per-domain AMR object (paper §2 HDep data model).

    Records: ``amr/refine``, ``amr/owner`` (boolrle), ``amr/level_offsets``,
    ``amr/coords0`` (raw), ``amr/field/<name>`` (fpdelta-tree or raw).
    """

    name = "amr_tree"
    prefix = "amr/"

    def parse(self, record_name: str) -> dict:
        rest = record_name[len(self.prefix):]
        if rest.startswith("field/"):
            return {"part": "field", "field": rest[len("field/"):]}
        return {"part": rest}

    def write(self, ctx, domain: int, tree, *, compress_fields: bool = True,
              zbits: int = 4) -> None:
        from ..core import fpdelta
        enc_bool = get_codec("boolrle").encode
        for part, bits in (("refine", tree.refine), ("owner", tree.owner)):
            payload, _ = enc_bool(bits)
            ctx.write_bytes(domain, f"amr/{part}", payload, dtype="bool",
                            shape=bits.shape, codec="boolrle")
        ctx.write_array(domain, "amr/level_offsets", tree.level_offsets)
        ctx.write_array(domain, "amr/coords0",
                        tree.coords[tree.level_slice(0)].astype(np.int64))
        for fname, v in tree.fields.items():
            if compress_fields:
                tc = fpdelta.encode_tree_field(tree, fname, zbits=zbits)
                ctx.write_bytes(domain, f"amr/field/{fname}",
                                codecs.encode_tree_field(tc),
                                dtype=str(v.dtype), shape=v.shape,
                                codec="fpdelta-tree", meta={"width": tc.width})
            else:
                ctx.write_array(domain, f"amr/field/{fname}", v)

    def assemble(self, view: ContextView, domain: int = 0, **opts):
        """Rebuild one domain's AMRTree from its self-describing object."""
        from ..core.amr import CHILD_OFFSETS, AMRTree
        refine = view.read(domain, "amr/refine").astype(bool)
        owner = view.read(domain, "amr/owner").astype(bool)
        offsets = view.read(domain, "amr/level_offsets").astype(np.int64)
        coords0 = view.read(domain, "amr/coords0").astype(np.int64)
        # reconstruct coords from the BFS structure (self-describing:
        # children coords follow from fathers')
        n = refine.shape[0]
        coords = np.zeros((n, 3), np.int64)
        coords[:coords0.shape[0]] = coords0
        tree = AMRTree(refine=refine, owner=owner, level_offsets=offsets,
                       coords=coords)
        cs = tree.child_start()
        for lvl in range(tree.n_levels - 1):
            sl = tree.level_slice(lvl)
            idx = np.flatnonzero(tree.refine[sl]) + sl.start
            for k in range(8):
                coords[cs[idx] + k] = 2 * coords[idx] + CHILD_OFFSETS[k]
        for rec in view.select(domains=domain, names="amr/field/*"):
            fname = self.parse(rec.name)["field"]
            payload = view.db.read_payload(rec)
            if rec.codec == "fpdelta-tree":
                tree.fields[fname] = codecs.decode_tree_field_bytes(
                    payload, tree, fname, int(rec.meta["width"]))
            else:
                tree.fields[fname] = np.frombuffer(
                    payload, dtype=rec.dtype).reshape(rec.shape).copy()
        return tree

    def domains_in(self, view: ContextView) -> list[int]:
        return view.domains("amr/refine")


class AnalysisKind(ObjectKind):
    """Named analysis tensors (``analysis/<name>``), pyramid-compressible."""

    name = "analysis"
    prefix = "analysis/"

    def parse(self, record_name: str) -> dict:
        return {"tensor": record_name[len(self.prefix):]}

    def write(self, ctx, domain: int, tensors: dict, *,
              compress: bool = True) -> None:
        for tname, arr in tensors.items():
            _write_maybe_compressed(ctx, domain, f"analysis/{tname}",
                                    np.asarray(arr), compress)

    def assemble(self, view: ContextView, domain: int = 0, **opts
                 ) -> dict[str, np.ndarray]:
        got = view.read_many(selector=Selector(
            names="analysis/*", domains=domain))
        return {self.parse(name)["tensor"]: arr
                for (_, name), arr in got.items()}


class ReducedKind(ObjectKind):
    """In-transit reduction outputs (``reduced/<reducer>/<name>``).

    One reduced object may span several Hercule domains: each contributor
    group of a multi-domain engine writes its part of the reduction as
    its own domain within the shared context, and reads merge them back
    (the paper's per-producer write + deferred-merge shape). Merge
    semantics are *per reducer* and registered by name on this kind —
    see :meth:`register_merge`; contexts written by the in-transit
    engine record each reducer's strategy in
    ``attrs["insitu"]["merge"]``, so merged reads are self-describing.
    """

    name = "reduced"
    prefix = "reduced/"

    #: merge-strategy registry: name -> fn({domain: {array: ndarray}})
    #: -> {array: ndarray}; the input dict is ordered by domain id
    MERGES: dict[str, object] = {}

    @classmethod
    def register_merge(cls, name: str, fn) -> None:
        """Register a named merge strategy for multi-domain reads."""
        cls.MERGES[name] = fn

    def parse(self, record_name: str) -> dict:
        reducer, _, array = record_name[len(self.prefix):].partition("/")
        return {"reducer": reducer, "array": array}

    def record_name(self, reducer: str, array: str) -> str:
        assert "/" not in array, f"reduced array name {array!r} contains '/'"
        return f"reduced/{reducer}/{array}"

    def write(self, ctx, domain: int, arrays: dict, *, reducer: str,
              compress: bool = False) -> None:
        for aname, arr in arrays.items():
            _write_maybe_compressed(ctx, domain,
                                    self.record_name(reducer, aname),
                                    arr, compress)

    def assemble(self, view: ContextView, domain: int | None = 0, *,
                 reducer: str, strategy: str | None = None, domains=None,
                 **opts) -> dict[str, np.ndarray]:
        """Assemble one reduced object.

        ``domain=None`` merges the object across every contributing
        domain (optionally restricted to ``domains``) using the merge
        strategy resolved from the explicit ``strategy`` argument or the
        context's ``attrs["insitu"]["merge"]``. A single contributing
        domain is returned as-is — the degenerate case is bit-for-bit
        the per-domain read, no strategy needed.
        """
        if domain is not None:
            prefix = f"reduced/{reducer}/"
            recs = [r for r in view.select(domains=domain)
                    if r.name.startswith(prefix)]
            if not recs:
                raise KeyError(
                    f"no reduced object {reducer!r} in context {view.step}")
            arrays = view.read_records(recs)
            return {r.name[len(prefix):]: a for r, a in zip(recs, arrays)}
        objs = self.read_parts(view, reducer, domains=domains)
        return self.merge(view, reducer, objs, strategy=strategy)

    def read_parts(self, view: ContextView, reducer: str, *, domains=None
                   ) -> dict[int, dict[str, np.ndarray]]:
        """Per-domain reduced objects: read_merged semantics, one batch.

        All of the reducer's records across domains decode in a single
        :meth:`ContextView.read_records` call (fanning out on the db's
        ``io_threads`` pool above ``PARALLEL_MIN_BYTES``) instead of one
        domain-merged gather per array name.
        """
        prefix = f"reduced/{reducer}/"
        recs = [r for n, rs in view._by_name.items()
                if n.startswith(prefix) for r in rs]
        if not recs:
            raise KeyError(
                f"no reduced object {reducer!r} in context {view.step}")
        if domains is not None:
            want = {int(d) for d in domains}
            recs = [r for r in recs if r.domain in want]
            if not recs:
                raise KeyError(
                    f"no reduced object {reducer!r} in context {view.step} "
                    f"for domains {sorted(want)}")
        arrays = view.read_records(recs)
        objs: dict[int, dict[str, np.ndarray]] = {}
        for rec, arr in zip(recs, arrays):
            objs.setdefault(rec.domain, {})[rec.name[len(prefix):]] = arr
        return {d: objs[d] for d in sorted(objs)}

    def merge(self, view: ContextView, reducer: str,
              objs: dict[int, dict[str, np.ndarray]], *,
              strategy: str | None = None) -> dict[str, np.ndarray]:
        """Merge per-domain objects into one (identity for one domain)."""
        if len(objs) == 1:
            return next(iter(objs.values()))
        if strategy is None:
            strategy = self.merge_strategy_of(view, reducer)
        if strategy is None:
            raise ValueError(
                f"reduced object {reducer!r} spans {len(objs)} domains but "
                f"declares no merge strategy; pass strategy=... or write "
                f"attrs['insitu']['merge'] (registered: {sorted(self.MERGES)})")
        fn = self.MERGES.get(strategy)
        if fn is None:
            raise ValueError(
                f"unknown merge strategy {strategy!r}; "
                f"registered: {sorted(self.MERGES)}")
        return fn(objs)

    def merge_strategy_of(self, view: ContextView, reducer: str
                          ) -> str | None:
        """Strategy recorded by the writer (engine attrs), if any."""
        merge = view.attrs.get("insitu", {}).get("merge", {})
        return merge.get(reducer)

    def reducers_in(self, view: ContextView) -> list[str]:
        return sorted({self.parse(n)["reducer"] for n in view._by_name
                       if self.match(n)})

    def domains_in(self, view: ContextView, reducer: str) -> list[int]:
        """Domains contributing to one reduced object."""
        prefix = f"reduced/{reducer}/"
        return sorted({r.domain for n, rs in view._by_name.items()
                       if n.startswith(prefix) for r in rs})


class CkptShardKind(ObjectKind):
    """HProt checkpoint shards: one record per owned device shard.

    Naming schema: the pytree key path of the leaf (``['params']['w']``);
    ``meta`` carries the global shape and this shard's index slices, so
    any target topology can reassemble exactly the regions it needs.
    This is the fallback kind: every record no other kind claims.
    """

    name = "ckpt_shard"
    prefix = ""

    def match(self, record_name: str) -> bool:
        return False  # fallback: claimed only via kind_of()

    def shards(self, view: ContextView, name: str) -> list[Record]:
        return view.select(Selector(names=name, kinds=self.name))

    def read_region(self, view: ContextView, name: str,
                    target_slices, *, reader=None) -> np.ndarray:
        """Elastic region read: decode only overlapping source shards.

        ``reader`` overrides the batched record decoder (``fn(records)
        -> [ndarray]``); the async manager injects a checksum-verifying
        decode here so integrity checking composes with the elastic
        intersection logic instead of duplicating it.
        """
        recs = self.shards(view, name)
        if not recs:
            raise KeyError(
                f"checkpoint context {view.step} missing tensor {name!r}")
        read = reader if reader is not None else view.read_records
        gshape = tuple(recs[0].meta["global_shape"])
        if not gshape:  # scalar: a single record, whole payload
            return read([recs[0]])[0].reshape(())
        out = np.empty([s.stop - s.start for s in target_slices],
                       _dtype_of(recs[0].dtype))
        hits = []
        for rec in recs:
            src = [slice(a, b) for a, b in rec.meta["slices"]]
            # shards from unsharded leaves record no slices: full extent
            src += [slice(0, dim) for dim in gshape[len(src):]]
            inter = []
            for ts, ss in zip(target_slices, src):
                lo, hi = max(ts.start, ss.start), min(ts.stop, ss.stop)
                if lo >= hi:
                    break
                inter.append((lo, hi))
            else:
                hits.append((rec, src, inter))
        for (rec, src, inter), data in zip(hits, read(
                [rec for rec, _, _ in hits])):
            dst = tuple(slice(lo - ts.start, hi - ts.start)
                        for (lo, hi), ts in zip(inter, target_slices))
            s_src = tuple(slice(lo - ss.start, hi - ss.start)
                          for (lo, hi), ss in zip(inter, src))
            out[dst] = data[s_src]
        return out


class HProtShardKind(CkptShardKind):
    """HProt protection shards written by the async checkpoint manager.

    Naming schema: ``ckpt/<pytree key path>`` — an explicit prefix (the
    sync manager's bare key paths stay on the fallback kind), so HProt
    records are claimable, selectable and scannable like any other
    typed object. Same meta contract as :class:`CkptShardKind` plus a
    per-record ``crc32`` of the stored payload and, for delta-encoded
    shards, the ``pred_step`` whose record is the temporal predictor
    (DESIGN.md §16).
    """

    name = "hprot_shard"
    prefix = "ckpt/"

    def match(self, record_name: str) -> bool:
        return record_name.startswith(self.prefix)

    def parse(self, record_name: str) -> dict:
        return {"tensor": record_name[len(self.prefix):]}

    def record_name(self, tensor: str) -> str:
        return f"{self.prefix}{tensor}"


class TelemetryKind(ObjectKind):
    """Run-ledger telemetry batches (``telemetry/<part>``).

    The observability flavor of the paper's purpose-specific-format
    lesson (DESIGN.md §19): each flush of :class:`repro.obs.ledger.
    RunLedger` writes one ledger context whose records are JSON parts —
    ``telemetry/meta``, ``telemetry/metrics``, ``telemetry/spans``,
    ``telemetry/events``, ``telemetry/attrib``, ``telemetry/health`` —
    and every writing process (trainer/engine, process lanes relayed
    over the results queue, catalog server) lands its parts as its *own
    Hercule domain*. ``assemble(domain=None)`` merges them back at read
    exactly like the reduced kind: spans and events concatenate across
    domains ordered by timestamp; metrics/attrib/health key by domain.
    """

    name = "telemetry"
    prefix = "telemetry/"

    #: parts whose per-domain payloads are event-shaped lists merged by
    #: timestamp; the rest stay keyed by contributing domain
    _CONCAT = {"spans": "ts", "events": "ts_us"}

    def parse(self, record_name: str) -> dict:
        return {"part": record_name[len(self.prefix):]}

    def record_name(self, part: str) -> str:
        return f"{self.prefix}{part}"

    def write(self, ctx, domain: int, parts: dict, **opts) -> None:
        """Write a dict of JSON-able parts as one domain's records."""
        for part, payload in parts.items():
            blob = json.dumps(payload).encode()
            ctx.write_bytes(domain, self.record_name(part), blob,
                            dtype="uint8", shape=(len(blob),),
                            codec="json")

    def _decode(self, view: ContextView, rec: Record):
        return json.loads(view.db.read_payload(rec).decode())

    def assemble(self, view: ContextView, domain: int | None = None,
                 **opts) -> dict:
        """Merge every domain's telemetry parts for one ledger context.

        Returns ``{part: ...}``: span/event parts are one time-ordered
        list across all (selected) domains; other parts map
        ``{domain: payload}``.
        """
        out: dict = {}
        for rec in view.select(names="telemetry/*", domains=domain):
            part = self.parse(rec.name)["part"]
            payload = self._decode(view, rec)
            if part in self._CONCAT:
                out.setdefault(part, []).extend(payload or [])
            else:
                out.setdefault(part, {})[rec.domain] = payload
        for part, ts_key in self._CONCAT.items():
            if part in out:
                out[part].sort(key=lambda e: e.get(ts_key, 0.0))
        return out


def _decode_json_record(db, rec, payload):
    # JSON records decode to a uint8 byte array at the record layer;
    # TelemetryKind.assemble parses the actual objects
    return np.frombuffer(payload, dtype=np.uint8)


register_codec("json", decode=_decode_json_record)


AMR_TREE = register_kind(AmrTreeKind())
ANALYSIS = register_kind(AnalysisKind())
REDUCED = register_kind(ReducedKind())
HPROT_SHARD = register_kind(HProtShardKind())
TELEMETRY = register_kind(TelemetryKind())
CKPT_SHARD = register_kind(CkptShardKind(), fallback=True)


# ----------------------------------------------- built-in merge strategies
#
# Each strategy implements the full merge semantics of one reducer family
# over per-domain objects produced from *disjoint* contributor partitions
# (each owned element contributed by exactly one domain):
#
#   sum       elementwise sum of every array (column-density projections)
#   max       elementwise maximum (depth/max image compositing)
#   hist      sum per-level counts, rows zero-padded; bin edges must agree
#   tile      NaN-background images tiled by extent (axis slices)
#   assemble  AMR-tree arrays merged by (level, coords), owned copies win
#             (level-of-detail cuts: concatenate + re-sort in Morton/BFS)
#   concat    row-concatenate arrays keyed by a "names" axis, re-sorted
#             (tensor-norm tables)
#   union     dict union of disjointly-named arrays (spectra)

def _each_name(objs):
    seen: dict[str, None] = {}
    for obj in objs.values():
        for n in obj:
            seen.setdefault(n)
    return list(seen)


def _merge_sum(objs):
    return {n: sum(o[n] for o in objs.values() if n in o)
            for n in _each_name(objs)}


def _merge_max(objs):
    out = {}
    for n in _each_name(objs):
        arrs = [o[n] for o in objs.values() if n in o]
        acc = arrs[0]
        for a in arrs[1:]:
            acc = np.fmax(acc, a)
        out[n] = acc
    return out


def _merge_hist(objs):
    parts = list(objs.values())
    edges = [p["edges"] for p in parts]
    if any(not np.array_equal(edges[0], e) for e in edges[1:]):
        raise ValueError(
            "histogram bin edges differ across domains (auto lo/hi bounds "
            "are per-partition); use fixed lo/hi bounds for multi-domain "
            "histogram reduction")
    hists = [p["hist"] for p in parts]
    rows = max(h.shape[0] for h in hists)
    acc = np.zeros((rows,) + hists[0].shape[1:], hists[0].dtype)
    for h in hists:
        acc[:h.shape[0]] += h
    return {"hist": acc, "edges": edges[0]}


def _merge_tile(objs):
    """Overlay NaN-background arrays: first non-NaN per element wins.

    Disjoint contributor partitions paint disjoint extents (shared
    pixels, e.g. demoted coarse nodes, carry identical restricted
    values), so overlay order does not matter.
    """
    out = {}
    for n in _each_name(objs):
        acc = None
        for o in objs.values():
            if n not in o:
                continue
            a = o[n]
            if acc is None:
                acc = np.array(a, copy=True)
            elif acc.dtype.kind == "f":
                hole = np.isnan(acc)
                acc[hole] = a[hole]
            elif not np.array_equal(acc, a):
                raise ValueError(
                    f"cannot tile non-float array {n!r} with conflicting "
                    "values across domains")
        out[n] = acc
    return out


def _merge_assemble(objs):
    from ..core.amr import AMRTree   # lazy: api is imported by core users
    from . import analysis
    trees = [AMRTree.from_arrays(o) for o in objs.values()]
    return dict(analysis.assemble(trees).to_arrays())


def _merge_concat(objs):
    parts = list(objs.values())
    if any("names" not in p for p in parts):
        raise ValueError(
            "'concat' merge needs a 'names' array in every domain part")
    names = np.concatenate([np.asarray(p["names"]) for p in parts])
    order = np.argsort(names, kind="stable")
    out = {"names": names[order]}
    for n in _each_name(objs):
        if n == "names":
            continue
        arrs = [p[n] for p in parts if n in p]
        identical = all(np.array_equal(arrs[0], a) for a in arrs[1:])
        aligned = len(arrs) == len(parts) and all(
            a.shape[:1] == np.asarray(p["names"]).shape[:1]
            for a, p in zip(arrs, parts))
        # a constant *string* side table (e.g. stat_names) can
        # coincidentally have as many rows as each part owns names —
        # identity wins there; numeric rows that merely happen to be
        # equal (zero-init layers) still concatenate with the names
        if aligned and (not identical or arrs[0].dtype.kind not in "US"):
            out[n] = np.concatenate(arrs)[order]
        elif identical:
            out[n] = arrs[0]
        else:
            raise ValueError(
                f"array {n!r} is neither row-aligned with 'names' nor "
                "identical across domains")
    return out


def _merge_union(objs):
    out: dict[str, np.ndarray] = {}
    for dom, obj in objs.items():
        for n, a in obj.items():
            if n in out and not np.array_equal(out[n], a):
                raise ValueError(
                    f"'union' merge found conflicting values for {n!r} "
                    f"(domain {dom})")
            out.setdefault(n, a)
    return out


for _name, _fn in (("sum", _merge_sum), ("max", _merge_max),
                   ("hist", _merge_hist), ("tile", _merge_tile),
                   ("assemble", _merge_assemble), ("concat", _merge_concat),
                   ("union", _merge_union)):
    ReducedKind.register_merge(_name, _fn)


# ------------------------------------------------------- object-level API

def write_object(ctx, kind: str, domain: int, payload, **opts) -> None:
    """Write one typed object into a context (dispatch by kind name)."""
    if kind not in KINDS:
        raise ValueError(f"unknown object kind {kind!r}; "
                         f"registered: {sorted(KINDS)}")
    KINDS[kind].write(ctx, domain, payload, **opts)


def read_object(db: HerculeDB, step: int, kind: str,
                domain: int | None = 0, **opts):
    """Assemble one typed object from a context's records.

    For the ``reduced`` kind, ``domain=None`` returns the object merged
    across every contributing domain (see
    :meth:`ReducedKind.assemble`); other kinds require a concrete domain.
    """
    if kind not in KINDS:
        raise ValueError(f"unknown object kind {kind!r}; "
                         f"registered: {sorted(KINDS)}")
    return KINDS[kind].assemble(db.view(step), domain, **opts)


# ------------------------------------------------------------------- scan

@dataclasses.dataclass(frozen=True)
class RecordRef:
    """One matched record with enough context to read it."""
    view: ContextView
    record: Record

    @property
    def step(self) -> int:
        return self.view.step

    @property
    def kind(self) -> str:
        return kind_of(self.record.name).name

    def read(self) -> np.ndarray:
        return self.view.read_record(self.record)


def scan(db: HerculeDB, selector: Selector | None = None, **kw):
    """Iterate matching records across every context of a database.

    Yields :class:`RecordRef` in (step, manifest) order. Contexts whose
    step the selector rejects are skipped without opening their manifest.
    """
    sel = as_selector(selector, **kw)
    for step in db.contexts():
        if not sel.match_step(step):
            continue
        view = db.view(step)
        for rec in view.select(sel):
            yield RecordRef(view, rec)
