"""Hercule-style parallel I/O and data management (paper §2).

Two database kinds, written at independent frequencies (fig. 1):

  * :mod:`hprot`  — checkpoint/restart: raw, coarse-grained, code-private.
  * :mod:`hdep`   — post-processing: self-describing, pruned, compressed.

Shared machinery in :mod:`database`: *contexts* (one per time step /
checkpoint step), *domains* (one per contributor), contributor groups of
NCF processes sharing one physical file, and max-file-size rollover.

The unified object layer lives in :mod:`api`: typed ObjectKinds
(``amr_tree`` / ``analysis`` / ``reduced`` / ``ckpt_shard``), a codec
registry, indexed :class:`~repro.hercule.api.ContextView` handles and the
shared :class:`~repro.hercule.api.Selector` query object (DESIGN.md §11).
"""
from . import api  # noqa: F401  (registers object kinds + fpdelta codecs)
from .api import (ContextView, Selector, read_object, scan,  # noqa: F401
                  write_object)
from .database import (ContextWriter, HerculeDB, Record,  # noqa: F401
                       codec_names, decode_record, register_codec)
