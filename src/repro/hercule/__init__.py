"""Hercule-style parallel I/O and data management (paper §2).

Two database kinds, written at independent frequencies (fig. 1):

  * :mod:`hprot`  — checkpoint/restart: raw, coarse-grained, code-private.
  * :mod:`hdep`   — post-processing: self-describing, pruned, compressed.

Shared machinery in :mod:`database`: *contexts* (one per time step /
checkpoint step), *domains* (one per contributor), contributor groups of
NCF processes sharing one physical file, and max-file-size rollover.
"""
from .database import HerculeDB, ContextWriter  # noqa: F401
