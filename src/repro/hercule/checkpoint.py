"""HProt checkpoint/restart for sharded JAX train states.

The paper's HProt flow, mapped to ML (DESIGN.md §2):

  * **contributor** = one device's shard of the train state; domain id =
    device id. NCF contributors share a physical file (metadata-server
    relief at 1000+ nodes).
  * **raw coarse-grained blocks** — each shard is appended untransformed
    (the paper's second, successful granularity strategy: "big blocks of
    untransformed raw data", no pre-processing on the write path).
  * **ownership pruning** — replicated shards (same global slice on many
    devices) are written once by their owner device; the ownership map is
    the paper's ownership array analogue. On a (data=16, model=16) mesh a
    purely tensor-parallel tensor is written 16x less.
  * **contexts** = checkpoint steps, appended into the same physical files
    until rollover (multiple time steps per file, exactly Hercule).
  * **async** — device->host snapshot is synchronous, file I/O happens on
    a background thread; the next save barriers on the previous write
    ("different output frequencies" between compute and I/O flows).
  * **elastic restore** — the index stores global shape + shard slices, so
    restore works onto any mesh/topology; only the slices each target
    shard needs are read (no full-tensor host materialization).
  * optional lossless compression per tensor: ``delta`` (previous context
    as predictor — temporal father–son), ``pyramid`` (8-way mean pyramid),
    or ``auto`` (smallest of raw/delta/pyramid, per tensor, per save).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from ..core import pyramid as pyr
from . import api, codecs
from .database import HerculeDB

_SENTINEL = object()


def _leaf_paths(tree) -> list[tuple[str, object]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def leaf_name(path) -> str:
    """Flatten a pytree key path to a dotted record name ('params.w').

    Shared by the HDep analysis dump and the in-transit engine so both
    flows emit identical names for the same parameter.
    """
    return jax.tree_util.keystr(path).strip("'[]").replace("']['", ".")


def _shards_of(leaf) -> list[tuple[int, tuple, np.ndarray]]:
    """(domain, index-slices, data) per *owned* shard (replicas pruned)."""
    if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
        seen: dict[tuple, int] = {}
        out = []
        for sh in sorted(leaf.addressable_shards, key=lambda s: s.device.id):
            key = tuple((s.start, s.stop, s.step) for s in sh.index)
            if key in seen:
                continue  # ghost replica — ownership pruning
            seen[key] = sh.device.id
            out.append((sh.device.id, sh.index, np.asarray(sh.data)))
        return out
    return [(0, (), np.asarray(leaf))]


def _slices_json(index: tuple, shape: tuple) -> list[list[int]]:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        out.append([int(start), int(stop)])
    return out


_FLOATY = ("float32", "float64", "bfloat16")


class CheckpointManager:
    """Hercule HProt-backed checkpoint manager."""

    def __init__(self, root: str, *, ncf: int = 8,
                 max_file_bytes: int = 2 << 30, mode: str = "raw",
                 async_write: bool = True, io_threads: int = 4):
        assert mode in ("raw", "delta", "pyramid", "auto")
        self.db = HerculeDB.create(root, kind="hprot", ncf=ncf,
                                   max_file_bytes=max_file_bytes,
                                   io_threads=io_threads)
        self.mode = mode
        self.async_write = async_write
        self._prev: dict[tuple[str, int], np.ndarray] = {}
        self._prev_step: int | None = None
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: list[BaseException] = []
        self._thread = None
        if async_write:
            self._thread = threading.Thread(target=self._worker,
                                            name="hprot-writer", daemon=True)
            self._thread.start()

    # ------------------------------------------------------------- save
    def save(self, step: int, state, *, attrs: dict | None = None,
             wait: bool = False) -> None:
        """Snapshot ``state`` (sync) and write it (async by default)."""
        self.check_errors()
        snapshot = []
        for name, leaf in _leaf_paths(state):
            if leaf is None:
                continue
            for domain, index, data in _shards_of(leaf):
                gshape = tuple(getattr(leaf, "shape", data.shape))
                snapshot.append((name, domain, _slices_json(index, gshape),
                                 gshape, data))
        job = (step, snapshot, dict(attrs or {}))
        if self.async_write:
            self._q.put(job)  # blocks if previous write still in flight
        else:
            self._write(job)
        if wait:
            self.wait()

    def _worker(self):
        while True:
            job = self._q.get()
            if job is _SENTINEL:
                return
            try:
                self._write(job)
            except BaseException as e:  # surfaced on next save/wait
                self._err.append(e)
            finally:
                self._q.task_done()

    def _encode(self, name: str, domain: int, data: np.ndarray):
        """Pick codec per tensor shard; returns (codec, payload, meta)."""
        raw = None
        candidates = []
        floaty = str(data.dtype) in _FLOATY and data.size >= 64
        prev = self._prev.get((name, domain))
        mode = self.mode
        if floaty and mode in ("delta", "auto") and prev is not None \
                and prev.shape == data.shape:
            dc = pyr.encode_delta(data, prev)
            candidates.append(("fpdelta-delta", codecs.encode_delta(dc),
                              {"pred_step": self._prev_step, "pad": dc.pad}))
        if floaty and mode in ("pyramid", "auto"):
            pc = pyr.encode_pyramid(data)
            candidates.append(("fpdelta-pyramid", codecs.encode_pyramid(pc),
                              {"pad": pc.pad}))
        raw = ("raw", np.ascontiguousarray(data).tobytes(), {})
        if mode == "raw" or not candidates:
            return raw
        best = min(candidates, key=lambda c: len(c[1]))
        return best if len(best[1]) < len(raw[1]) else raw

    def _write(self, job):
        step, snapshot, attrs = job
        ctx = self.db.begin_context(step)
        # group-parallel writes: one closure per contributor group
        bygroup: dict[int, list] = {}
        for name, domain, slices, gshape, data in snapshot:
            bygroup.setdefault(self.db.group_of(domain), []).append(
                (name, domain, slices, gshape, data))

        def write_group(items):
            for name, domain, slices, gshape, data in items:
                codec, payload, meta = self._encode(name, domain, data)
                ctx.write_bytes(domain, name, payload, dtype=str(data.dtype),
                                shape=data.shape, codec=codec,
                                meta={**meta, "slices": slices,
                                      "global_shape": list(gshape)})
        for items in bygroup.values():
            ctx.submit(write_group, items)
        ctx.finalize(attrs={**attrs, "mode": self.mode})
        # retain snapshot as the next delta predictor
        if self.mode in ("delta", "auto"):
            self._prev = {(n, d): data for n, d, _, _, data in snapshot}
            self._prev_step = step

    # ------------------------------------------------------------- sync
    def wait(self) -> None:
        if self.async_write:
            self._q.join()
        self.check_errors()

    def check_errors(self) -> None:
        if self._err:
            raise RuntimeError("async checkpoint write failed") from self._err[0]

    def close(self) -> None:
        if self.async_write and self._thread is not None:
            self._q.join()
            self._q.put(_SENTINEL)
            self._thread.join()
        self.db.close()

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        return self.db.latest_context()

    def restore(self, template, step: int | None = None):
        """Restore into ``template`` (abstract or concrete state pytree).

        Elastic: works for any target sharding/mesh. For each target shard
        only the overlapping source records are read and decoded.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint context found")
        view = self.db.view(step)

        def restore_leaf(path, leaf):
            name = jax.tree_util.keystr(path)
            if leaf is None:
                return None
            recs = api.CKPT_SHARD.shards(view, name)
            if not recs:
                raise KeyError(f"checkpoint {step} missing tensor {name!r}")
            gshape = tuple(recs[0].meta["global_shape"])

            def read_region(target_slices):
                # only the source shards overlapping the target region are
                # decoded (elastic), in parallel on the db's read pool
                return api.CKPT_SHARD.read_region(view, name, target_slices)

            sharding = getattr(leaf, "sharding", None)
            if isinstance(leaf, (jax.Array, jax.ShapeDtypeStruct)) and sharding is not None:
                def cb(idx):
                    tslices = [slice(0 if s.start is None else s.start,
                                     dim if s.stop is None else s.stop)
                               for s, dim in zip(idx, gshape)]
                    return read_region(tslices)
                return jax.make_array_from_callback(gshape, sharding, cb)
            full = read_region([slice(0, d) for d in gshape]) if gshape else \
                read_region(())
            return jax.numpy.asarray(full) if isinstance(leaf, jax.Array) else full

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = [restore_leaf(p, leaf) for p, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves), view.attrs
