"""HDep post-processing database: self-describing AMR objects (paper §2).

Each domain stores one *object* per context following the Hercule AMR-3D
data model: the two boolean arrays (refinement, ownership — RLE/base-52
compressed), level offsets, and the physical fields (father–son delta
compressed, top-down decodable). Any reader can assemble the full AMR tree
from the objects alone — nothing about the producing code is needed.

The ML flavor (`write_analysis` / `read_analysis`) stores named tensors
with the pyramid codec for weight/activation analysis dumps.

The *reduced* flavor (`write_reduced` / `read_reduced`) stores the output
of in-transit reductions (:mod:`repro.insitu`): purpose-specific
lightweight objects (slice images, projections, histograms, LOD tree
cuts) written at their own cadence, far smaller than full domain trees.
Each reducer's arrays live under ``reduced/<reducer>/<name>`` and stay
self-describing — a catalog reader needs only the database directory.
"""
from __future__ import annotations

import numpy as np

from ..core import boolcodec, fpdelta, pyramid as pyr
from ..core.amr import AMRTree
from . import codecs
from .database import HerculeDB


# --------------------------------------------------------------- AMR flow

def write_domain_tree(ctx, domain: int, tree: AMRTree, *,
                      compress_fields: bool = True, zbits: int = 4) -> None:
    """Write one domain's (pruned) AMR object into a context."""
    ctx.write_bytes(domain, "amr/refine", boolcodec.encode(tree.refine),
                    dtype="bool", shape=tree.refine.shape, codec="boolrle")
    ctx.write_bytes(domain, "amr/owner", boolcodec.encode(tree.owner),
                    dtype="bool", shape=tree.owner.shape, codec="boolrle")
    ctx.write_array(domain, "amr/level_offsets", tree.level_offsets)
    ctx.write_array(domain, "amr/coords0",
                    tree.coords[tree.level_slice(0)].astype(np.int64))
    for name, v in tree.fields.items():
        if compress_fields:
            tc = fpdelta.encode_tree_field(tree, name, zbits=zbits)
            ctx.write_bytes(domain, f"amr/field/{name}",
                            codecs.encode_tree_field(tc),
                            dtype=str(v.dtype), shape=v.shape,
                            codec="fpdelta-tree", meta={"width": tc.width})
        else:
            ctx.write_array(domain, f"amr/field/{name}", v)


def read_domain_tree(db: HerculeDB, step: int, domain: int) -> AMRTree:
    """Rebuild one domain's AMRTree from its self-describing object."""
    refine = db.read(step, domain, "amr/refine").astype(bool)
    owner = db.read(step, domain, "amr/owner").astype(bool)
    offsets = db.read(step, domain, "amr/level_offsets").astype(np.int64)
    coords0 = db.read(step, domain, "amr/coords0").astype(np.int64)
    # reconstruct coords from the BFS structure (self-describing: children
    # coords follow from fathers')
    n = refine.shape[0]
    coords = np.zeros((n, 3), np.int64)
    coords[:coords0.shape[0]] = coords0
    tree = AMRTree(refine=refine, owner=owner, level_offsets=offsets,
                   coords=coords)
    cs = tree.child_start()
    from ..core.amr import CHILD_OFFSETS
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        idx = np.flatnonzero(tree.refine[sl]) + sl.start
        for k in range(8):
            coords[cs[idx] + k] = 2 * coords[idx] + CHILD_OFFSETS[k]
    # fields
    for rec in db.records(step, domain=domain):
        if not rec.name.startswith("amr/field/"):
            continue
        fname = rec.name[len("amr/field/"):]
        payload = db.read_payload(rec)
        if rec.codec == "fpdelta-tree":
            tree.fields[fname] = codecs.decode_tree_field_bytes(
                payload, tree, fname, int(rec.meta["width"]))
        else:
            tree.fields[fname] = np.frombuffer(
                payload, dtype=rec.dtype).reshape(rec.shape).copy()
    return tree


def domains_in(db: HerculeDB, step: int) -> list[int]:
    return sorted({r.domain for r in db.records(step)
                   if r.name == "amr/refine"})


# ----------------------------------------------------------- reduced flow

def _write_maybe_pyramid(ctx, domain: int, name: str, arr: np.ndarray,
                         compress: bool) -> None:
    """Write one tensor raw, or pyramid-compressed when that shrinks it."""
    arr = np.ascontiguousarray(arr)
    if compress and arr.dtype.kind == "f" and arr.size >= 64:
        pc = pyr.encode_pyramid(arr)
        payload = codecs.encode_pyramid(pc)
        if len(payload) < arr.nbytes:
            ctx.write_bytes(domain, name, payload, dtype=str(arr.dtype),
                            shape=arr.shape, codec="fpdelta-pyramid",
                            meta={"pad": pc.pad})
            return
    ctx.write_array(domain, name, arr)


def write_reduced(ctx, domain: int, reducer: str,
                  arrays: dict[str, np.ndarray], *,
                  compress: bool = False) -> None:
    """Write one reducer's output arrays as a reduced object.

    Reduced objects are already small (that is the point of reducing), so
    they default to raw records — a catalog cold read is then a single
    seek+memcpy. ``compress=True`` additionally runs float arrays through
    the (lossless) pyramid codec, trading write/read CPU for bytes; worth
    it for archival cadences, not for live viewer traffic. Array names
    may not contain ``/`` — the record path is
    ``reduced/<reducer>/<name>``.
    """
    for name, arr in arrays.items():
        assert "/" not in name, f"reduced array name {name!r} contains '/'"
        _write_maybe_pyramid(ctx, domain, f"reduced/{reducer}/{name}",
                             arr, compress)


def read_reduced(db: HerculeDB, step: int, reducer: str,
                 domain: int = 0) -> dict[str, np.ndarray]:
    """Read back one reducer's output arrays from a context."""
    from .database import decode_record
    prefix = f"reduced/{reducer}/"
    out = {}
    for rec in db.records(step, domain=domain):
        if rec.name.startswith(prefix):
            out[rec.name[len(prefix):]] = decode_record(db, rec)
    if not out:
        raise KeyError(f"no reduced object {reducer!r} in context {step}")
    return out


def reducers_in(db: HerculeDB, step: int) -> list[str]:
    """Names of all reduced objects present in a context."""
    names = set()
    for rec in db.records(step):
        if rec.name.startswith("reduced/"):
            names.add(rec.name.split("/", 2)[1])
    return sorted(names)


# ---------------------------------------------------------------- ML flow

def write_analysis(ctx, domain: int, tensors: dict[str, np.ndarray], *,
                   compress: bool = True) -> None:
    """Dump named tensors (weight stats, activations) for offline analysis."""
    for name, arr in tensors.items():
        _write_maybe_pyramid(ctx, domain, f"analysis/{name}",
                             np.asarray(arr), compress)


def read_analysis(db: HerculeDB, step: int, domain: int = 0) -> dict[str, np.ndarray]:
    out = {}
    from .database import decode_record
    for rec in db.records(step, domain=domain):
        if rec.name.startswith("analysis/"):
            out[rec.name[len("analysis/"):]] = decode_record(db, rec)
    return out
