"""HDep post-processing flows — legacy free functions (deprecated).

The HDep object flavors now live in :mod:`repro.hercule.api` as typed
ObjectKinds (``amr_tree``, ``analysis``, ``reduced``): each kind declares
its record naming schema, write/read codecs and assembly logic, and every
read routes through an indexed :class:`~repro.hercule.api.ContextView`.

This module keeps the original free functions as thin deprecation shims
so existing callers keep working (DESIGN.md §11 has the migration table
and the deprecation policy). New code should call::

    from repro.hercule import api
    api.write_object(ctx, "amr_tree", domain, tree)
    tree   = api.read_object(db, step, "amr_tree", domain)
    stats  = api.read_object(db, step, "analysis", domain)
    arrays = api.read_object(db, step, "reduced", domain, reducer=name)
"""
from __future__ import annotations

import warnings

import numpy as np

from ..core.amr import AMRTree
from . import api
from .database import HerculeDB


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.hercule.hdep.{old} is deprecated; use {new} "
        f"(see DESIGN.md §11)", DeprecationWarning, stacklevel=3)


# --------------------------------------------------------------- AMR flow

def write_domain_tree(ctx, domain: int, tree: AMRTree, *,
                      compress_fields: bool = True, zbits: int = 4) -> None:
    """Deprecated shim for ``api.write_object(ctx, "amr_tree", ...)``."""
    _deprecated("write_domain_tree",
                'api.write_object(ctx, "amr_tree", domain, tree)')
    api.write_object(ctx, "amr_tree", domain, tree,
                     compress_fields=compress_fields, zbits=zbits)


def read_domain_tree(db: HerculeDB, step: int, domain: int) -> AMRTree:
    """Deprecated shim for ``api.read_object(db, step, "amr_tree", ...)``."""
    _deprecated("read_domain_tree",
                'api.read_object(db, step, "amr_tree", domain)')
    return api.read_object(db, step, "amr_tree", domain)


def domains_in(db: HerculeDB, step: int) -> list[int]:
    """Deprecated shim for ``api.AMR_TREE.domains_in(db.view(step))``."""
    _deprecated("domains_in", "api.AMR_TREE.domains_in(db.view(step))")
    return api.AMR_TREE.domains_in(db.view(step))


# ----------------------------------------------------------- reduced flow

def write_reduced(ctx, domain: int, reducer: str,
                  arrays: dict[str, np.ndarray], *,
                  compress: bool = False) -> None:
    """Deprecated shim for ``api.write_object(ctx, "reduced", ...)``."""
    _deprecated("write_reduced",
                'api.write_object(ctx, "reduced", domain, arrays, '
                'reducer=reducer)')
    api.write_object(ctx, "reduced", domain, arrays, reducer=reducer,
                     compress=compress)


def read_reduced(db: HerculeDB, step: int, reducer: str,
                 domain: int = 0) -> dict[str, np.ndarray]:
    """Deprecated shim for ``api.read_object(db, step, "reduced", ...)``."""
    _deprecated("read_reduced",
                'api.read_object(db, step, "reduced", domain, '
                'reducer=reducer)')
    return api.read_object(db, step, "reduced", domain, reducer=reducer)


def reducers_in(db: HerculeDB, step: int) -> list[str]:
    """Deprecated shim for ``api.REDUCED.reducers_in(db.view(step))``."""
    _deprecated("reducers_in", "api.REDUCED.reducers_in(db.view(step))")
    return api.REDUCED.reducers_in(db.view(step))


# ---------------------------------------------------------------- ML flow

def write_analysis(ctx, domain: int, tensors: dict[str, np.ndarray], *,
                   compress: bool = True) -> None:
    """Deprecated shim for ``api.write_object(ctx, "analysis", ...)``."""
    _deprecated("write_analysis",
                'api.write_object(ctx, "analysis", domain, tensors)')
    api.write_object(ctx, "analysis", domain, tensors, compress=compress)


def read_analysis(db: HerculeDB, step: int, domain: int = 0
                  ) -> dict[str, np.ndarray]:
    """Deprecated shim for ``api.read_object(db, step, "analysis", ...)``."""
    _deprecated("read_analysis",
                'api.read_object(db, step, "analysis", domain)')
    return api.read_object(db, step, "analysis", domain)
