"""HDep post-processing flows — moved to :mod:`repro.hercule.api`.

The HDep object flavors live in :mod:`repro.hercule.api` as typed
ObjectKinds (``amr_tree``, ``analysis``, ``reduced``): each kind declares
its record naming schema, write/read codecs and assembly logic, and every
read routes through an indexed :class:`~repro.hercule.api.ContextView`.

The legacy free functions that used to live here
(``write_domain_tree`` / ``read_domain_tree`` / ``domains_in`` /
``write_analysis`` / ``read_analysis`` / ``write_reduced`` /
``read_reduced`` / ``reducers_in``) went through the DESIGN.md §11
deprecation countdown (shims since PR 2, removed in PR 4). Call the
unified API instead::

    from repro.hercule import api
    api.write_object(ctx, "amr_tree", domain, tree)
    tree   = api.read_object(db, step, "amr_tree", domain)
    stats  = api.read_object(db, step, "analysis", domain)
    arrays = api.read_object(db, step, "reduced", domain, reducer=name)
    api.AMR_TREE.domains_in(db.view(step))
    api.REDUCED.reducers_in(db.view(step))
"""
