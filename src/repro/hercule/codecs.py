"""Binary serialization of the fpdelta compressed forms for HDep records.

Wire layout (little-endian) — a sequence of sections, each
``[u32 tag][u64 nbytes][payload]``; tags: 1=json header, 2=codes words,
3=payload words, 4=raw array. Self-describing together with the record's
``codec`` + ``meta`` fields.
"""
from __future__ import annotations

import io
import json
import struct

import numpy as np

from ..core import fpdelta, pyramid
from .database import _dtype_of, register_codec

_HDR = struct.Struct("<IQ")


def _put(buf: io.BytesIO, tag: int, payload: bytes) -> None:
    buf.write(_HDR.pack(tag, len(payload)))
    buf.write(payload)


def _walk(data: bytes):
    off = 0
    while off < len(data):
        tag, n = _HDR.unpack_from(data, off)
        off += _HDR.size
        yield tag, data[off:off + n]
        off += n


def _block_to_bytes(buf: io.BytesIO, blk: fpdelta.Compressed) -> None:
    _put(buf, 1, json.dumps({
        "n_groups": blk.n_groups, "group_size": blk.group_size,
        "zbits": blk.zbits, "width": blk.width}).encode())
    _put(buf, 2, np.ascontiguousarray(blk.codes, np.uint32).tobytes())
    _put(buf, 3, np.ascontiguousarray(blk.payload, np.uint32).tobytes())


def _blocks_from_bytes(data: bytes) -> list[fpdelta.Compressed]:
    out = []
    hdr = codes = None
    for tag, payload in _walk(data):
        if tag == 1:
            hdr = json.loads(payload)
        elif tag == 2:
            codes = np.frombuffer(payload, np.uint32).copy()
        elif tag == 3:
            out.append(fpdelta.Compressed(
                codes=codes, payload=np.frombuffer(payload, np.uint32).copy(),
                **hdr))
    return out


def encode_pyramid(pc: pyramid.PyramidCompressed) -> bytes:
    buf = io.BytesIO()
    _put(buf, 4, np.ascontiguousarray(pc.root).tobytes())
    for blk in pc.levels:
        _block_to_bytes(buf, blk)
    return buf.getvalue()


def decode_pyramid_bytes(data: bytes, rec_meta: dict, dtype, shape) -> np.ndarray:
    blocks = _blocks_from_bytes(data)
    root = None
    for tag, payload in _walk(data):
        if tag == 4:
            root = np.frombuffer(payload, dtype=dtype).copy()
            break
    pc = pyramid.PyramidCompressed(levels=blocks, root=root, shape=tuple(shape),
                                   dtype=str(dtype), pad=rec_meta.get("pad", 0))
    return pyramid.decode_pyramid(pc)


def encode_delta(dc: pyramid.DeltaCompressed) -> bytes:
    buf = io.BytesIO()
    _block_to_bytes(buf, dc.block)
    return buf.getvalue()


def decode_delta_bytes(data: bytes, prev: np.ndarray, rec_meta: dict,
                       dtype, shape) -> np.ndarray:
    blk = _blocks_from_bytes(data)[0]
    dc = pyramid.DeltaCompressed(block=blk, shape=tuple(shape),
                                 dtype=str(dtype), pad=rec_meta.get("pad", 0))
    return pyramid.decode_delta(dc, prev)


def encode_tree_field(tc: fpdelta.TreeCompressed) -> bytes:
    buf = io.BytesIO()
    _put(buf, 4, np.ascontiguousarray(tc.root_raw).tobytes())
    _put(buf, 5, json.dumps({"level_groups": tc.level_groups,
                             "field": tc.field}).encode())
    _block_to_bytes(buf, tc.stream)
    return buf.getvalue()


def decode_tree_field_bytes(data: bytes, tree, field: str, width: int) -> np.ndarray:
    blocks = _blocks_from_bytes(data)
    root = meta = None
    for tag, payload in _walk(data):
        if tag == 4:
            root = np.frombuffer(
                payload, np.float64 if width == 64 else np.float32).copy()
        elif tag == 5:
            meta = json.loads(payload)
    tc = fpdelta.TreeCompressed(root_raw=root, stream=blocks[0],
                                level_groups=meta["level_groups"],
                                field=field, width=width)
    return fpdelta.decode_tree_field(tree, tc)


# ------------------------------------------------- codec registry entries

def _decode_fpdelta_pyramid(db, rec, payload: bytes) -> np.ndarray:
    return decode_pyramid_bytes(payload, rec.meta, _dtype_of(rec.dtype),
                                rec.shape)


def _encode_fpdelta_pyramid(arr: np.ndarray, *, zbits: int = 4
                            ) -> tuple[bytes, dict]:
    pc = pyramid.encode_pyramid(np.ascontiguousarray(arr), zbits=zbits)
    return encode_pyramid(pc), {"pad": pc.pad}


def _decode_fpdelta_delta(db, rec, payload: bytes) -> np.ndarray:
    # temporal father-son: the predictor is the same record in an earlier
    # context, read back through the database (self-describing chain)
    pred_step = int(rec.meta["pred_step"])
    prev = db.read(pred_step, rec.domain, rec.name)
    return decode_delta_bytes(payload, prev, rec.meta, _dtype_of(rec.dtype),
                              rec.shape)


def _encode_fpdelta_delta(arr: np.ndarray, *, prev: np.ndarray,
                          zbits: int = 4) -> tuple[bytes, dict]:
    """Caller must merge ``{"pred_step": <step of prev>}`` into the meta."""
    dc = pyramid.encode_delta(np.ascontiguousarray(arr), prev, zbits=zbits)
    return encode_delta(dc), {"pad": dc.pad}


register_codec("fpdelta-pyramid", decode=_decode_fpdelta_pyramid,
               encode=_encode_fpdelta_pyramid)
# "pyramid" is the user-facing alias (checkpoint mode names, docs); both
# names decode identically so either may appear in a record
register_codec("pyramid", decode=_decode_fpdelta_pyramid,
               encode=_encode_fpdelta_pyramid)
register_codec("fpdelta-delta", decode=_decode_fpdelta_delta,
               encode=_encode_fpdelta_delta)
# fpdelta-tree payloads need the assembled AMR tree structure to decode:
# the amr_tree ObjectKind drives them; record-level decode is unavailable
register_codec("fpdelta-tree", decode=None, encode=None)
