"""Opt-in metrics endpoint for processes without a catalog server.

The catalog server already exposes ``/metrics``; the trainer, the
insitu CLI and bare benchmark processes had no scrape surface at all.
:func:`serve_metrics` starts a daemon-threaded stdlib HTTP server that
renders a :class:`~repro.obs.metrics.MetricsRegistry` (the global
``REGISTRY`` by default) in the Prometheus text format, plus a JSON
twin and a tiny health probe:

  ``/metrics``  Prometheus text exposition (0.0.4)
  ``/snapshot`` the JSON snapshot of the same registry
  ``/healthz``  200 "ok" liveness probe

Wired to ``launch/train.py --metrics-port`` (and usable from anything
else: ``obs.serve_metrics(9090)``). ``port=0`` binds an ephemeral port
— read it back from the returned handle's ``.port``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import REGISTRY


class MetricsServer:
    """Handle for a running scrape endpoint; ``close()`` to stop."""

    def __init__(self, httpd: ThreadingHTTPServer,
                 thread: threading.Thread):
        self._httpd = httpd
        self._thread = thread

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def serve_metrics(port: int = 0, *, host: str = "127.0.0.1",
                  registry=None) -> MetricsServer:
    """Start a background Prometheus scrape endpoint; returns a
    :class:`MetricsServer` (``.port``, ``.url``, ``.close()``)."""
    reg = REGISTRY if registry is None else registry

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
            path = self.path.split("?", 1)[0]
            if path in ("/metrics", "/"):
                body = reg.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif path == "/snapshot":
                body = json.dumps(reg.snapshot()).encode()
                ctype = "application/json"
            elif path == "/healthz":
                body, ctype = b"ok\n", "text/plain"
            else:
                self.send_error(404, "unknown path")
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):   # scrape traffic is not news
            pass

    httpd = ThreadingHTTPServer((host, int(port)), _Handler)
    httpd.daemon_threads = True
    thread = threading.Thread(target=httpd.serve_forever,
                              name="obs-metrics-http", daemon=True)
    thread.start()
    return MetricsServer(httpd, thread)
