"""Declarative run-health rules: thresholds, burn rates, a verdict.

A :class:`Rule` watches one *signal* — a named scalar the run ledger
computes each flush (staging pressure, eviction rate, ckpt stall ratio,
serve p99, device fallbacks, lane crashes) — and fires when the signal
violates its threshold persistently enough:

* ``window=1`` (default): plain threshold — one bad sample fires.
* ``window=N, burn=f``: windowed burn rate — fires when at least
  ``ceil(f*N)`` of the last ``N`` samples violate, the standard SLO
  burn-rate shape that ignores one-sample blips but catches sustained
  pressure.

Rules are data, not code: build them from dicts/kwargs or from the
compact string syntax (``Rule.parse``)::

    staging_pressure > 0.9 for 3/5 : warn
    lane_crashes    >= 1           : crit

Firing is *edge-triggered*: an alert event is emitted when a rule
transitions into violation, and a clear is recorded when it leaves, so
the event stream stays an incident log rather than a square wave.
:meth:`HealthEngine.verdict` folds the run's alert history into one
run-end answer: ``healthy`` / ``degraded`` (only warnings) /
``critical``.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import re

_OPS = {
    ">": lambda v, t: v > t,
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
}

_RULE_RE = re.compile(
    r"^\s*(?P<signal>[\w.]+)\s*(?P<op>>=|<=|>|<)\s*(?P<thr>[-\w.+]+)"
    r"(?:\s+for\s+(?P<need>\d+)/(?P<window>\d+))?"
    r"(?:\s*:\s*(?P<sev>warn|crit))?\s*$")

SEVERITIES = ("warn", "crit")


@dataclasses.dataclass
class Rule:
    """One health rule over a ledger signal."""

    signal: str
    op: str
    threshold: float
    window: int = 1
    burn: float = 1.0               # fraction of window that must violate
    severity: str = "warn"
    name: str = ""

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; use one of "
                             f"{sorted(_OPS)}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}")
        self.window = max(1, int(self.window))
        self.burn = min(1.0, max(0.0, float(self.burn)))
        if not self.name:
            self.name = f"{self.signal}{self.op}{self.threshold:g}"

    @property
    def need(self) -> int:
        """Violating samples within the window required to fire."""
        return max(1, math.ceil(self.burn * self.window))

    @staticmethod
    def parse(text: str, severity: str | None = None) -> "Rule":
        """Build a rule from the compact syntax (see module docstring).

        ``"signal > 0.9"`` — instant threshold; append ``for K/N`` for
        a K-of-last-N burn window and ``: warn|crit`` for severity.
        """
        m = _RULE_RE.match(text)
        if m is None:
            raise ValueError(
                f"unparsable health rule {text!r}; expected "
                f"'<signal> <op> <threshold> [for K/N] [: warn|crit]'")
        window = int(m["window"]) if m["window"] else 1
        need = int(m["need"]) if m["need"] else 1
        if need > window:
            raise ValueError(f"rule {text!r}: K must be <= N in 'for K/N'")
        return Rule(signal=m["signal"], op=m["op"],
                    threshold=float(m["thr"]), window=window,
                    burn=need / window,
                    severity=severity or m["sev"] or "warn")

    def violated(self, value: float) -> bool:
        return _OPS[self.op](float(value), self.threshold)


def default_rules() -> list[Rule]:
    """The stock rule set over the signals the stock writers register.

    A rule whose signal never appears in a run's flushes simply stays
    idle — trainer-side and server-side ledgers share one default set.
    """
    return [
        Rule.parse("staging_pressure > 0.9 for 2/3 : warn"),
        Rule.parse("eviction_rate > 2 for 2/3 : warn"),       # parts/s
        Rule.parse("backpressure > 0.5 for 3/5 : warn"),      # blocked frac
        Rule.parse("ckpt_stall_ratio > 0.25 for 2/3 : warn"),
        Rule.parse("device_fallbacks > 0 : warn"),
        Rule.parse("serve_p99_ms > 500 for 2/3 : warn"),
        Rule.parse("serve_429_rate > 5 for 2/3 : warn"),      # rejects/s
        Rule.parse("lane_crashes >= 1 : crit"),
        Rule.parse("engine_failed >= 1 : crit"),
    ]


class HealthEngine:
    """Evaluates rules over successive signal samples; keeps history."""

    def __init__(self, rules=None):
        self.rules: list[Rule] = list(default_rules() if rules is None
                                      else rules)
        self._hist = {r.name: collections.deque(maxlen=r.window)
                      for r in self.rules}
        self._active: dict[str, dict] = {}
        self.alerts: list[dict] = []    # full incident history
        self._samples = 0

    def observe(self, signals: dict, *, ts_us: float = 0.0) -> list[dict]:
        """Feed one flush's signal sample; returns newly-fired alerts."""
        self._samples += 1
        fired = []
        for rule in self.rules:
            value = signals.get(rule.signal)
            if value is None:
                continue                # signal absent this run: idle
            hist = self._hist[rule.name]
            hist.append(1 if rule.violated(value) else 0)
            burning = len(hist) == rule.window and sum(hist) >= rule.need
            active = rule.name in self._active
            if burning and not active:
                alert = {"rule": rule.name, "signal": rule.signal,
                         "severity": rule.severity,
                         "value": float(value),
                         "threshold": rule.threshold, "op": rule.op,
                         "window": rule.window, "need": rule.need,
                         "ts_us": ts_us, "sample": self._samples}
                self._active[rule.name] = alert
                self.alerts.append(alert)
                fired.append(alert)
            elif not burning and active:
                cleared = self._active.pop(rule.name)
                cleared["cleared_sample"] = self._samples
                cleared["cleared_ts_us"] = ts_us
        return fired

    def state(self) -> dict:
        """JSON-able engine state, persisted with every ledger flush."""
        return {"samples": self._samples,
                "rules": [r.name for r in self.rules],
                "active": sorted(self._active),
                "alerts": list(self.alerts),
                "verdict": self.verdict()}

    def verdict(self) -> str:
        if any(a["severity"] == "crit" for a in self.alerts):
            return "critical"
        if self.alerts:
            return "degraded"
        return "healthy"
