"""Per-step span tracing with cross-process context propagation.

Every pipeline stage (producer ``submit`` → staging enqueue/dequeue →
lane ``reduce`` → device transfer → domain ``write`` → manifest
``commit``) opens a span. Spans carry ``trace_id`` (one per pipeline
step), ``span_id``, and ``parent_id``; within a thread, parentage is
implicit via a thread-local span stack. Across process lanes the parent
context rides the existing shm descriptor JSON header (a two-key dict
from :meth:`Tracer.context`, restored lane-side with ``parent=``), and
finished lane spans are shipped back over the results queue and
:meth:`Tracer.ingest`-ed into the parent's buffer.

The export format is Chrome trace / Perfetto JSON (``traceEvents`` with
complete ``ph:"X"`` events): ``write_chrome_trace(path)`` then
chrome://tracing or https://ui.perfetto.dev loads it directly.

Tracing is OFF by default — ``span()`` returns a shared no-op object
and costs one attribute read; ``launch/insitu.py --trace-out`` enables
the global ``TRACER`` for a run.
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import threading
import time
import uuid

_EPOCH_NS = time.time_ns() - time.perf_counter_ns()


def _now_us() -> float:
    """Microseconds since the unix epoch, monotonic within the process."""
    return (_EPOCH_NS + time.perf_counter_ns()) / 1e3


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


class Span:
    """One timed unit of pipeline work (Chrome-trace complete event)."""

    __slots__ = ("name", "cat", "trace_id", "span_id", "parent_id",
                 "ts", "dur", "args", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 trace_id: str, parent_id: str | None, args=None):
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.ts = _now_us()
        self.dur = 0.0
        self.args = dict(args) if args else {}
        self._tracer = tracer

    def set(self, **kw) -> None:
        self.args.update(kw)

    def __enter__(self):
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._tracer._pop(self)
        return False

    def context(self) -> dict:
        """Wire form of this span as a parent: rides JSON headers."""
        return {"trace_id": self.trace_id, "span_id": self.span_id}

    def as_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat,
                "trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "pid": os.getpid(),
                "tid": threading.get_ident() % 2**31,
                "ts": self.ts, "dur": self.dur, "args": self.args}


class _NoopSpan:
    """Shared do-nothing span: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw) -> None:
        pass

    def context(self):
        return None


_NOOP = _NoopSpan()


#: default retained-span window; a long ledger-instrumented run keeps
#: only the newest spans in memory (older ones were already flushed to
#: the run ledger, or weren't wanted at all)
DEFAULT_MAX_SPANS = 100_000


class Tracer:
    """Collects finished spans; thread-local stack gives implicit parents.

    The span buffer is bounded (``max_spans``, a deque window): once a
    run outgrows it the oldest spans fall off and ``spans_dropped``
    counts them. ``write_chrome_trace``/``export`` keep their exact
    semantics on the retained window; incremental consumers (the run
    ledger) use :meth:`drain_since` marks and therefore see every span
    as long as they drain faster than the window turns over.
    """

    def __init__(self, enabled: bool = False,
                 max_spans: int = DEFAULT_MAX_SPANS):
        self.enabled = enabled
        self._max_spans = int(max_spans)
        self._spans: collections.deque[dict] = \
            collections.deque(maxlen=self._max_spans)
        self._appended = 0          # lifetime spans, incl. fallen-off
        self._lock = threading.Lock()
        self._tls = threading.local()

    # --------------------------------------------------------- lifecycle
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._appended = 0

    def set_max_spans(self, n: int) -> None:
        """Resize the retained window (keeps the newest spans)."""
        with self._lock:
            self._max_spans = int(n)
            self._spans = collections.deque(self._spans,
                                            maxlen=self._max_spans)

    @property
    def max_spans(self) -> int:
        return self._max_spans

    @property
    def spans_dropped(self) -> int:
        """Spans that fell off the bounded window (lifetime count)."""
        with self._lock:
            return self._appended - len(self._spans)

    # ------------------------------------------------------------- spans
    def span(self, name: str, cat: str = "insitu", parent=None,
             args=None):
        """Open a span. ``parent`` may be a wire dict from ``context()``.

        Disabled tracers hand back a shared no-op, so call sites don't
        need their own enabled checks.
        """
        if not self.enabled:
            return _NOOP
        if parent is not None:
            trace_id = parent["trace_id"]
            parent_id = parent["span_id"]
        else:
            cur = self._current()
            if cur is not None:
                trace_id, parent_id = cur.trace_id, cur.span_id
            else:
                trace_id, parent_id = _new_id(), None
        return Span(self, name, cat, trace_id, parent_id, args)

    def record(self, name: str, t0_us: float, t1_us: float,
               cat: str = "insitu", parent=None, args=None) -> dict | None:
        """Log an already-measured interval (timestamps from ``now_us``)."""
        if not self.enabled:
            return None
        span = self.span(name, cat, parent=parent, args=args)
        span.ts = t0_us
        span.dur = max(0.0, t1_us - t0_us)
        rec = span.as_dict()
        with self._lock:
            self._spans.append(rec)
            self._appended += 1
        return rec

    def context(self) -> dict | None:
        """Wire dict of the innermost open span (None when disabled)."""
        cur = self._current()
        return cur.context() if cur is not None else None

    def ingest(self, spans) -> None:
        """Merge span dicts produced elsewhere (e.g. a process lane)."""
        if not spans:
            return
        with self._lock:
            self._spans.extend(spans)
            self._appended += len(spans)

    # ----------------------------------------------------------- exports
    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._spans)

    def drain_since(self, mark: int) -> tuple[int, list[dict]]:
        """Spans appended after ``mark``; returns ``(new_mark, spans)``.

        ``mark`` is an opaque cursor (the lifetime append count from a
        previous call; start at 0). Spans that both arrived and fell
        off the bounded window between two drains are lost — they still
        show in :attr:`spans_dropped`. A cursor ahead of the buffer
        (e.g. after :meth:`clear`) resyncs to the full window.
        """
        with self._lock:
            total = self._appended
            if mark > total:      # buffer was cleared since that mark
                mark = total - len(self._spans)
            n_new = min(total - mark, len(self._spans))
            if n_new <= 0:
                return total, []
            start = len(self._spans) - n_new
            return total, list(itertools.islice(
                self._spans, start, len(self._spans)))

    def export(self) -> dict:
        """Chrome-trace JSON object (load in chrome://tracing/Perfetto)."""
        events = []
        for s in self.spans():
            events.append({
                "name": s["name"], "cat": s["cat"], "ph": "X",
                "pid": s["pid"], "tid": s["tid"],
                "ts": s["ts"], "dur": s["dur"],
                "args": {**s["args"], "trace_id": s["trace_id"],
                         "span_id": s["span_id"],
                         "parent_id": s["parent_id"]}})
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> int:
        """Write the export to ``path``; returns the span count."""
        doc = self.export()
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return len(doc["traceEvents"])

    # ----------------------------------------------------------- internal
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _current(self):
        st = self._stack()
        return st[-1] if st else None

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        span.dur = _now_us() - span.ts
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        else:                      # unbalanced exit: drop just this span
            try:
                st.remove(span)
            except ValueError:
                pass
        with self._lock:
            self._spans.append(span.as_dict())
            self._appended += 1


def now_us() -> float:
    """Public clock for ``Tracer.record`` call sites."""
    return _now_us()


#: process-global tracer: pipeline call sites trace through this; it is
#: disabled (no-op spans) unless a CLI/test enables it
TRACER = Tracer(enabled=False)
