"""Observability substrate for the in-transit pipeline (DESIGN.md §15).

Two stdlib-only pieces:

  * :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
    histograms behind a :class:`MetricsRegistry`, with Prometheus text
    and JSON snapshot renderers.
  * :mod:`repro.obs.trace` — per-step span tracing with cross-process
    context propagation and Chrome-trace/Perfetto export.
"""
from . import metrics, trace
from .metrics import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                      MetricsRegistry, REGISTRY, exponential_buckets,
                      set_enabled)
from .trace import TRACER, Span, Tracer, now_us

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS",
    "MetricsRegistry", "REGISTRY", "Span", "TRACER", "Tracer",
    "exponential_buckets", "metrics", "now_us", "set_enabled", "trace",
]
