"""Observability substrate for the in-transit pipeline (DESIGN.md §15, §19).

Stdlib-only pieces:

  * :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
    histograms behind a :class:`MetricsRegistry`, with Prometheus text
    and JSON snapshot renderers.
  * :mod:`repro.obs.trace` — per-step span tracing with cross-process
    context propagation and Chrome-trace/Perfetto export.
  * :mod:`repro.obs.events` — bounded typed event ring (the flight
    recorder) with crash-dump hooks.
  * :mod:`repro.obs.ledger` — persistent run ledger: periodic durable
    flushes of metrics/spans/events/attribution/health into a
    ``telemetry/`` Hercule database under the run root.
  * :mod:`repro.obs.attrib` — per-step critical-path attribution.
  * :mod:`repro.obs.health` — declarative threshold/burn-rate rules
    with a run-end verdict.
  * :mod:`repro.obs.httpd` — opt-in ``/metrics`` scrape endpoint for
    processes without a catalog server.
"""
from . import attrib, events, health, httpd, ledger, metrics, trace
from .attrib import Attributor, attribute
from .events import EVENTS, EventRing
from .health import HealthEngine, Rule, default_rules
from .httpd import MetricsServer, serve_metrics
from .ledger import LedgerReader, RunLedger
from .metrics import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                      MetricsRegistry, REGISTRY, exponential_buckets,
                      set_enabled)
from .trace import TRACER, Span, Tracer, now_us

__all__ = [
    "Attributor", "Counter", "EVENTS", "EventRing", "Gauge",
    "HealthEngine", "Histogram", "LATENCY_BUCKETS", "LedgerReader",
    "MetricsRegistry", "MetricsServer", "REGISTRY", "Rule", "RunLedger",
    "Span", "TRACER", "Tracer", "attrib", "attribute", "default_rules",
    "events", "exponential_buckets", "health", "httpd", "ledger",
    "metrics", "now_us", "serve_metrics", "set_enabled", "trace",
]
