"""Persistent run ledger: telemetry as its own Hercule flavor.

The paper's lesson is purpose-specific formats — HProt for restart,
HDep for post-processing. PR 6's telemetry violated it: metrics, spans
and (now) events lived only in volatile process memory, scattered over
the trainer, the lane processes and the catalog server, gone the moment
anything crashed. The run ledger gives observability its own
lightweight Hercule flavor instead: a ``telemetry/`` sub-database under
the run root to which every process periodically appends a *flush* —
one small Hercule context holding JSON records
(:class:`~repro.hercule.api.TelemetryKind`):

  ``telemetry/meta``     flush header (proc, seq, wall time, reason)
  ``telemetry/metrics``  MetricsRegistry snapshots per source
  ``telemetry/spans``    span batch drained from the tracer since the
                         previous flush (exactly-once via drain marks)
  ``telemetry/events``   event-ring drain (same discipline)
  ``telemetry/attrib``   per-step critical-path attribution completed
                         since the previous flush
  ``telemetry/health``   rule-engine state incl. full alert history

Domain layout follows the engine's per-producer shape: the trainer (or
the insitu CLI's producer process) writes domain 0, the catalog server
writes domain 1, and process lanes land as domains ``8+group`` — their
span/event batches arrive over the existing results queue and the
engine relays them into the trainer's ledger via :meth:`ingest_domain`.
Context numbering keeps concurrent committers collision-free: flush
``seq`` of committer slot ``s`` commits context ``seq*64 + s``, and
every commit is the usual fsync-then-atomic-rename, so a SIGKILL at any
point leaves every previously-flushed context readable.

Crash persistence: the ledger registers a dump hook on the global event
ring — when a lane dies or the engine aborts, :func:`~repro.obs.events.
EventRing.dump` forces an immediate flush that also carries *partial*
attribution for every step still in flight.

:class:`LedgerReader` merges the whole run back (all domains, all
slots): merged event/span streams, per-step attribution, alert
timeline, run verdict — the substrate for ``launch/obs.py``'s
``tail`` / ``report`` / ``export --perfetto``.
"""
from __future__ import annotations

import json
import os
import threading
import time

from ..hercule import api
from ..hercule.database import DomainWriter, HerculeDB
from . import metrics as _metrics
from .attrib import Attributor
from .events import ALERT, EVENTS, RUN_END, LANE_CRASH, STAGING_EVICT, \
    SERVE_429
from .health import HealthEngine
from .trace import TRACER, now_us

#: context step = seq * SEQ_STRIDE + slot; one slot per committing
#: process, so concurrent committers never race a manifest
SEQ_STRIDE = 64
SLOTS = {"trainer": 0, "server": 1}
#: Hercule domain of each writer within a flush context
DOMAINS = {"trainer": 0, "server": 1}
LANE_DOMAIN_BASE = 8

LEDGER_DIRNAME = "telemetry"


def ledger_dir(run_root: str) -> str:
    """The telemetry sub-database of a run root (idempotent)."""
    if os.path.basename(os.path.normpath(run_root)) == LEDGER_DIRNAME:
        return run_root
    return os.path.join(run_root, LEDGER_DIRNAME)


def lane_domain(group: int) -> int:
    """Ledger domain of contributor-group ``group``'s lane process."""
    return LANE_DOMAIN_BASE + int(group)


def _open_db(path: str) -> HerculeDB:
    """Create-or-open with a retry: two processes (trainer + catalog
    server) may race the initial ``db.json`` write; the content is
    identical, so losing the race only means re-reading it."""
    for attempt in range(3):
        try:
            return HerculeDB.create(path, kind="hdep", ncf=1,
                                    io_threads=1)
        except (json.JSONDecodeError, OSError):
            if attempt == 2:
                raise
            time.sleep(0.05 * (attempt + 1))
    raise AssertionError("unreachable")


class RunLedger:
    """One process's writer into the run's telemetry database.

    ``interval > 0`` starts a daemon flush thread; ``interval = 0``
    leaves cadence to explicit :meth:`flush` calls (tests, benchmarks).
    Registered *sources* (``name -> fn() -> metrics snapshot``) are
    captured every flush; *signals* (``name -> fn() -> float|None``)
    feed the health rule engine, alongside the event-derived rates the
    ledger computes itself (eviction/429 rates, lane-crash count).
    """

    def __init__(self, run_root: str, proc: str = "trainer", *,
                 interval: float = 2.0, rules=None,
                 capture_spans: bool = True):
        if proc not in SLOTS:
            raise ValueError(f"proc must be one of {sorted(SLOTS)}")
        self.proc = proc
        self.slot = SLOTS[proc]
        self.domain = DOMAINS[proc]
        self.dir = ledger_dir(run_root)
        self.db = _open_db(self.dir)
        self.interval = float(interval)
        self.capture_spans = capture_spans
        self.health = HealthEngine(rules)
        self.attributor = Attributor()
        self._sources: dict = {"process": _metrics.REGISTRY.snapshot}
        self._signals: dict = {}
        self._foreign: list[tuple[int, dict]] = []   # (domain, parts)
        # drain marks start at the current heads: a ledger owns its
        # run's telemetry from the moment it is created, not whatever an
        # earlier run in this process left in the global rings
        self._span_mark = TRACER.drain_since(0)[0]
        self._event_mark = EVENTS.drain_since(0)[0]
        self._counts = {"lane_crashes": 0, "evictions": 0, "serve_429": 0}
        self._last_flush_ts = time.monotonic()
        self._flush_lock = threading.Lock()
        self._closed = False
        self.bytes_written = 0
        self.flushes = 0
        self.steps_attributed = 0
        # resume after a crash/restart: continue this slot's seq stream
        seqs = [s // SEQ_STRIDE for s in self.db.contexts()
                if s % SEQ_STRIDE == self.slot]
        self._seq = (max(seqs) + 1) if seqs else 0
        EVENTS.register_dump_hook(self._on_dump)
        self._stop = threading.Event()
        self._thread = None
        if self.interval > 0:
            self._thread = threading.Thread(
                target=self._loop, name=f"ledger-{proc}", daemon=True)
            self._thread.start()

    # ------------------------------------------------------- registration
    def add_source(self, name: str, fn) -> None:
        """Register a metrics source (``fn() -> snapshot dict``)."""
        self._sources[name] = fn

    def add_signal(self, name: str, fn) -> None:
        """Register a health signal (``fn() -> float | None``)."""
        self._signals[name] = fn

    def ingest_domain(self, domain: int, parts: dict) -> None:
        """Queue another process's telemetry parts (e.g. a lane batch
        relayed over the results queue) for the next flush."""
        if parts:
            with self._flush_lock:
                self._foreign.append((int(domain), dict(parts)))

    # ------------------------------------------------------------- flush
    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.flush(reason="interval")
            except Exception:   # noqa: BLE001 — a failing flush must
                pass            # never take the pipeline down with it

    def _on_dump(self, reason: str, ring) -> None:
        self.flush(reason=reason, dump=True)

    def _event_signals(self, events, elapsed: float) -> dict:
        for ev in events:
            t = ev.get("type")
            if t == LANE_CRASH:
                self._counts["lane_crashes"] += 1
            elif t == STAGING_EVICT:
                self._counts["evictions"] += 1
            elif t == SERVE_429:
                self._counts["serve_429"] += 1
        n_evict = sum(1 for ev in events
                      if ev.get("type") == STAGING_EVICT)
        n_429 = sum(1 for ev in events if ev.get("type") == SERVE_429)
        elapsed = max(elapsed, 1e-6)
        return {"lane_crashes": self._counts["lane_crashes"],
                "eviction_rate": n_evict / elapsed,
                "serve_429_rate": n_429 / elapsed}

    def flush(self, reason: str = "manual", *, dump: bool = False
              ) -> int | None:
        """Write one ledger context; returns its step id (None if the
        ledger is already closed)."""
        with self._flush_lock:
            if self._closed and reason != "final":
                return None
            now_wall = now_us()
            elapsed = time.monotonic() - self._last_flush_ts
            self._last_flush_ts = time.monotonic()

            spans: list = []
            if self.capture_spans:
                self._span_mark, spans = \
                    TRACER.drain_since(self._span_mark)
            foreign, self._foreign = self._foreign, []
            # lane spans were TRACER.ingest-ed engine-side and ride the
            # trainer drain; lane *events* arrive as foreign parts and
            # also feed attribution/health below
            foreign_events = [ev for _, parts in foreign
                              for ev in parts.get("events", ())]
            attribs = self.attributor.ingest(spans)
            if dump or reason == "final":
                attribs = attribs + self.attributor.flush_pending()
            self.steps_attributed += sum(1 for a in attribs
                                         if not a["partial"])

            # health: evaluate on signals *before* draining events so
            # fired alerts land in this same flush
            _, pre_events = EVENTS.drain_since(self._event_mark)
            signals = self._event_signals(pre_events + foreign_events,
                                          elapsed)
            for name, fn in self._signals.items():
                try:
                    v = fn()
                except Exception:   # noqa: BLE001 — bad signal != crash
                    v = None
                if v is not None:
                    signals[name] = float(v)
            for alert in self.health.observe(signals, ts_us=now_wall):
                EVENTS.emit(ALERT, **alert)
            self._event_mark, events = \
                EVENTS.drain_since(self._event_mark)

            parts = {
                "meta": {"proc": self.proc, "seq": self._seq,
                         "pid": os.getpid(), "ts_us": now_wall,
                         "reason": reason, "elapsed_s": elapsed,
                         "signals": signals,
                         "spans_dropped": TRACER.spans_dropped,
                         "events_dropped": EVENTS.dropped},
                "metrics": {name: fn() for name, fn
                            in self._sources.items()},
                "spans": spans,
                "events": events,
                "attrib": {str(a["step"]): a for a in attribs},
                "health": self.health.state(),
            }
            step = self._seq * SEQ_STRIDE + self.slot
            writer = DomainWriter(self.db, step)
            api.KINDS["telemetry"].write(writer, self.domain, parts)
            for domain, fparts in foreign:
                api.KINDS["telemetry"].write(writer, domain, fparts)
            self.db.commit_context(step, writer.records, attrs={
                "telemetry": {"proc": self.proc, "seq": self._seq,
                              "reason": reason}})
            self.bytes_written += sum(r.nbytes for r in writer.records)
            self.flushes += 1
            self._seq += 1
            return step

    # ------------------------------------------------------------- admin
    def verdict(self) -> str:
        return self.health.verdict()

    def telemetry(self) -> dict:
        """The ledger's own accounting (for engine/CLI summaries)."""
        return {"proc": self.proc, "flushes": self.flushes,
                "bytes_written": self.bytes_written,
                "steps_attributed": self.steps_attributed,
                "verdict": self.health.verdict(),
                "alerts": len(self.health.alerts)}

    def close(self) -> None:
        if self._closed:
            return
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        EVENTS.emit(RUN_END, proc=self.proc,
                    verdict=self.health.verdict())
        self._closed = True
        self.flush(reason="final")
        EVENTS.unregister_dump_hook(self._on_dump)
        self.db.close()


# ===================================================================== read

class LedgerReader:
    """Merged read side over every process's flushes of one run."""

    def __init__(self, run_root: str):
        path = ledger_dir(run_root)
        if not os.path.exists(os.path.join(path, "db.json")):
            raise FileNotFoundError(
                f"no run ledger under {run_root!r} (expected "
                f"{path}/db.json — was the run started with a ledger?)")
        self.db = HerculeDB.open(path)
        self._kind = api.KINDS["telemetry"]

    def close(self) -> None:
        self.db.close()

    # ----------------------------------------------------------- flushes
    def flushes(self) -> list[dict]:
        """Every flush context, time-ordered: ``{seq, slot, step,
        parts}`` with parts merged across the flush's domains."""
        out = []
        for step in self.db.contexts():
            view = self.db.view(step)
            parts = self._kind.assemble(view)
            meta = next(iter(parts.get("meta", {}).values()), {})
            out.append({"step": step, "seq": step // SEQ_STRIDE,
                        "slot": step % SEQ_STRIDE,
                        "ts_us": meta.get("ts_us", 0.0),
                        "proc": meta.get("proc", f"slot{step % SEQ_STRIDE}"),
                        "parts": parts})
        out.sort(key=lambda f: (f["ts_us"], f["step"]))
        return out

    # ------------------------------------------------------ merged views
    def events(self, flushes=None) -> list[dict]:
        """One time-ordered event stream for the whole run (deduped)."""
        seen, out = set(), []
        for fl in flushes if flushes is not None else self.flushes():
            for ev in fl["parts"].get("events", []):
                key = (ev.get("pid"), ev.get("seq"), ev.get("type"),
                       ev.get("ts_us"))
                if key not in seen:
                    seen.add(key)
                    out.append(ev)
        out.sort(key=lambda e: e.get("ts_us", 0.0))
        return out

    def spans(self, flushes=None) -> list[dict]:
        """Every persisted span across trainer, lanes and server."""
        out = []
        for fl in flushes if flushes is not None else self.flushes():
            out.extend(fl["parts"].get("spans", []))
        out.sort(key=lambda s: s.get("ts", 0.0))
        return out

    def attribs(self, flushes=None) -> dict[int, dict]:
        """Per-step attribution; a complete record wins over a partial
        one from a crash flush, later flushes win otherwise."""
        out: dict[int, dict] = {}
        for fl in flushes if flushes is not None else self.flushes():
            for dom_attr in fl["parts"].get("attrib", {}).values():
                for key, a in (dom_attr or {}).items():
                    step = int(key)
                    prev = out.get(step)
                    if prev is not None and not prev["partial"] \
                            and a["partial"]:
                        continue        # complete beats partial
                    out[step] = a
        return out

    def alerts(self, flushes=None) -> list[dict]:
        return [ev for ev in self.events(flushes)
                if ev.get("type") == ALERT]

    def crash_dumps(self, flushes=None) -> list[dict]:
        return [ev for ev in self.events(flushes)
                if ev.get("type") in ("crash.dump", LANE_CRASH)]

    def verdict(self, flushes=None) -> str:
        """Worst run-end verdict across every writing process."""
        order = {"healthy": 0, "degraded": 1, "critical": 2}
        worst = "healthy"
        fls = flushes if flushes is not None else self.flushes()
        latest: dict[str, str] = {}
        for fl in fls:
            for health in fl["parts"].get("health", {}).values():
                if health and "verdict" in health:
                    latest[fl["proc"]] = health["verdict"]
        for v in latest.values():
            if order.get(v, 0) > order[worst]:
                worst = v
        return worst

    def export_perfetto(self, path: str) -> int:
        """Write one merged Chrome-trace/Perfetto JSON for the run —
        trainer, lane and server spans in a single timeline. Returns
        the event count."""
        events = []
        for s in self.spans():
            events.append({
                "name": s["name"], "cat": s.get("cat", "insitu"),
                "ph": "X", "pid": s["pid"], "tid": s["tid"],
                "ts": s["ts"], "dur": s["dur"],
                "args": {**s.get("args", {}),
                         "trace_id": s.get("trace_id"),
                         "span_id": s.get("span_id"),
                         "parent_id": s.get("parent_id")}})
        events.sort(key=lambda e: e["ts"])
        with open(path, "w") as fh:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      fh)
        return len(events)
