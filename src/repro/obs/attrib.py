"""Per-step critical-path attribution from pipeline spans.

Every traced step leaves a family of spans — producer ``submit`` and
``stage.push``, lane ``stage.pop``/``reduce``/``write`` (possibly from
several contributor groups in parallel), ``device.*`` transfers,
``manifest.commit``, and checkpoint ``ckpt.*`` work. This module folds
them into one answer per step: *where did the wall time go?*

Two subtleties make this more than a per-name sum:

* **Parallelism.** Four lanes reducing concurrently spend 4x CPU but
  1x wall; attribution is over the *union* of each stage's time
  intervals, so a stage's share is the wall time during which at least
  one span of that stage was open — the quantity that actually gates
  step latency.
* **Partial steps.** A crashed lane leaves a step without its commit
  span. :class:`Attributor` keeps such steps pending and surfaces them
  with ``partial=True`` when asked (the run ledger flushes pending
  attribution on crash dumps), so a postmortem still shows where an
  interrupted step's time went.
"""
from __future__ import annotations

#: span name -> attribution stage; names absent here fall back to their
#: span ``cat`` (e.g. every ``ckpt.*`` span has cat="ckpt") and then to
#: the name's first dotted token
STAGE_OF_NAME = {
    "submit": "submit",
    "stage.push": "staging",
    "stage.pop": "staging",
    "reduce": "reduce",
    "write": "write",
    "manifest.commit": "commit",
}

STAGE_OF_CAT = {"ckpt": "ckpt", "device": "device", "serve": "serve"}

#: stages named by span-name prefix when neither table matches
_PREFIX_STAGES = ("device", "serve", "ckpt")


def stage_of(span: dict) -> str:
    """Attribution stage of one span dict."""
    name = span.get("name", "")
    st = STAGE_OF_NAME.get(name)
    if st is not None:
        return st
    st = STAGE_OF_CAT.get(span.get("cat", ""))
    if st is not None:
        return st
    head = name.split(".", 1)[0]
    return head if head in _PREFIX_STAGES else "other"


def union_seconds(intervals) -> float:
    """Total coverage of a list of ``(t0_us, t1_us)`` intervals."""
    if not intervals:
        return 0.0
    ivs = sorted(intervals)
    total = 0.0
    lo, hi = ivs[0]
    for a, b in ivs[1:]:
        if a > hi:
            total += hi - lo
            lo, hi = a, b
        else:
            hi = max(hi, b)
    total += hi - lo
    return total / 1e6


def attribute(step: int, spans: list[dict], *, partial: bool = False
              ) -> dict:
    """Fold one step's spans into a stage attribution dict."""
    by_stage: dict[str, list] = {}
    t_min, t_max = float("inf"), float("-inf")
    for sp in spans:
        t0 = float(sp.get("ts", 0.0))
        t1 = t0 + float(sp.get("dur", 0.0))
        t_min, t_max = min(t_min, t0), max(t_max, t1)
        by_stage.setdefault(stage_of(sp), []).append((t0, t1))
    stages = {st: round(union_seconds(ivs), 9)
              for st, ivs in sorted(by_stage.items())}
    total = max(0.0, (t_max - t_min) / 1e6) if spans else 0.0
    covered = union_seconds([iv for ivs in by_stage.values()
                             for iv in ivs])
    critical = max(stages, key=stages.get) if stages else None
    return {"step": int(step), "total_s": round(total, 9),
            "idle_s": round(max(0.0, total - covered), 9),
            "stages": stages, "critical": critical,
            "n_spans": len(spans), "partial": bool(partial)}


class Attributor:
    """Incremental per-step attribution over a span stream.

    Feed span batches with :meth:`ingest`; a step is *complete* once
    its ``manifest.commit`` (or ``ckpt.commit``) span arrives, at which
    point its attribution is returned and the buffered spans released.
    Steps older than ``max_pending`` completed steps are assumed
    abandoned and also flushed (partial) to bound memory.
    """

    #: spans that mark a step's pipeline as finished
    _TERMINAL = {"manifest.commit", "ckpt.commit"}

    def __init__(self, max_pending: int = 256):
        self._spans: dict[int, list[dict]] = {}
        self._done: set[int] = set()
        self.max_pending = int(max_pending)

    def ingest(self, spans) -> list[dict]:
        """Buffer new spans; returns attributions for completed steps."""
        completed = []
        for sp in spans:
            step = (sp.get("args") or {}).get("step")
            if step is None:
                continue
            step = int(step)
            self._spans.setdefault(step, []).append(sp)
            if sp.get("name") in self._TERMINAL:
                completed.append(step)
        out = [attribute(s, self._spans.pop(s))
               for s in dict.fromkeys(completed) if s in self._spans]
        self._done.update(a["step"] for a in out)
        # bound the pending set: steps far behind the newest completed
        # step will never finish (dropped parts, dead lanes)
        if len(self._spans) > self.max_pending:
            horizon = sorted(self._spans)[:-self.max_pending]
            out.extend(attribute(s, self._spans.pop(s), partial=True)
                       for s in horizon)
        return out

    def flush_pending(self) -> list[dict]:
        """Attribution for every incomplete step (crash-dump path)."""
        out = [attribute(s, spans, partial=True)
               for s, spans in sorted(self._spans.items())]
        self._spans.clear()
        return out

    @property
    def pending_steps(self) -> list[int]:
        return sorted(self._spans)
