"""Bounded structured event ring: the run's flight recorder.

Where metrics answer "how much" and spans answer "how long", events
answer "what happened": discrete, typed occurrences on the pipeline —
step begin/commit, staging evictions and backpressure transitions, lane
crashes, device fallbacks, checkpoint commits/rebases, serve-side 429
rejections, health alerts. Each is one small dict appended to a bounded
ring (:class:`EventRing`); emission costs one short uncontended lock
acquire plus a deque append, and events are per-step-or-rarer, so the
hot paths never notice.

The ring is volatile by design — persistence is the run ledger's job
(:mod:`repro.obs.ledger` drains it incrementally via
:meth:`EventRing.drain_since`). What makes it a *flight recorder* is the
crash-dump hook: when a lane dies or the engine aborts, :meth:`dump`
flushes the retained window through every registered hook (the ledger
registers one that forces an immediate durable flush), so the last
``capacity`` events survive the crash on disk.

Emission shares the metrics kill switch (``repro.obs.metrics.ENABLED``)
— "obs off" silences the whole always-on substrate at once, and the
overhead benchmark's bare arm measures the true zero-cost path.
"""
from __future__ import annotations

import collections
import os
import threading

from . import metrics as _metrics
from .trace import now_us

# ------------------------------------------------------ event taxonomy

STEP_BEGIN = "step.begin"              # a step entered the pipeline
STEP_COMMIT = "step.commit"            # its context manifest committed
STAGING_EVICT = "staging.evict"        # drop-oldest displaced a part
STAGING_BACKPRESSURE = "staging.backpressure"   # state: enter|exit
LANE_CRASH = "lane.crash"              # a lane process died unreported
LANE_ERROR = "lane.error"              # a lane's reduce/write failed
DEVICE_FALLBACK = "device.fallback"    # device reduce fell back to host
CKPT_COMMIT = "ckpt.commit"            # checkpoint manifest committed
CKPT_REBASE = "ckpt.rebase"            # delta chain rebased onto a full
SERVE_429 = "serve.429"                # admission control shed a viewer
ALERT = "alert"                        # a health rule fired
CRASH_DUMP = "crash.dump"              # the ring was dump()-flushed
RUN_END = "run.end"                    # ledger closed with a verdict

EVENT_TYPES = frozenset({
    STEP_BEGIN, STEP_COMMIT, STAGING_EVICT, STAGING_BACKPRESSURE,
    LANE_CRASH, LANE_ERROR, DEVICE_FALLBACK, CKPT_COMMIT, CKPT_REBASE,
    SERVE_429, ALERT, CRASH_DUMP, RUN_END,
})

DEFAULT_CAPACITY = 4096


class EventRing:
    """Bounded ring of typed event dicts with crash-dump hooks.

    Events are ``{"seq", "ts_us", "type", "pid", "fields"}``; ``seq``
    is a per-ring lifetime counter, so incremental consumers drain with
    :meth:`drain_since` marks and duplicates are detectable across
    process boundaries by ``(pid, seq)``.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        #: entries are ``(arrival, event)``: the arrival cursor orders
        #: emits *and* ingests, so incremental drains never duplicate
        self._ring: collections.deque[tuple[int, dict]] = \
            collections.deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._count = 0          # locally-emitted events (seq stream)
        self._arrivals = 0       # appended entries incl. ingested
        self._dump_hooks: list = []

    # ----------------------------------------------------------- emit
    def emit(self, etype: str, **fields) -> dict | None:
        """Append one typed event; returns it (None when obs is off)."""
        if not _metrics.ENABLED:
            return None
        if etype not in EVENT_TYPES:
            raise ValueError(f"unknown event type {etype!r}; "
                             f"taxonomy: {sorted(EVENT_TYPES)}")
        ev = {"ts_us": now_us(), "type": etype, "pid": os.getpid(),
              "fields": fields}
        with self._lock:
            self._count += 1
            self._arrivals += 1
            ev["seq"] = self._count
            self._ring.append((self._arrivals, ev))
        return ev

    def ingest(self, events) -> None:
        """Merge event dicts produced elsewhere (e.g. a lane process).

        Foreign events keep their own ``pid``/``seq`` identity but get
        local arrival cursors, so drains stay exactly-once.
        """
        if not events:
            return
        with self._lock:
            for ev in events:
                self._arrivals += 1
                self._ring.append((self._arrivals, ev))

    # ---------------------------------------------------------- read
    def snapshot(self) -> list[dict]:
        with self._lock:
            return [ev for _, ev in self._ring]

    def drain_since(self, mark: int) -> tuple[int, list[dict]]:
        """Retained events that arrived after the ``mark`` cursor;
        returns ``(new_mark, events)``. Start at 0; events that arrived
        and fell off between two drains are lost (see ``dropped``)."""
        with self._lock:
            if mark > self._arrivals:     # ring cleared since that mark
                mark = 0
            return self._arrivals, [ev for arr, ev in self._ring
                                    if arr > mark]

    @property
    def count(self) -> int:
        """Lifetime locally-emitted event count."""
        with self._lock:
            return self._count

    @property
    def dropped(self) -> int:
        """Appended events that fell off the bounded ring."""
        with self._lock:
            return max(0, self._arrivals - len(self._ring))

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._count = 0
            self._arrivals = 0

    # ---------------------------------------------------- crash dumps
    def register_dump_hook(self, fn) -> None:
        """``fn(reason, ring)`` runs on every :meth:`dump` call."""
        with self._lock:
            if fn not in self._dump_hooks:
                self._dump_hooks.append(fn)

    def unregister_dump_hook(self, fn) -> None:
        with self._lock:
            try:
                self._dump_hooks.remove(fn)
            except ValueError:
                pass

    def dump(self, reason: str, **fields) -> list:
        """Flush the ring through every dump hook (lane died, engine
        aborted). Emits a ``crash.dump`` marker first so readers can
        locate the dump in the persisted stream; hook errors are
        collected, never raised — a broken sink must not mask the
        original crash."""
        self.emit(CRASH_DUMP, reason=reason, **fields)
        with self._lock:
            hooks = list(self._dump_hooks)
        errors = []
        for fn in hooks:
            try:
                fn(reason, self)
            except Exception as e:      # noqa: BLE001 — see docstring
                errors.append(e)
        return errors


#: process-global event ring: pipeline call sites emit through this
EVENTS = EventRing()
