"""Process-local metrics: counters, gauges, fixed-bucket histograms.

The in-transit pipeline's measurement substrate (DESIGN.md §15). Three
instrument kinds behind one :class:`MetricsRegistry`:

  * :class:`Counter`   — monotonically increasing float. The write path
    is lock-free: each thread accumulates into its own shard (a slot of
    a plain dict keyed by thread id, written only by that thread — a
    single GIL-atomic read-modify-write), and shards are summed at read.
  * :class:`Gauge`     — last-write-wins value, or a pull ``fn`` sampled
    at collect time (for stats another object already maintains).
  * :class:`Histogram` — fixed bucket boundaries, per-thread shards of
    bucket counts. Quantiles (p50/p90/p99) are estimated at read by
    linear interpolation inside the bucket holding the rank — accuracy
    is bounded by the bucket width (asserted against numpy percentiles
    in ``tests/test_obs.py``).

Labeled families (``registry.counter(name, labels=("endpoint",))``)
materialize one child instrument per label-value tuple so staging areas,
lanes, reducers and server endpoints register under stable names with
bounded cardinality. :meth:`MetricsRegistry.render_prometheus` emits the
Prometheus text exposition format (scraped by ``CatalogServer`` at
``/metrics``); :meth:`MetricsRegistry.snapshot` the JSON twin.

``ENABLED`` is the module kill switch the overhead benchmark flips
(``bench_insitu.run_obs_overhead``): instrumented call sites gate their
observes on it, so the uninstrumented baseline is measurable in-process.
"""
from __future__ import annotations

import bisect
import math
import re
import threading
import time

#: global kill switch consulted by instrumented hot paths (the overhead
#: benchmark measures the pipeline with this off vs on)
ENABLED = True


def set_enabled(on: bool) -> None:
    global ENABLED
    ENABLED = bool(on)


_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _escape_label(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"") \
        .replace("\n", r"\n")


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


# ------------------------------------------------------------ instruments

class Counter:
    """Monotonic counter; per-thread shards merged at read."""

    kind = "counter"

    def __init__(self):
        self._shards: dict[int, float] = {}

    def inc(self, v: float = 1.0) -> None:
        # each thread writes only its own key: one dict slot, GIL-atomic
        tid = threading.get_ident()
        d = self._shards
        d[tid] = d.get(tid, 0.0) + v

    @property
    def value(self) -> float:
        return sum(self._shards.values())

    def sample(self):
        return self.value


class Gauge:
    """Point-in-time value: set directly, or pulled from ``fn``."""

    kind = "gauge"

    def __init__(self):
        self._value = 0.0
        self._fn = None

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    def dec(self, v: float = 1.0) -> None:
        self._value -= v

    def set_function(self, fn) -> None:
        """Sample ``fn()`` at collect time instead of a stored value."""
        self._fn = fn

    @property
    def value(self) -> float:
        return float(self._fn()) if self._fn is not None else self._value

    def sample(self):
        return self.value


#: default latency buckets (seconds): 1 µs .. 60 s, ~x2.5 steps
LATENCY_BUCKETS = (1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4,
                   5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def exponential_buckets(start: float, factor: float, count: int
                        ) -> tuple[float, ...]:
    return tuple(start * factor ** i for i in range(count))


class Histogram:
    """Fixed-bucket histogram with per-thread shards.

    ``observe`` touches only this thread's shard (bucket counts + sum),
    no lock anywhere on the write path. Reads merge every shard.
    """

    kind = "histogram"

    def __init__(self, buckets=None):
        bounds = tuple(sorted(buckets or LATENCY_BUCKETS))
        assert bounds, "histogram needs at least one finite bucket bound"
        self.bounds = bounds                   # finite upper bounds
        self._n = len(bounds) + 1              # + the +Inf bucket
        self._shards: dict[int, list] = {}     # tid -> [counts, sum]

    def observe(self, v: float) -> None:
        tid = threading.get_ident()
        shard = self._shards.get(tid)
        if shard is None:
            shard = self._shards.setdefault(tid, [[0] * self._n, 0.0])
        shard[0][bisect.bisect_left(self.bounds, v)] += 1
        shard[1] += v

    def timeit(self):
        """Context manager observing the elapsed wall seconds."""
        return _Timer(self)

    # ----------------------------------------------------------- reads
    def merged(self) -> tuple[list[int], float, int]:
        """(per-bucket counts, value sum, total count) over all shards."""
        counts = [0] * self._n
        total = 0.0
        for shard in list(self._shards.values()):
            for i, c in enumerate(shard[0]):
                counts[i] += c
            total += shard[1]
        return counts, total, sum(counts)

    @property
    def count(self) -> int:
        return self.merged()[2]

    @property
    def sum(self) -> float:
        return self.merged()[1]

    def quantile(self, q: float) -> float:
        """Estimate the ``q``-quantile by in-bucket interpolation.

        Exact to within the width of the bucket holding the rank; the
        open +Inf bucket reports its lower bound.
        """
        counts, _, n = self.merged()
        if n == 0:
            return math.nan
        rank = q * n
        cum = 0
        for i, c in enumerate(counts):
            prev = cum
            cum += c
            if cum >= rank and c > 0:
                lo = 0.0 if i == 0 else self.bounds[i - 1]
                if i == len(self.bounds):      # the open +Inf bucket
                    return self.bounds[-1]
                hi = self.bounds[i]
                return lo + (hi - lo) * (rank - prev) / c
        return self.bounds[-1]

    def quantiles(self, qs=(0.5, 0.9, 0.99)) -> dict[str, float]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def sample(self):
        counts, total, n = self.merged()
        out = {"count": n, "sum": total,
               "buckets": dict(zip([*map(float, self.bounds), math.inf],
                                   counts))}
        if n:
            out.update(self.quantiles())
        return out


class _Timer:
    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric with 0+ label dimensions.

    With no ``labels`` the family is its single child (attribute access
    forwards), so ``registry.counter("x").inc()`` just works; with
    labels, :meth:`labels` materializes/returns the child for one
    label-value tuple.
    """

    def __init__(self, name: str, kind: str, help: str,
                 labelnames: tuple[str, ...], **kw):
        assert _NAME_RE.match(name), f"bad metric name {name!r}"
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._kw = kw
        self._children: dict[tuple, object] = {}
        self._lock = threading.Lock()
        if not self.labelnames:
            self._children[()] = _KINDS[kind](**kw)

    def labels(self, *values) -> object:
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: got {len(key)} label values for "
                f"{self.labelnames}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, _KINDS[self.kind](
                    **self._kw))
        return child

    def __getattr__(self, attr):
        # unlabeled families act as their single child
        if not self.labelnames:
            return getattr(self._children[()], attr)
        raise AttributeError(
            f"{self.name} has labels {self.labelnames}; use .labels(...)")

    def children(self) -> list[tuple[tuple, object]]:
        with self._lock:
            return sorted(self._children.items())


# -------------------------------------------------------------- registry

class MetricsRegistry:
    """Named instruments + pull callbacks, one coherent read surface.

    Components create (or share) a registry and register instruments
    under stable names; ``snapshot``/``render_prometheus`` give the
    merged view. ``register_callback(fn)`` runs ``fn()`` before every
    collect — the hook that syncs externally-maintained stats (staging
    counters, cache info) into gauges without touching their hot paths.
    """

    def __init__(self):
        self._families: dict[str, Family] = {}
        self._callbacks: list = []
        self._lock = threading.Lock()

    # ------------------------------------------------------ constructors
    def _family(self, name: str, kind: str, help: str, labels, **kw
                ) -> Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.labelnames}")
                return fam
            fam = Family(name, kind, help, tuple(labels), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels=()) -> Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=None) -> Family:
        return self._family(name, "histogram", help, labels,
                            buckets=buckets)

    def register_callback(self, fn) -> None:
        with self._lock:
            self._callbacks.append(fn)

    # ------------------------------------------------------------ reads
    def _collect(self) -> list[Family]:
        with self._lock:
            callbacks = list(self._callbacks)
        # callbacks run first: they may register families lazily
        for fn in callbacks:
            try:
                fn()
            except Exception:       # noqa: BLE001 — a dead component's
                pass                # callback must not poison the scrape
        with self._lock:
            return sorted(self._families.values(),
                          key=lambda f: f.name)

    def snapshot(self) -> dict:
        """JSON-able view: name -> {kind, help, values|series}."""
        out = {}
        for fam in self._collect():
            samples = []
            for key, child in fam.children():
                samples.append({
                    "labels": dict(zip(fam.labelnames, key)),
                    "value": child.sample()})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "samples": samples}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for fam in self._collect():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.children():
                pairs = [f'{n}="{_escape_label(v)}"'
                         for n, v in zip(fam.labelnames, key)]
                if fam.kind == "histogram":
                    counts, total, n = child.merged()
                    cum = 0
                    for bound, c in zip([*child.bounds, math.inf], counts):
                        cum += c
                        lp = ",".join([*pairs, f'le="{_fmt(bound)}"'])
                        lines.append(f"{fam.name}_bucket{{{lp}}} {cum}")
                    suffix = "{" + ",".join(pairs) + "}" if pairs else ""
                    lines.append(f"{fam.name}_sum{suffix} {_fmt(total)}")
                    lines.append(f"{fam.name}_count{suffix} {n}")
                else:
                    suffix = "{" + ",".join(pairs) + "}" if pairs else ""
                    lines.append(
                        f"{fam.name}{suffix} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"


#: process-wide default registry (components may also own private ones —
#: the engine and catalog server do, so two instances never collide)
REGISTRY = MetricsRegistry()
