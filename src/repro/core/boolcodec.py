"""Lossless boolean-array codec: run-length + base-52 character encoding.

Paper §2.2: the AMR refinement/ownership arrays are boolean but stored one
byte per value; even a bitfield wastes space because these arrays contain
long runs of identical values. The paper's codec run-length-encodes the
array and writes run lengths with "base-52 and character encoding",
reaching 63.4 % (refinement) / 99.3 % (ownership) compression *relative to
a bitfield* on the Orion data (1 M cells -> ~1.5 KB in ~0.5 ms).

Encoding used here (the paper does not spell out the digit scheme; this one
is prefix-free, uses exactly 52 letters, and hits the same size regime):

  * Runs alternate starting with value 0. If the array starts with 1, the
    first run has length 0.
  * A run length L >= 0 is written little-endian in base 26 where each
    digit d in [0, 25] maps to 'a'+d when more digits follow and 'A'+d for
    the final digit. 52 characters total; decoding is unambiguous.

A run of 1e6 needs 5 characters ('1e6 = sum d_i * 26^i'), so ownership
arrays with a handful of giant runs collapse to a few bytes.
"""
from __future__ import annotations

import numpy as np

_LOWER = ord("a")
_UPPER = ord("A")


def runs_of(bits: np.ndarray) -> np.ndarray:
    """Run lengths of a boolean array, alternating and starting at value 0."""
    bits = np.asarray(bits, bool)
    if bits.size == 0:
        return np.zeros(0, np.int64)
    change = np.flatnonzero(np.diff(bits.view(np.int8)))
    edges = np.concatenate([[0], change + 1, [bits.size]])
    lengths = np.diff(edges)
    if bits[0]:  # first run must be of value 0
        lengths = np.concatenate([[0], lengths])
    return lengths.astype(np.int64)


def _encode_lengths(lengths: np.ndarray) -> bytes:
    """Vectorized little-endian base-26 with case as the continuation bit."""
    if lengths.size == 0:
        return b""
    # Max digits needed across all runs (bounded, loop over digit index).
    out_cols = []
    rem = lengths.astype(np.int64).copy()
    alive = np.ones(rem.shape, bool)
    while alive.any():
        digit = rem % 26
        rem //= 26
        more = alive & (rem > 0)
        ch = np.where(more, _LOWER + digit, _UPPER + digit).astype(np.uint8)
        ch = np.where(alive, ch, 0).astype(np.uint8)
        out_cols.append(ch)
        alive = more
    cols = np.stack(out_cols, axis=1)  # (runs, max_digits)
    flat = cols.reshape(-1)
    return flat[flat != 0].tobytes()


def _decode_lengths(data: bytes) -> np.ndarray:
    buf = np.frombuffer(data, np.uint8)
    if buf.size == 0:
        return np.zeros(0, np.int64)
    is_final = (buf >= _UPPER) & (buf < _UPPER + 26)
    digit = np.where(is_final, buf - _UPPER, buf - _LOWER).astype(np.int64)
    # Position of each digit within its run: distance since last final char.
    ends = np.flatnonzero(is_final)
    starts = np.concatenate([[0], ends[:-1] + 1])
    run_id = np.repeat(np.arange(ends.size), ends - starts + 1)
    pos = np.arange(buf.size) - starts[run_id]
    vals = digit * (26 ** pos)
    return np.bincount(run_id, weights=vals).astype(np.int64)


def encode(bits: np.ndarray) -> bytes:
    """Boolean array -> base-52 byte string (ASCII letters only)."""
    return _encode_lengths(runs_of(bits))


def decode(data: bytes, n: int | None = None) -> np.ndarray:
    """Inverse of :func:`encode`. ``n`` (if given) checks the total length."""
    lengths = _decode_lengths(data)
    total = int(lengths.sum())
    if n is not None and total != n:
        raise ValueError(f"decoded length {total} != expected {n}")
    vals = (np.arange(lengths.size) % 2).astype(bool)
    out = np.repeat(vals, lengths)
    return out


def bitfield_bytes(n: int) -> int:
    """Size of the bitfield equivalent the paper compares against."""
    return max(1, (n + 7) // 8)


def compression_vs_bitfield(bits: np.ndarray) -> float:
    """Paper fig. 4 metric: 1 - len(encoded)/len(bitfield)."""
    enc = encode(bits)
    return 1.0 - len(enc) / bitfield_bytes(len(bits))
