"""3D (n-D) Hilbert space-filling curve, vectorized (Skilling's algorithm).

RAMSES load-balances AMR cells over MPI ranks by sorting cells along a
Hilbert curve and cutting the curve into equal-count segments (paper §2.1:
"Because of the Hilbert space filling curve, domain boundaries of Ramses can
occur on leafs of the tree and at different levels"). We reproduce that
domain decomposition for the simulation substrate.

Reference: J. Skilling, "Programming the Hilbert curve", AIP Conf. Proc.
707 (2004). Transpose-form algorithm, vectorized over points with numpy.
"""
from __future__ import annotations

import numpy as np


def coords_to_key(coords: np.ndarray, bits: int, ndim: int = 3) -> np.ndarray:
    """Map integer coords (N, ndim) in [0, 2**bits) to Hilbert keys (N,)."""
    x = np.array(coords, dtype=np.uint64, copy=True)
    n = x.shape[0]
    if x.shape[1] != ndim:
        raise ValueError(f"coords must be (N, {ndim})")
    m = np.uint64(1) << np.uint64(bits - 1)
    # Inverse undo excess work
    q = m
    while q > 1:
        p = q - np.uint64(1)
        for i in range(ndim):
            flip = (x[:, i] & q) != 0
            # invert low bits of x[0] where flip
            x[:, 0] = np.where(flip, x[:, 0] ^ p, x[:, 0])
            # else exchange low bits of x[i] and x[0]
            t = (x[:, 0] ^ x[:, i]) & p
            t = np.where(flip, np.uint64(0), t)
            x[:, 0] ^= t
            x[:, i] ^= t
        q >>= np.uint64(1)
    # Gray encode
    for i in range(1, ndim):
        x[:, i] ^= x[:, i - 1]
    t = np.zeros(n, np.uint64)
    q = m
    while q > 1:
        t = np.where((x[:, ndim - 1] & q) != 0, t ^ (q - np.uint64(1)), t)
        q >>= np.uint64(1)
    for i in range(ndim):
        x[:, i] ^= t
    # Interleave transpose-form bits into a single key
    key = np.zeros(n, np.uint64)
    for b in range(bits - 1, -1, -1):
        for i in range(ndim):
            bit = (x[:, i] >> np.uint64(b)) & np.uint64(1)
            key = (key << np.uint64(1)) | bit
    return key


def key_to_coords(keys: np.ndarray, bits: int, ndim: int = 3) -> np.ndarray:
    """Inverse of :func:`coords_to_key`."""
    keys = np.asarray(keys, np.uint64)
    n = keys.shape[0]
    x = np.zeros((n, ndim), np.uint64)
    # De-interleave into transpose form
    pos = bits * ndim
    for b in range(bits - 1, -1, -1):
        for i in range(ndim):
            pos -= 1
            bit = (keys >> np.uint64(pos)) & np.uint64(1)
            x[:, i] |= bit << np.uint64(b)
    # Gray decode
    m = np.uint64(1) << np.uint64(bits)
    t = x[:, ndim - 1] >> np.uint64(1)
    for i in range(ndim - 1, 0, -1):
        x[:, i] ^= x[:, i - 1]
    x[:, 0] ^= t
    # Undo excess work
    q = np.uint64(2)
    while q != m:
        p = q - np.uint64(1)
        for i in range(ndim - 1, -1, -1):
            flip = (x[:, i] & q) != 0
            x[:, 0] = np.where(flip, x[:, 0] ^ p, x[:, 0])
            tt = (x[:, 0] ^ x[:, i]) & p
            tt = np.where(flip, np.uint64(0), tt)
            x[:, 0] ^= tt
            x[:, i] ^= tt
        q <<= np.uint64(1)
    return x


def domain_split(keys: np.ndarray, n_domains: int) -> np.ndarray:
    """Assign each key's cell to a domain by equal-count Hilbert segments.

    Returns (N,) int32 domain ids. Ties broken by sort order, matching
    RAMSES' contiguous-curve-segment ownership.
    """
    order = np.argsort(keys, kind="stable")
    n = keys.shape[0]
    dom_of_rank = (np.arange(n, dtype=np.int64) * n_domains) // n
    out = np.empty(n, np.int32)
    out[order] = dom_of_rank.astype(np.int32)
    return out
