"""AMR octree model in the Hercule HDep "AMR-3D" linearization.

Paper §2: each MPI process stores its piece of the AMR structure as a
*self-describing object* whose key attributes are two boolean arrays in
breadth-first order (top level -> bottom level, left -> right inside each
level):

  * ``refine``  — True: coarse cell (has 2**ndim children), False: leaf.
  * ``owner``   — True: cell belongs to this process, False: ghost.

Children of refined node i sit contiguously at the next level, ordered by
the node's rank among refined nodes of its level; inside a father the
children follow Morton order (k = ix + 2*iy + 4*iz).

The flat-array representation here is exactly that linearization, plus
derived navigation arrays (levels, child_start, parent) and per-node
physical fields — coarse "father" cells carry values too (restriction of
their sons), which is what the father–son delta compression predicts from.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def morton3(coords: np.ndarray) -> np.ndarray:
    """Interleave (N, 3) int coords into 64-bit Morton codes (<=21 bits/axis)."""
    def spread(v):
        v = v.astype(np.uint64)
        v = (v | (v << np.uint64(32))) & np.uint64(0x1F00000000FFFF)
        v = (v | (v << np.uint64(16))) & np.uint64(0x1F0000FF0000FF)
        v = (v | (v << np.uint64(8))) & np.uint64(0x100F00F00F00F00F)
        v = (v | (v << np.uint64(4))) & np.uint64(0x10C30C30C30C30C3)
        v = (v | (v << np.uint64(2))) & np.uint64(0x1249249249249249)
        return v
    return (spread(coords[:, 0]) | (spread(coords[:, 1]) << np.uint64(1))
            | (spread(coords[:, 2]) << np.uint64(2)))


CHILD_OFFSETS = np.array(
    [[i & 1, (i >> 1) & 1, (i >> 2) & 1] for i in range(8)], np.int64)


@dataclasses.dataclass
class AMRTree:
    """Flat BFS-linearized octree with ownership and per-node fields."""

    refine: np.ndarray                 # (n_nodes,) bool
    owner: np.ndarray                  # (n_nodes,) bool
    level_offsets: np.ndarray          # (n_levels+1,) int64
    coords: np.ndarray                 # (n_nodes, 3) int64, per-level integer coords
    fields: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    # ---------------------------------------------------------- properties
    @property
    def n_nodes(self) -> int:
        return int(self.refine.shape[0])

    @property
    def n_levels(self) -> int:
        return int(self.level_offsets.shape[0] - 1)

    @property
    def n_leaves(self) -> int:
        return int((~self.refine).sum())

    def levels(self) -> np.ndarray:
        """(n_nodes,) level of each node."""
        out = np.zeros(self.n_nodes, np.int32)
        for l in range(self.n_levels):
            out[self.level_offsets[l]:self.level_offsets[l + 1]] = l
        return out

    def level_slice(self, l: int) -> slice:
        return slice(int(self.level_offsets[l]), int(self.level_offsets[l + 1]))

    # ---------------------------------------------------------- navigation
    def child_start(self) -> np.ndarray:
        """(n_nodes,) index of first child for refined nodes, -1 for leaves."""
        out = np.full(self.n_nodes, -1, np.int64)
        for l in range(self.n_levels - 1):
            sl = self.level_slice(l)
            ref = self.refine[sl]
            rank = np.cumsum(ref) - ref  # refined-rank within level
            out[sl][ref] = 0  # placeholder to keep shapes; assign below
            idx = np.flatnonzero(ref) + sl.start
            out[idx] = self.level_offsets[l + 1] + 8 * rank[ref]
        return out

    def parent(self) -> np.ndarray:
        """(n_nodes,) parent index, -1 for root level."""
        out = np.full(self.n_nodes, -1, np.int64)
        cs = self.child_start()
        refined = np.flatnonzero(self.refine)
        for k in range(8):
            out[cs[refined] + k] = refined
        return out

    # ---------------------------------------------------------- validation
    def validate(self) -> None:
        assert self.refine.shape == self.owner.shape == (self.n_nodes,)
        assert self.coords.shape == (self.n_nodes, 3)
        for l in range(self.n_levels - 1):
            n_ref = int(self.refine[self.level_slice(l)].sum())
            n_next = int(self.level_offsets[l + 2] - self.level_offsets[l + 1])
            assert n_next == 8 * n_ref, f"level {l}: {n_next} != 8*{n_ref}"
        if self.n_levels:
            assert not self.refine[self.level_slice(self.n_levels - 1)].any(), \
                "deepest level must be all leaves"
        # children coords must be 2*parent + morton offset
        cs = self.child_start()
        refined = np.flatnonzero(self.refine)
        if refined.size:
            for k in range(8):
                got = self.coords[cs[refined] + k]
                want = 2 * self.coords[refined] + CHILD_OFFSETS[k]
                assert np.array_equal(got, want), "child coords broken"
        for f, v in self.fields.items():
            assert v.shape[0] == self.n_nodes, f"field {f} wrong length"

    # ---------------------------------------------------------- fields
    def restrict_fields_upward(self) -> None:
        """Recompute coarse-node field values as the mean of their sons.

        Intensive ("non conservative" in the paper's wording) restriction:
        the father value is the average of its 8 sons, which is exactly the
        predictor the father–son codec assumes.
        """
        cs = self.child_start()
        for name, v in self.fields.items():
            for l in range(self.n_levels - 2, -1, -1):
                sl = self.level_slice(l)
                idx = np.flatnonzero(self.refine[sl]) + sl.start
                if idx.size == 0:
                    continue
                sons = v[(cs[idx][:, None] + np.arange(8)[None, :]).ravel()]
                v[idx] = sons.reshape(-1, 8).mean(axis=1)

    def leaf_mask(self) -> np.ndarray:
        return ~self.refine

    # ---------------------------------------------------------- serialization
    def to_arrays(self) -> dict[str, np.ndarray]:
        return {
            "refine": self.refine,
            "owner": self.owner,
            "level_offsets": self.level_offsets,
            "coords": self.coords,
            **{f"field:{k}": v for k, v in self.fields.items()},
        }

    @staticmethod
    def from_arrays(arrs: dict[str, np.ndarray]) -> "AMRTree":
        fields = {k[len("field:"):]: v for k, v in arrs.items() if k.startswith("field:")}
        return AMRTree(refine=np.asarray(arrs["refine"], bool),
                       owner=np.asarray(arrs["owner"], bool),
                       level_offsets=np.asarray(arrs["level_offsets"], np.int64),
                       coords=np.asarray(arrs["coords"], np.int64),
                       fields=fields)


def subset_tree(tree: AMRTree, keep: np.ndarray, force_leaf: np.ndarray | None = None) -> AMRTree:
    """Extract the sub-tree of ``keep`` nodes, re-linearized in BFS order.

    ``keep`` must be closed: if a refined node is kept and not forced leaf,
    all 8 children are kept; ancestors of kept nodes are kept. Nodes in
    ``force_leaf`` are demoted to leaves (their kept descendants dropped).
    """
    keep = keep.copy()
    refine = tree.refine.copy()
    if force_leaf is not None:
        refine[force_leaf] = False
    cs = tree.child_start()
    # Drop descendants of forced leaves, top-down.
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        par = np.flatnonzero(tree.refine[sl]) + sl.start
        dead_par = par[~keep[par] | ~refine[par]]
        for k in range(8):
            keep[cs[dead_par] + k] = False
        # children of dropped nodes can't be refined either
        kids = (cs[dead_par][:, None] + np.arange(8)[None, :]).ravel()
        refine[kids] = False
    new_index = np.cumsum(keep) - 1
    offsets = [0]
    for l in range(tree.n_levels):
        offsets.append(offsets[-1] + int(keep[tree.level_slice(l)].sum()))
    offsets = np.asarray(offsets, np.int64)
    # trim empty deepest levels
    n_lv = len(offsets) - 1
    while n_lv > 1 and offsets[n_lv] == offsets[n_lv - 1]:
        n_lv -= 1
    offsets = offsets[:n_lv + 1]
    sel = np.flatnonzero(keep)
    return AMRTree(
        refine=refine[sel],
        owner=tree.owner[sel],
        level_offsets=offsets,
        coords=tree.coords[sel],
        fields={k: v[sel].copy() for k, v in tree.fields.items()},
    )
