"""Vectorized variable-length bit packing on uint32 words.

TPU adaptation note (DESIGN.md §2): the paper's sequential CPU codec packs a
variable-length bitstream byte by byte. On TPU there is no scalar path worth
using, so packing is expressed as a cumsum + dual segment-sum over disjoint
bit ranges — every lane writes its value's low/high word contribution and the
(disjoint-bit) sum reassembles the stream. Works under jit with a static
word-count upper bound, and on host with the exact count.

All values are uint32; 64-bit payloads are handled by the callers as (hi, lo)
uint32 pairs (TPU has no native int64 — see DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

UINT32_FULL = np.uint32(0xFFFFFFFF)


def _mask(nbits: jnp.ndarray) -> jnp.ndarray:
    """Bitmask with the low ``nbits`` set; nbits in [0, 32]."""
    nbits = nbits.astype(jnp.uint32)
    # (1 << 32) overflows, so split on the boundary.
    safe = jnp.where(nbits >= 32, 0, nbits)
    m = (jnp.uint32(1) << safe) - jnp.uint32(1)
    return jnp.where(nbits >= 32, jnp.uint32(UINT32_FULL), m)


def _shr(x: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Logical right shift that is well-defined for n in [0, 32]."""
    n = n.astype(jnp.uint32)
    safe = jnp.where(n >= 32, 0, n)
    return jnp.where(n >= 32, jnp.uint32(0), (x >> safe))


def _shl(x: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Left shift that is well-defined for n in [0, 32]."""
    n = n.astype(jnp.uint32)
    safe = jnp.where(n >= 32, 0, n)
    return jnp.where(n >= 32, jnp.uint32(0), (x << safe))


def pack_bits(values: jnp.ndarray, nbits: jnp.ndarray, num_words: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack ``values[i]``'s low ``nbits[i]`` bits densely into uint32 words.

    Args:
      values: (M,) uint32 payloads (only the low nbits are stored).
      nbits:  (M,) int32 in [0, 32], bits to keep per value.
      num_words: static output length (>= ceil(sum(nbits)/32)).

    Returns:
      (words, total_bits): (num_words,) uint32 and the scalar bit count.
    """
    values = values.astype(jnp.uint32)
    nbits = nbits.astype(jnp.uint32)
    offsets = jnp.cumsum(nbits) - nbits  # exclusive prefix
    total_bits = jnp.sum(nbits)
    word_idx = (offsets >> 5).astype(jnp.int32)
    bit_in = (offsets & 31).astype(jnp.uint32)

    masked = values & _mask(nbits)
    lo = _shl(masked, bit_in)
    hi = _shr(masked, jnp.uint32(32) - bit_in)  # 0 when bit_in == 0

    words = jax.ops.segment_sum(lo, word_idx, num_segments=num_words)
    words = words + jax.ops.segment_sum(hi, jnp.minimum(word_idx + 1, num_words - 1),
                                        num_segments=num_words)
    return words.astype(jnp.uint32), total_bits


def unpack_bits(words: jnp.ndarray, offsets: jnp.ndarray, nbits: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_bits` given per-value bit offsets.

    Args:
      words: (W,) uint32 stream.
      offsets: (M,) exclusive bit offsets (cumsum(nbits) - nbits).
      nbits: (M,) int in [0, 32].

    Returns:
      (M,) uint32 payloads (high bits zero).
    """
    offsets = offsets.astype(jnp.uint32)
    nbits = nbits.astype(jnp.uint32)
    word_idx = (offsets >> 5).astype(jnp.int32)
    bit_in = offsets & 31
    padded = jnp.concatenate([words.astype(jnp.uint32), jnp.zeros((1,), jnp.uint32)])
    w0 = padded[word_idx]
    w1 = padded[jnp.minimum(word_idx + 1, padded.shape[0] - 1)]
    lo = _shr(w0, bit_in)
    hi = _shl(w1, jnp.uint32(32) - bit_in)
    hi = jnp.where(bit_in == 0, jnp.uint32(0), hi)
    return (lo | hi) & _mask(nbits)


# ---------------------------------------------------------------------------
# Host-side convenience (exact sizing, numpy in/out).
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << (max(1, n) - 1).bit_length()


_pack_jit = jax.jit(pack_bits, static_argnums=2)
_unpack_jit = jax.jit(unpack_bits)


def pack_bits_host(values: np.ndarray, nbits: np.ndarray) -> tuple[np.ndarray, int]:
    """Host packing with exact output size. Returns (words, total_bits).

    Shapes are bucketed to powers of two (zero-bit padding entries) so the
    jit cache is hit across calls with varying sizes — without this, every
    AMR level/domain would trigger a recompile.
    """
    nbits = np.asarray(nbits, np.int64)
    m = int(nbits.shape[0])
    total = int(nbits.sum())
    mpad = _next_pow2(m)
    vals_p = np.zeros(mpad, np.uint32)
    vals_p[:m] = np.asarray(values, np.uint32)
    nb_p = np.zeros(mpad, np.int32)
    nb_p[:m] = nbits
    num_words = _next_pow2(max(1, (total + 31) // 32))
    words, _ = _pack_jit(jnp.asarray(vals_p), jnp.asarray(nb_p), num_words)
    return np.asarray(words)[: max(1, (total + 31) // 32)].copy(), total


def unpack_bits_host(words: np.ndarray, nbits: np.ndarray) -> np.ndarray:
    nbits64 = np.asarray(nbits, np.int64)
    m = int(nbits64.shape[0])
    offsets = np.cumsum(nbits64) - nbits64
    mpad = _next_pow2(m)
    off_p = np.zeros(mpad, np.uint32)
    off_p[:m] = offsets.astype(np.uint32)
    nb_p = np.zeros(mpad, np.int32)
    nb_p[:m] = nbits64
    wpad = _next_pow2(int(np.asarray(words).shape[0]))
    words_p = np.zeros(wpad, np.uint32)
    words_p[: np.asarray(words).shape[0]] = np.asarray(words, np.uint32)
    out = _unpack_jit(jnp.asarray(words_p), jnp.asarray(off_p),
                      jnp.asarray(nb_p))
    return np.asarray(out)[:m].copy()


# ---------------------------------------------------------------------------
# (hi, lo) pair helpers for 64-bit payloads.
# ---------------------------------------------------------------------------

def f64_to_pair(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """View float64 as (hi, lo) uint32 pair arrays (little-endian layout)."""
    v = np.ascontiguousarray(x, np.float64).view(np.uint32).reshape(*x.shape, 2)
    return v[..., 1].copy(), v[..., 0].copy()


def pair_to_f64(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    out = np.empty((*hi.shape, 2), np.uint32)
    out[..., 1] = hi
    out[..., 0] = lo
    return out.view(np.float64).reshape(hi.shape)


def f32_to_u32(x: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(x, np.float32).view(np.uint32)


def u32_to_f32(x: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(x, np.uint32).view(np.float32)


def bf16_to_u32(x) -> np.ndarray:
    """bfloat16 -> uint16 payload widened to uint32 (high 16 bits zero)."""
    import ml_dtypes  # bundled with jax
    a = np.ascontiguousarray(np.asarray(x, dtype=ml_dtypes.bfloat16))
    return a.view(np.uint16).astype(np.uint32)


def u32_to_bf16(x: np.ndarray):
    import ml_dtypes
    return np.ascontiguousarray(x, np.uint32).astype(np.uint16).view(ml_dtypes.bfloat16)


@functools.lru_cache(maxsize=None)
def _popcount_table():
    return np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1)
