"""Father–son lossless FP delta compression (paper §2.3).

The predictor for an AMR cell's value is its *father* cell's value (which
RAMSES already stores — the intensive restriction of its sons). Per group
of 8 sons:

  1. residue_j = bits(son_j) XOR bits(father)      (lossless delta)
  2. m = OR_j residue_j; nlz = clz(m)              (shared leading zeros)
  3. nlz is clamped to 2**zbits - 1 (default zbits=4 -> <= 15, the paper's
     default; "this parameter can be optimized at runtime") and stored as a
     zbits-wide code; every residue is stored with width - nlz bits.

Asymptotic best rate at zbits=4/width=64: (8*15-4)/(8*64) = 22.66 % — the
paper's "22.65 %". Measured on Orion data the paper gets 16.26 % (density,
~11 zeros stripped) and 17.91 % (v_y, ~12): reproduced by
``benchmarks/bench_fpdelta.py``.

Format note (TPU adaptation, DESIGN.md §2): codes and residues go to two
separate packed streams instead of an interleaved one so that decode is a
pure vectorized cumsum+gather — same total size, no sequential walk. The
paper's top-down order is kept: groups are emitted level by level, so
partial decompression down to a chosen level works (``decode_to_level``).

Everything here is host-side numpy orchestration; the compute-hot inner
step (XOR + group-OR + CLZ) has a Pallas TPU kernel in
``repro.kernels.fpdelta_kernel`` with this module as its oracle.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import bitstream as bs
from .amr import AMRTree

WIDTHS = (16, 32, 64)


def _clz32(x: np.ndarray) -> np.ndarray:
    """Count leading zeros of uint32, vectorized (clz(0) = 32)."""
    x = np.asarray(x, np.uint32)
    # float64 mantissa (53 bits) represents uint32 exactly; frexp gives bitlength
    exp = np.frexp(x.astype(np.float64))[1]
    return (32 - exp).astype(np.int32)


def group_residues(pred_hi, pred_lo, son_hi, son_lo, zbits: int, width: int):
    """Residues + clamped shared leading-zero count per group.

    pred_*: (G,) or (G, S) predictor bit patterns; son_*: (G, S).
    Returns (res_hi (G,S), res_lo (G,S), nlz (G,) int32).
    """
    g, s = son_hi.shape
    if g == 0:
        return (np.zeros((0, s), np.uint32), np.zeros((0, s), np.uint32),
                np.zeros((0,), np.int32))
    pred_hi = np.broadcast_to(np.asarray(pred_hi, np.uint32).reshape(g, -1), son_hi.shape)
    pred_lo = np.broadcast_to(np.asarray(pred_lo, np.uint32).reshape(g, -1), son_lo.shape)
    res_hi = son_hi ^ pred_hi
    res_lo = son_lo ^ pred_lo
    m_hi = np.bitwise_or.reduce(res_hi, axis=1)
    m_lo = np.bitwise_or.reduce(res_lo, axis=1)
    if width == 64:
        nlz = np.where(m_hi != 0, _clz32(m_hi), 32 + _clz32(m_lo))
    elif width == 32:
        nlz = _clz32(m_lo)
    else:  # 16-bit payload in lo
        nlz = _clz32(m_lo) - 16
    nlz = np.minimum(nlz, (1 << zbits) - 1).astype(np.int32)
    return res_hi, res_lo, nlz


@dataclasses.dataclass
class Compressed:
    """A compressed stream of S-son groups."""
    codes: np.ndarray        # packed zbits-wide nlz codes (uint32 words)
    payload: np.ndarray      # packed residues (uint32 words)
    n_groups: int
    group_size: int
    zbits: int
    width: int               # 16 / 32 / 64

    @property
    def nbytes(self) -> int:
        return self.codes.nbytes + self.payload.nbytes

    def rate_vs_raw(self) -> float:
        raw = self.n_groups * self.group_size * (self.width // 8)
        return 1.0 - self.nbytes / raw if raw else 0.0


def _to_bits(x: np.ndarray, width: int):
    if width == 64:
        return bs.f64_to_pair(np.asarray(x, np.float64))
    if width == 32:
        return np.zeros(x.shape, np.uint32), bs.f32_to_u32(np.asarray(x, np.float32))
    return np.zeros(x.shape, np.uint32), bs.bf16_to_u32(x)


def _from_bits(hi: np.ndarray, lo: np.ndarray, width: int):
    if width == 64:
        return bs.pair_to_f64(hi, lo)
    if width == 32:
        return bs.u32_to_f32(lo)
    return bs.u32_to_bf16(lo)


def encode(pred: np.ndarray, sons: np.ndarray, *, zbits: int = 4,
           width: int = 64) -> Compressed:
    """Compress ``sons`` (G, S) floats against predictor ``pred`` (G,) or (G, S)."""
    assert width in WIDTHS
    G, S = sons.shape
    ph, plo = _to_bits(np.asarray(pred), width)
    sh, slo = _to_bits(np.asarray(sons), width)
    res_hi, res_lo, nlz = group_residues(ph, plo, sh, slo, zbits, width)
    nbits = (width - nlz).astype(np.int64)            # per son, per group

    codes, _ = bs.pack_bits_host(nlz.astype(np.uint32),
                                 np.full(G, zbits, np.int32))
    if width == 64:
        # each son -> two entries: (lo, min(nbits,32)) then (hi, nbits-32)
        nb = np.repeat(nbits, S)
        vals = np.empty(G * S * 2, np.uint32)
        lens = np.empty(G * S * 2, np.int64)
        vals[0::2] = res_lo.ravel(); lens[0::2] = np.minimum(nb, 32)
        vals[1::2] = res_hi.ravel(); lens[1::2] = np.maximum(nb - 32, 0)
        payload, _ = bs.pack_bits_host(vals, lens.astype(np.int32))
    else:
        nb = np.repeat(np.minimum(nbits, width), S)
        payload, _ = bs.pack_bits_host(res_lo.ravel().astype(np.uint32),
                                       nb.astype(np.int32))
    return Compressed(codes=codes, payload=payload, n_groups=G, group_size=S,
                      zbits=zbits, width=width)


def decode(blk: Compressed, pred: np.ndarray) -> np.ndarray:
    """Inverse of :func:`encode`; ``pred`` must match the encode-time predictor."""
    G, S, width = blk.n_groups, blk.group_size, blk.width
    if G == 0:
        return np.zeros((0, S), np.float64 if width == 64 else np.float32)
    nlz = bs.unpack_bits_host(blk.codes, np.full(G, blk.zbits, np.int32))
    nbits = (np.int64(width) - nlz.astype(np.int64))
    nb = np.repeat(nbits, S)
    if width == 64:
        lens = np.empty(G * S * 2, np.int64)
        lens[0::2] = np.minimum(nb, 32)
        lens[1::2] = np.maximum(nb - 32, 0)
        flat = bs.unpack_bits_host(blk.payload, lens.astype(np.int32))
        res_lo = flat[0::2].reshape(G, S)
        res_hi = flat[1::2].reshape(G, S)
    else:
        flat = bs.unpack_bits_host(blk.payload, nb.astype(np.int32))
        res_lo = flat.reshape(G, S)
        res_hi = np.zeros((G, S), np.uint32)
    ph, plo = _to_bits(np.asarray(pred), width)
    ph = np.broadcast_to(ph.reshape(G, -1), (G, S))
    plo = np.broadcast_to(plo.reshape(G, -1), (G, S))
    return _from_bits(res_hi ^ ph, res_lo ^ plo, width)


# ------------------------------------------------------------------ trees

@dataclasses.dataclass
class TreeCompressed:
    """Level-fused compressed field over an AMR tree (top-down decodable).

    The paper's format is conceptually per-level; here all levels' groups
    are packed into ONE stream in level-major order (a beyond-paper perf
    change: one vectorized encode per field instead of one per level; the
    prefix property keeps partial decompression to a level intact).
    ``level_groups[l]`` = number of 8-son groups contributed by level l.
    """
    root_raw: np.ndarray             # level-0 values, stored raw
    stream: Compressed               # all groups, level-major
    level_groups: list[int]
    field: str
    width: int

    @property
    def nbytes(self) -> int:
        return self.root_raw.nbytes + self.stream.nbytes

    # kept for older callers/tests
    @property
    def levels(self):
        return [self.stream]


def _tree_groups(tree: AMRTree, v: np.ndarray):
    cs = tree.child_start()
    preds, sons, counts = [], [], []
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        fathers = np.flatnonzero(tree.refine[sl]) + sl.start
        counts.append(fathers.size)
        if fathers.size:
            preds.append(v[fathers])
            sons.append(v[(cs[fathers][:, None] + np.arange(8)[None, :])])
    pred = np.concatenate(preds) if preds else np.zeros(0)
    son = np.concatenate(sons) if sons else np.zeros((0, 8))
    return pred, son, counts


def encode_tree_field(tree: AMRTree, field: str, *, zbits: int = 4,
                      width: int = 64) -> TreeCompressed:
    """Compress a per-node field (fathers predict sons), level-fused."""
    v = tree.fields[field]
    pred, sons, counts = _tree_groups(tree, v)
    stream = encode(pred, sons, zbits=zbits, width=width)
    root = v[tree.level_slice(0)].astype(np.float64 if width == 64 else np.float32)
    return TreeCompressed(root_raw=root.copy(), stream=stream,
                          level_groups=counts, field=field, width=width)


def _unpack_residues(blk: Compressed, n_groups: int | None = None):
    """Unpack nlz codes + residue bit patterns for the first ``n_groups``
    groups (prefix slice = the paper's level-bounded partial decode)."""
    G, S, width = blk.n_groups, blk.group_size, blk.width
    n = G if n_groups is None else min(n_groups, G)
    nlz = bs.unpack_bits_host(blk.codes, np.full(G, blk.zbits, np.int32))[:n]
    nbits = (np.int64(width) - nlz.astype(np.int64))
    nb = np.repeat(nbits, S)
    if width == 64:
        lens = np.empty(n * S * 2, np.int64)
        lens[0::2] = np.minimum(nb, 32)
        lens[1::2] = np.maximum(nb - 32, 0)
        flat = bs.unpack_bits_host(blk.payload, lens.astype(np.int32))
        return flat[1::2].reshape(n, S), flat[0::2].reshape(n, S)  # (hi, lo)
    flat = bs.unpack_bits_host(blk.payload, nb.astype(np.int32))
    return np.zeros((n, S), np.uint32), flat.reshape(n, S)


def decode_tree_field(tree: AMRTree, tc: TreeCompressed,
                      to_level: int | None = None) -> np.ndarray:
    """Decode top-down; ``to_level`` stops early (partial decompression —
    the paper's memory-saving visualization path). Values beyond the level
    are left zero. Residues are unpacked in one vectorized pass; the
    level walk is a pure XOR chain (fathers from the already-decoded
    level)."""
    n_levels = tree.n_levels if to_level is None else min(to_level + 1,
                                                          tree.n_levels)
    width = tc.width
    v = np.zeros(tree.n_nodes, np.float64 if width == 64 else np.float32)
    v[tree.level_slice(0)] = tc.root_raw
    need = sum(tc.level_groups[:max(0, n_levels - 1)])
    res_hi, res_lo = _unpack_residues(tc.stream, need)
    cs = tree.child_start()
    g0 = 0
    for l in range(n_levels - 1):
        sl = tree.level_slice(l)
        fathers = np.flatnonzero(tree.refine[sl]) + sl.start
        g1 = g0 + fathers.size
        if fathers.size == 0:
            continue
        ph, plo = _to_bits(v[fathers], width)
        sh = res_hi[g0:g1] ^ ph[:, None]
        slo = res_lo[g0:g1] ^ plo[:, None]
        sons = _from_bits(sh, slo, width)
        v[(cs[fathers][:, None] + np.arange(8)[None, :])] = \
            np.asarray(sons, v.dtype)
        g0 = g1
    return v


def tree_field_rate(tree: AMRTree, tc: TreeCompressed) -> float:
    """Paper figs. 5/6 metric: 1 - compressed/raw over the whole field."""
    raw = tree.n_nodes * (tc.width // 8)
    return 1.0 - tc.nbytes / raw
