"""Hilbert-curve domain decomposition + local-tree (ghost zone) extraction.

Reproduces RAMSES' data layout that the paper prunes:

  * leaves are ordered along a 3D Hilbert curve at the finest level and cut
    into equal-count segments -> one *domain* per MPI process;
  * each domain's local tree contains (a) its own leaves, (b) ghost
    neighbor leaves (stencil halo), and (c) a *degraded global* coarse view
    of the whole box down to ``coarse_level`` (multigrid requirement);
  * coarse ownership: a coarse cell is owned iff any descendant leaf is.

The redundancy introduced by (b)+(c) is what :mod:`repro.core.prune`
removes for the post-processing (HDep) flow.
"""
from __future__ import annotations

import numpy as np

from . import hilbert
from .amr import AMRTree, morton3, subset_tree


def leaf_hilbert_keys(tree: AMRTree) -> np.ndarray:
    """Hilbert key (at the finest level) of each leaf's first fine cell."""
    max_level = tree.n_levels - 1
    leaves = np.flatnonzero(~tree.refine)
    lv = tree.levels()[leaves]
    fine = tree.coords[leaves].astype(np.uint64) << (max_level - lv)[:, None].astype(np.uint64)
    return hilbert.coords_to_key(fine, bits=max(max_level, 1))


def assign_domains(tree: AMRTree, n_domains: int) -> np.ndarray:
    """(n_leaves,) domain id per leaf, contiguous along the Hilbert curve."""
    keys = leaf_hilbert_keys(tree)
    return hilbert.domain_split(keys, n_domains)


class _LevelIndex:
    """Per-level sorted-Morton index for covering-leaf queries."""

    def __init__(self, tree: AMRTree):
        self.tree = tree
        self.max_level = tree.n_levels - 1
        self.codes = []
        self.node_ids = []
        for l in range(tree.n_levels):
            sl = tree.level_slice(l)
            ids = np.arange(sl.start, sl.stop, dtype=np.int64)
            codes = morton3(tree.coords[sl])
            order = np.argsort(codes)
            self.codes.append(codes[order])
            self.node_ids.append(ids[order])

    def covering_leaf(self, fine_coords: np.ndarray) -> np.ndarray:
        """Leaf node id covering each fine-level coordinate (-1 if none)."""
        out = np.full(fine_coords.shape[0], -1, np.int64)
        todo = np.ones(fine_coords.shape[0], bool)
        for l in range(self.tree.n_levels):
            shift = np.uint64(self.max_level - l)
            c = (fine_coords.astype(np.uint64) >> shift)
            q = morton3(c)
            pos = np.searchsorted(self.codes[l], q)
            pos = np.minimum(pos, len(self.codes[l]) - 1)
            hit = (self.codes[l][pos] == q) & todo
            node = self.node_ids[l][pos]
            is_leaf = ~self.tree.refine[node]
            take = hit & is_leaf
            out[take] = node[take]
            todo &= ~take
            if not todo.any():
                break
        return out


_NEIGHBOR_OFFSETS = np.array(
    [[dx, dy, dz] for dx in (-1, 0, 1) for dy in (-1, 0, 1) for dz in (-1, 0, 1)
     if (dx, dy, dz) != (0, 0, 0)], np.int64)


def ghost_leaves(tree: AMRTree, leaf_domain: np.ndarray, domain: int,
                 index: _LevelIndex | None = None,
                 chunk: int = 200_000) -> np.ndarray:
    """Global leaf ids of the ghost halo of ``domain`` (26-neighborhood).

    For each owned leaf, sample the center of each of its 26 same-level
    neighbors (periodic box) and find the covering leaf; any covering leaf
    owned by another domain is a ghost. One-level-finer neighbors are caught
    via the neighbor's 8 sub-centers on face-adjacent offsets.
    """
    if index is None:
        index = _LevelIndex(tree)
    max_level = tree.n_levels - 1
    box = np.int64(1) << max_level
    leaves = np.flatnonzero(~tree.refine)
    mine = leaves[leaf_domain == domain]
    lv = tree.levels()[mine].astype(np.int64)
    size = (np.int64(1) << (max_level - lv))
    base = tree.coords[mine] * size[:, None]

    ghost_ids: list[np.ndarray] = []
    for lo in range(0, mine.size, chunk):
        sel = slice(lo, lo + chunk)
        b, s = base[sel], size[sel]
        pts = []
        # same-level neighbor centers (26 offsets)
        for off in _NEIGHBOR_OFFSETS:
            p = b + off[None, :] * s[:, None] + (s // 2)[:, None]
            pts.append(p)
        # half-cell sub-centers across the 6 faces (catch finer neighbors)
        for axis in range(3):
            for sign in (-1, 1):
                for u in (1, 3):
                    for v in (1, 3):
                        p = b.copy()
                        p[:, axis] += np.where(sign > 0, s, -(s // 2) - (s // 4))
                        p[:, axis] += np.where(sign > 0, s // 4, 0)
                        ax_u, ax_v = [a for a in range(3) if a != axis]
                        p[:, ax_u] += (u * s) // 4
                        p[:, ax_v] += (v * s) // 4
                        pts.append(p)
        q = np.concatenate(pts, axis=0) % box  # periodic wrap
        cover = index.covering_leaf(q)
        cover = cover[cover >= 0]
        ghost_ids.append(np.unique(cover))
    if not ghost_ids:
        return np.zeros(0, np.int64)
    g = np.unique(np.concatenate(ghost_ids))
    # drop my own leaves
    leaf_rank = np.full(tree.n_nodes, -1, np.int64)
    leaf_rank[leaves] = np.arange(leaves.size)
    g = g[leaf_domain[leaf_rank[g]] != domain]
    return g


def subtree_ownership(tree: AMRTree, leaf_domain: np.ndarray, domain: int) -> np.ndarray:
    """(n_nodes,) owner flags: leaf owned iff assigned; coarse iff any son."""
    owner = np.zeros(tree.n_nodes, bool)
    leaves = np.flatnonzero(~tree.refine)
    owner[leaves[leaf_domain == domain]] = True
    cs = tree.child_start()
    for l in range(tree.n_levels - 2, -1, -1):
        sl = tree.level_slice(l)
        idx = np.flatnonzero(tree.refine[sl]) + sl.start
        if idx.size == 0:
            continue
        kids = cs[idx][:, None] + np.arange(8)[None, :]
        owner[idx] |= owner[kids].any(axis=1)
    return owner


def local_tree(tree: AMRTree, leaf_domain: np.ndarray, domain: int,
               coarse_level: int = 3,
               index: _LevelIndex | None = None) -> AMRTree:
    """Extract the RAMSES-like local tree of ``domain`` (own+ghost+coarse)."""
    owner = subtree_ownership(tree, leaf_domain, domain)
    levels = tree.levels()
    keep = np.zeros(tree.n_nodes, bool)

    # (a) own leaves, (b) ghost halo leaves
    leaves = np.flatnonzero(~tree.refine)
    keep[leaves[leaf_domain == domain]] = True
    keep[ghost_leaves(tree, leaf_domain, domain, index=index)] = True
    # (c) degraded global coarse view
    keep[levels <= coarse_level] = True

    # ancestor closure (bottom-up through parents)
    parent = tree.parent()
    for l in range(tree.n_levels - 1, 0, -1):
        sl = tree.level_slice(l)
        kept = np.flatnonzero(keep[sl]) + sl.start
        keep[parent[kept]] = True

    # sibling closure + demote refined nodes with no kept children
    cs = tree.child_start()
    force_leaf = []
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        idx = np.flatnonzero(tree.refine[sl] & keep[sl]) + sl.start
        if idx.size == 0:
            continue
        kids = cs[idx][:, None] + np.arange(8)[None, :]
        any_kid = keep[kids].any(axis=1)
        keep[kids[any_kid].ravel()] = True           # all 8 siblings
        force_leaf.append(idx[~any_kid])             # degraded view leaf
    force = np.concatenate(force_leaf) if force_leaf else np.zeros(0, np.int64)

    base = AMRTree(refine=tree.refine, owner=owner,
                   level_offsets=tree.level_offsets, coords=tree.coords,
                   fields=tree.fields)
    return subset_tree(base, keep, force_leaf=force)
