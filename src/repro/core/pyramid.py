"""Father–son compression for dense ML tensors (paper technique -> ML).

Two predictors, both lossless (XOR residue):

  * **Spatial (pyramid)** — build an 8-way mean pyramid over the flattened
    tensor: level k+1 is the mean of 8 consecutive level-k values. The mean
    is an *intensive* restriction, exactly the AMR father the paper's codec
    assumes, so fathers predict sons well wherever the tensor is locally
    smooth (embeddings, layernorm scales, optimizer second moments).
  * **Temporal (delta)** — predictor = the same tensor from the previous
    checkpoint context; groups are 8 consecutive values sharing one
    leading-zero code. This is the paper's "different output frequency"
    HProt flow turned into delta-encoded checkpoint chains.

Both reuse :mod:`repro.core.fpdelta` and decode exactly (bitwise), so
restart correctness is unaffected.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import fpdelta

GROUP = 8


def _width_of(dtype: np.dtype) -> int:
    name = np.dtype(dtype).name if not str(dtype) == "bfloat16" else "bfloat16"
    return {"float64": 64, "float32": 32, "bfloat16": 16}[str(name)]


def _pad_flat(x: np.ndarray) -> tuple[np.ndarray, int]:
    flat = np.asarray(x).reshape(-1)
    pad = (-flat.size) % GROUP
    if pad:
        filler = flat[-1] if flat.size else 0
        flat = np.concatenate([flat, np.full(pad, filler, flat.dtype)])
    return flat, pad


@dataclasses.dataclass
class PyramidCompressed:
    levels: list[fpdelta.Compressed]   # fine -> coarse order
    root: np.ndarray                   # coarsest level, raw
    shape: tuple
    dtype: str
    pad: int

    @property
    def nbytes(self) -> int:
        return self.root.nbytes + sum(c.nbytes for c in self.levels)


def encode_pyramid(x: np.ndarray, *, zbits: int = 4,
                   min_root: int = 512) -> PyramidCompressed:
    """Compress a tensor against its own 8-way mean pyramid."""
    width = _width_of(x.dtype)
    flat, pad = _pad_flat(x)
    # build mean pyramid in float64 reduced precision of source dtype:
    # fathers must be representable in the source dtype so the decoder can
    # rebuild them exactly -> cast each level back to the source dtype.
    levels_vals = [flat]
    while levels_vals[-1].size > max(min_root, GROUP):
        cur = levels_vals[-1]
        nxt_size = cur.size // GROUP
        trunc = cur[:nxt_size * GROUP].reshape(nxt_size, GROUP)
        nxt = trunc.astype(np.float64).mean(axis=1).astype(cur.dtype)
        nxt, _ = _pad_flat(nxt)
        levels_vals.append(nxt)
    blocks = []
    for k in range(len(levels_vals) - 1):
        sons = levels_vals[k]
        fathers = levels_vals[k + 1][: sons.size // GROUP]
        blocks.append(fpdelta.encode(fathers, sons.reshape(-1, GROUP),
                                     zbits=zbits, width=width))
    return PyramidCompressed(levels=blocks, root=np.asarray(levels_vals[-1]).copy(),
                             shape=tuple(np.asarray(x).shape), dtype=str(x.dtype),
                             pad=pad)


def decode_pyramid(pc: PyramidCompressed) -> np.ndarray:
    cur = pc.root
    for blk in reversed(pc.levels):
        fathers = cur[: blk.n_groups]
        cur = fpdelta.decode(blk, fathers).reshape(-1)
    n = int(np.prod(pc.shape)) if pc.shape else 1
    out = cur[:n].reshape(pc.shape)
    return out


@dataclasses.dataclass
class DeltaCompressed:
    block: fpdelta.Compressed
    shape: tuple
    dtype: str
    pad: int

    @property
    def nbytes(self) -> int:
        return self.block.nbytes


def encode_delta(x: np.ndarray, prev: np.ndarray, *, zbits: int = 4) -> DeltaCompressed:
    """Compress ``x`` against the previous-context tensor ``prev``."""
    width = _width_of(x.dtype)
    flat, pad = _pad_flat(x)
    pflat, _ = _pad_flat(np.asarray(prev, dtype=np.asarray(x).dtype))
    assert flat.size == pflat.size, "temporal predictor shape mismatch"
    blk = fpdelta.encode(pflat.reshape(-1, GROUP), flat.reshape(-1, GROUP),
                         zbits=zbits, width=width)
    return DeltaCompressed(block=blk, shape=tuple(np.asarray(x).shape),
                           dtype=str(x.dtype), pad=pad)


def decode_delta(dc: DeltaCompressed, prev: np.ndarray) -> np.ndarray:
    pflat, _ = _pad_flat(np.asarray(prev))
    out = fpdelta.decode(dc.block, pflat.reshape(-1, GROUP)).reshape(-1)
    n = int(np.prod(dc.shape)) if dc.shape else 1
    return out[:n].reshape(dc.shape)
