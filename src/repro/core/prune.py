"""AMR tree pruning (paper §2.1) — remove ghost-subtree redundancy.

RAMSES' multigrid solver requires every MPI process to hold a *degraded
global* view of the whole box's mesh, and hydro stencils require ghost
neighbor cells; both make each process' local tree heavily redundant for
post-processing. The pruning algorithm walks the tree bottom-up and
"dynamically changes the refinement values of unnecessary cells which are
defined as ghost coarse cells of whom leafs are also all ghosts": such a
coarse cell is demoted to a (ghost) leaf and its children dropped.

On the paper's Orion data this removed 31.3 % of cells on average
(17.2 % worst, 47.3 % best domain) — reproduced by
``benchmarks/bench_pruning.py`` on the Orion-like substrate.
"""
from __future__ import annotations

import numpy as np

from .amr import AMRTree, subset_tree


def prune(tree: AMRTree) -> AMRTree:
    """Return the pruned copy of ``tree`` (bottom-up ghost-subtree collapse)."""
    refine = tree.refine.copy()
    alive = np.ones(tree.n_nodes, bool)
    cs = tree.child_start()
    # Bottom-up sweep: a ghost refined node whose 8 children are all
    # (currently) leaves and all ghosts becomes a leaf; children die.
    for l in range(tree.n_levels - 2, -1, -1):
        sl = tree.level_slice(l)
        idx = np.flatnonzero(tree.refine[sl]) + sl.start  # originally refined
        if idx.size == 0:
            continue
        kids = cs[idx][:, None] + np.arange(8)[None, :]   # (m, 8)
        all_leaf = ~refine[kids].any(axis=1)
        all_ghost = ~tree.owner[kids].any(axis=1)
        collapse = (~tree.owner[idx]) & all_leaf & all_ghost
        victims = idx[collapse]
        refine[victims] = False
        alive[(cs[victims][:, None] + np.arange(8)[None, :]).ravel()] = False
    return subset_tree(
        AMRTree(refine=tree.refine, owner=tree.owner,
                level_offsets=tree.level_offsets, coords=tree.coords,
                fields=tree.fields),
        keep=alive,
        force_leaf=np.flatnonzero(tree.refine & ~refine),
    )


def removed_fraction(before: AMRTree, after: AMRTree) -> float:
    """Paper fig. 3 metric: fraction of cells removed by pruning."""
    return 1.0 - after.n_nodes / before.n_nodes
