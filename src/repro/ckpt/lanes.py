"""Writer-lane backends for the async checkpoint manager (HProt flow).

The in-transit lane shape (``insitu/lanes.py``) applied to protection
data: the manager's gather thread streams encoded shards into one
staging area per Hercule contributor group, and a *writer lane* per
group drains it — append to the group's files, publish to the page
cache, report the :class:`~repro.hercule.database.Record` home. Lanes
never fsync and never commit: durability belongs to the manifest
committer (``HerculeDB.commit_context``), exactly the split the
multi-domain in-transit engine uses.

Two backends register here, mirroring the ``insitu`` registry:

  * ``thread``  — one daemon thread per group over a pooled
    :class:`~repro.insitu.staging.StagingArea`; writes run in the
    training process (simple, zero extra processes, the file-write
    syscalls release the GIL).
  * ``process`` — one spawned OS process per group fed through a
    :class:`~repro.insitu.staging.ShmStagingArea` (shared-memory slabs,
    pickle-free): serialization and page-cache writes leave the
    producer's GIL entirely.

Both run ``policy="block"`` — checkpoints are lossless; backpressure
stalls the *gather thread*, never the train step (the step only waits
when the snapshot queue itself is full, i.e. a whole previous
checkpoint is still gathering).

Crash semantics (satellite of ISSUE 7): a lane dying mid-checkpoint is
detected by the collector's exitcode poll, surfaced through
``manager._lane_failed`` — which fails every in-flight step (their
records can never all land) so no manifest commits for them — and the
dead lane's staging area is closed so a blocked gather push raises
instead of deadlocking ``wait()``.

Lanes are created lazily on first push to a group: the set of groups
is a function of the state's sharding, unknown at manager construction.
"""
from __future__ import annotations

import multiprocessing
import queue
import threading
import time
import traceback

from ..hercule.database import DomainWriter, HerculeDB, Record
from ..insitu.staging import ShmStagingArea, StagingArea
from ..obs import metrics as obs_metrics
from ..obs.trace import TRACER, Tracer, now_us

CKPT_BACKENDS: dict[str, type] = {}


def register_backend(name: str, cls: type) -> type:
    """Register (or replace) a checkpoint lane backend under ``name``."""
    CKPT_BACKENDS[name] = cls
    return cls


def make_backend(name: str, manager, **kw):
    if name not in CKPT_BACKENDS:
        raise ValueError(f"unknown checkpoint lane backend {name!r}; "
                         f"registered: {sorted(CKPT_BACKENDS)}")
    return CKPT_BACKENDS[name](manager, **kw)


class CkptLaneBackend:
    """One writer-lane strategy, bound to an AsyncCheckpointManager.

    Contract: :meth:`push` stages one encoded shard payload for the
    lane owning contributor group ``group`` (blocking when that lane is
    behind); the lane appends it via :class:`DomainWriter`, publishes
    the bytes to the page cache (``flush_domain(sync=False)``) and
    reports through ``manager._shard_landed``. Failures route through
    ``manager._lane_failed`` — never silently. ``stop()`` must not
    return while a lane could still be writing.
    """

    name = ""

    def __init__(self, manager, *, queue_capacity: int = 4):
        self.manager = manager
        self.queue_capacity = max(1, int(queue_capacity))
        #: group -> staging area (lazily created with its lane)
        self.stages: dict[int, object] = {}
        self._lock = threading.Lock()

    def push(self, group: int, step: int, payload, desc: dict) -> None:
        """Stage one encoded shard (uint8 payload + record descriptor)."""
        self._area(group).push(step, {"payload": payload}, kind="ckpt",
                               meta=desc)

    def _area(self, group: int):
        raise NotImplementedError

    def stop(self, timeout: float = 30.0) -> None:
        raise NotImplementedError

    def telemetry(self) -> dict:
        return {}


def _write_shard(db: HerculeDB, snap) -> list[Record]:
    """Append one staged shard to its group files; returns its records."""
    d = snap.meta
    w = DomainWriter(db, snap.step)
    w.write_bytes(int(d["domain"]), d["rec_name"],
                  bytes(snap.arrays["payload"]),
                  dtype=d["dtype"], shape=tuple(d["shape"]),
                  codec=d["codec"], meta=d["rec_meta"])
    db.flush_domain(int(d["domain"]), sync=False)
    return w.records


class ThreadCkptLanes(CkptLaneBackend):
    """One in-process writer thread per contributor group."""

    name = "thread"

    def __init__(self, manager, *, queue_capacity: int = 4):
        super().__init__(manager, queue_capacity=queue_capacity)
        self._threads: dict[int, threading.Thread] = {}

    def _area(self, group: int):
        with self._lock:
            area = self.stages.get(group)
            if area is None:
                area = StagingArea(capacity=self.queue_capacity,
                                   policy="block",
                                   n_buffers=self.queue_capacity + 2)
                t = threading.Thread(target=self._lane, args=(group, area),
                                     name=f"hprot-lane-g{group}",
                                     daemon=True)
                self.stages[group] = area
                self._threads[group] = t
                t.start()
            return area

    def _lane(self, group: int, area: StagingArea) -> None:
        mgr = self.manager
        while True:
            snap = area.pop(timeout=0.25)
            if snap is None:
                if area.closed and len(area) == 0:
                    return
                continue
            try:
                t0 = time.perf_counter()
                with TRACER.span("ckpt.write", cat="ckpt",
                                 parent=snap.meta.get("_trace"),
                                 args={"step": snap.step, "group": group}):
                    records = _write_shard(mgr.db, snap)
                mgr._shard_landed(snap.step, group, records,
                                  write_seconds=time.perf_counter() - t0)
            except BaseException as e:   # noqa: BLE001 — surfaced on wait
                mgr._lane_failed(group, e)
            finally:
                area.release(snap)

    def stop(self, timeout: float = 30.0) -> None:
        with self._lock:
            areas, threads = dict(self.stages), dict(self._threads)
        for area in areas.values():
            area.close()
        for t in threads.values():
            if t.ident is not None:
                t.join(timeout=timeout)
        if any(t.is_alive() for t in threads.values()):
            # never close the db under a still-writing lane — a leaked
            # daemon thread beats a corrupted group file
            raise TimeoutError(
                "checkpoint writer lanes did not stop; database left open")

    def telemetry(self) -> dict:
        return {"kind": "thread", "n_lanes": len(self._threads),
                "lanes_alive": sum(t.is_alive()
                                   for t in self._threads.values())}


def _ckpt_lane_main(handle, root: str, group: int, results) -> None:
    """One process writer lane: attach shm staging, append, report.

    Results-queue wire format (6-tuples): ``(tag, step, group,
    records_json, wall_or_tb, spans)`` — "done" carries the record
    index + write wall seconds (+ spans when the push rode a trace
    context), "error" carries the traceback in slot 4, "exit" announces
    a clean drain.
    """
    area = ShmStagingArea.attach(handle)
    db = HerculeDB.open(root)
    tracer = Tracer(enabled=True)    # only used when _trace rides in
    try:
        while True:
            try:
                snap = area.pop(timeout=0.25)
            except BaseException:    # noqa: BLE001 — transport failure
                results.put(("error", -1, group, None,
                             traceback.format_exc(), None))
                return
            if snap is None:
                if area.closed and len(area) == 0:
                    return
                continue
            try:
                w0 = now_us()
                records = _write_shard(db, snap)
                w1 = now_us()
                spans = None
                tctx = snap.meta.get("_trace")
                if tctx is not None:
                    tracer.record("ckpt.write", w0, w1, cat="ckpt",
                                  parent=tctx,
                                  args={"step": snap.step, "group": group})
                    spans = tracer.spans()
                    tracer.clear()
                results.put(("done", snap.step, group,
                             [r.to_json() for r in records],
                             (w1 - w0) / 1e6, spans))
            except BaseException:    # noqa: BLE001
                results.put(("error", snap.step, group, None,
                             traceback.format_exc(), None))
            finally:
                area.release(snap)
    finally:
        db.close()
        area.detach()
        results.put(("exit", None, group, None, None, None))


class ProcessCkptLanes(CkptLaneBackend):
    """One spawned OS process per contributor group over shm staging.

    The paper's per-producer protection shape: serialization already
    happened in the gather thread, so the lane's work — slab copy out,
    file append, page-cache flush — runs wholly outside the training
    process. A collector thread funnels record reports to the manager
    and polls lane liveness (see module docstring for crash semantics).
    """

    name = "process"

    def __init__(self, manager, *, queue_capacity: int = 4):
        super().__init__(manager, queue_capacity=queue_capacity)
        self._ctx = multiprocessing.get_context("spawn")
        self._results = self._ctx.Queue()
        self._procs: dict[int, object] = {}
        self._exited: set[int] = set()
        self._stopping = False
        self._collector = threading.Thread(
            target=self._collect, name="hprot-collector", daemon=True)
        self._collector.start()

    def _area(self, group: int):
        with self._lock:
            area = self.stages.get(group)
            if area is None:
                area = ShmStagingArea(capacity=self.queue_capacity,
                                      policy="block",
                                      n_slots=self.queue_capacity + 2,
                                      mp_context=self._ctx)
                p = self._ctx.Process(
                    target=_ckpt_lane_main,
                    args=(area.handle(), self.manager.db.root, group,
                          self._results),
                    name=f"hprot-lane-g{group}", daemon=True)
                self.stages[group] = area
                self._procs[group] = p
                p.start()
            return area

    # ------------------------------------------------------ result intake
    def _collect(self) -> None:
        mgr = self.manager
        while True:
            try:
                msg = self._results.get(timeout=0.25)
            except (ValueError, OSError):
                return   # results queue torn down under a stuck stop
            except queue.Empty:
                with self._lock:
                    procs = dict(self._procs)
                if self._stopping and all(
                        g in self._exited or not p.is_alive()
                        for g, p in procs.items()):
                    return
                if not self._stopping:
                    self._check_lanes(procs)
                continue
            tag, step, group = msg[0], msg[1], msg[2]
            if tag == "exit":
                self._exited.add(group)
            elif tag == "done":
                _, _, _, recs, wall, spans = msg
                if spans:            # lane spans join the parent trace
                    TRACER.ingest(spans)
                if obs_metrics.ENABLED:
                    mgr._h_write.labels(group).observe(wall)
                mgr._shard_landed(step, group,
                                  [Record.from_json(r) for r in recs],
                                  write_seconds=None)
            elif tag == "error":
                mgr._lane_failed(group, RuntimeError(
                    f"checkpoint lane g{group} failed at step {step}:\n"
                    f"{msg[4]}"))
                if step < 0:
                    # fatal transport failure: the lane is exiting; stop
                    # the gather from queueing (or blocking) behind it
                    with self._lock:
                        area = self.stages.get(group)
                    if area is not None:
                        area.close()

    def _check_lanes(self, procs) -> None:
        """Surface lanes that died without reporting (crash semantics).

        Only a nonzero exit code is a crash: a zero-exit lane may
        simply have its "exit" message still queued.
        """
        for g, p in procs.items():
            if g not in self._exited and p.exitcode not in (None, 0):
                self._exited.add(g)
                self.manager._lane_failed(g, RuntimeError(
                    f"checkpoint lane g{g} died (exit code {p.exitcode}) "
                    f"mid-checkpoint"))
                # fail fast instead of deadlocking the block-policy
                # gather against a lane that will never pop again
                with self._lock:
                    area = self.stages.get(g)
                if area is not None:
                    area.close()

    # ------------------------------------------------------------ control
    def stop(self, timeout: float = 30.0) -> None:
        with self._lock:
            areas, procs = dict(self.stages), dict(self._procs)
        for area in areas.values():
            area.close()
        killed = []
        for p in procs.values():
            if p.pid is None:
                continue
            p.join(timeout=timeout)
            if p.is_alive():
                # a stuck lane is its own process: killing it cannot
                # corrupt the parent; its un-reported bytes stay
                # orphaned (no manifest references them)
                p.terminate()
                p.join(timeout=5.0)
                killed.append(p.name)
        self._stopping = True
        if self._collector.ident is not None:
            self._collector.join(timeout=timeout)
        for area in areas.values():
            area.unlink()
        self._results.close()
        self._results.join_thread()
        if killed:
            self.manager._errors.append(TimeoutError(
                f"checkpoint lanes {killed} did not stop; terminated "
                f"(unreported shards lost)"))

    def telemetry(self) -> dict:
        with self._lock:
            procs = dict(self._procs)
        return {"kind": "process", "n_lanes": len(procs),
                "lanes_exited": len(self._exited),
                "lanes_alive": sum(p.is_alive() for p in procs.values())}


register_backend("thread", ThreadCkptLanes)
register_backend("process", ProcessCkptLanes)
