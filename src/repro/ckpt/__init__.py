"""HProt async checkpoint/restart subsystem (DESIGN.md §16).

:class:`AsyncCheckpointManager` — snapshot-consistent device-side cut,
staged writer lanes (thread/process), ordered fsync-then-manifest
commits, incremental delta checkpoints with periodic full rebase, and
checksum-verified elastic restore.
"""
from .lanes import CKPT_BACKENDS, CkptLaneBackend, register_backend
from .manager import AsyncCheckpointManager
from .restore import (CorruptShardError, context_complete,
                      latest_complete_step, verified_reader)

__all__ = [
    "AsyncCheckpointManager", "CorruptShardError", "CKPT_BACKENDS",
    "CkptLaneBackend", "register_backend", "context_complete",
    "latest_complete_step", "verified_reader",
]
