"""HProt async sharded checkpoint manager with delta checkpoints.

The paper's protection flow, rebuilt on this repo's staging + lane
machinery (DESIGN.md §16). One save decomposes into four pipeline
stages, each its own span:

  ``ckpt.snapshot``  (train thread, *the only stall*) — a
      snapshot-consistent cut of the state: every owned shard is copied
      device-side (``jnp.array``; donation-safe — the optimizer may
      overwrite the source buffers the moment save returns) and the
      copies fenced with one ``block_until_ready``. No host transfer,
      no serialization.
  ``ckpt.stage``     (gather thread) — tensors cross to the host *one
      at a time* (bounded host memory), are delta- or raw-encoded,
      CRC32-stamped and pushed into the owning contributor group's
      staging area.
  ``ckpt.write``     (writer lanes, thread or process) — append to the
      group's Hercule files and publish to the page cache
      (``flush_domain(sync=False)``); no fsync here.
  ``ckpt.commit``    — once every shard of the *oldest* in-flight step
      has landed, the referenced files are fsynced and the manifest
      atomically replaced (``HerculeDB.commit_context``). Commits are
      strictly save-ordered so a delta context can never become
      readable before its predecessor.

A crash anywhere before the commit leaves no manifest: restart falls
back to the previous complete step (``restore.latest_complete_step``).
A writer-lane crash fails every in-flight step and surfaces on the
next ``save``/``wait`` — never a silent half-checkpoint, never a
deadlocked barrier.

Delta checkpoints (``delta_every=K``): checkpoint k in each cycle of
K+1 stores each float tensor as an ``fpdelta-delta`` residual against
the previous checkpoint (temporal father–son, the paper's time-chained
objects), with a periodic *full rebase* bounding every restore chain
at K links. Restore replays the chain bit-exactly through the
checksum-verifying decoder in :mod:`.restore`.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pyramid as pyr
from ..hercule import api, codecs
from ..hercule.checkpoint import _FLOATY, _leaf_paths, _slices_json
from ..hercule.database import HerculeDB
from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from ..obs.trace import TRACER
from .lanes import make_backend
from .restore import latest_complete_step, verified_reader

_SENTINEL = object()


@dataclasses.dataclass
class _PendingSave:
    """One in-flight step between snapshot and manifest commit."""
    step: int
    attrs: dict
    tctx: dict | None                 # ckpt.snapshot span wire context
    expected: int | None = None       # shard count; None until gathered
    landed: int = 0
    records: list = dataclasses.field(default_factory=list)
    committing: bool = False          # commit claimed by some thread


class AsyncCheckpointManager:
    """Async sharded HProt checkpoints over staged writer lanes.

    Drop-in for :class:`~repro.hercule.checkpoint.CheckpointManager`
    (``save``/``wait``/``close``/``latest_step``/``restore``), but the
    train step only pays for the device-side snapshot; encoding, file
    I/O and durability all happen behind the staging areas.
    """

    def __init__(self, root: str, *, ncf: int = 8,
                 max_file_bytes: int = 2 << 30, delta_every: int = 0,
                 lane_backend: str = "thread", queue_capacity: int = 4,
                 io_threads: int = 4, registry=None):
        self.db = HerculeDB.create(root, kind="hprot", ncf=ncf,
                                   max_file_bytes=max_file_bytes,
                                   io_threads=io_threads)
        self.delta_every = max(0, int(delta_every))
        # delta predictors: last checkpoint's host tensors (only kept
        # when delta encoding is on — they cost one state copy of RAM)
        self._prev: dict[tuple[str, int], np.ndarray] = {}
        self._prev_step: int | None = None
        self._deltas_since_full = 0

        self._lock = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._pending: dict[int, _PendingSave] = {}
        self._order: list[int] = []          # steps in save order
        self._errors: list[BaseException] = []
        self._committed = 0
        self._stall_total = 0.0
        self._closed = False

        self.obs = registry if registry is not None \
            else obs_metrics.MetricsRegistry()
        self._h_stall = self.obs.histogram(
            "ckpt_stall_seconds", "train-step stall per save (snapshot)")
        self._h_gather = self.obs.histogram(
            "ckpt_gather_seconds", "host gather+encode time per save")
        self._h_commit = self.obs.histogram(
            "ckpt_commit_seconds", "fsync + manifest commit time")
        self._h_write = self.obs.histogram(
            "ckpt_write_seconds", "lane write time per shard",
            labels=("group",))
        self._c_bytes = self.obs.counter(
            "ckpt_bytes_written_total", "encoded shard bytes staged",
            labels=("codec",))
        self._c_records = self.obs.counter(
            "ckpt_records_total", "checkpoint shard records staged")
        self._c_saves = self.obs.counter(
            "ckpt_saves_total", "checkpoints gathered", labels=("mode",))

        self._backend = make_backend(lane_backend, self,
                                     queue_capacity=queue_capacity)
        # depth-1 hand-off: a save whose *predecessor* is still
        # gathering blocks — the paper's barrier on the previous flush
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._gather = threading.Thread(target=self._gather_main,
                                        name="hprot-gather", daemon=True)
        self._gather.start()

    # --------------------------------------------------------------- save
    def save(self, step: int, state, *, attrs: dict | None = None,
             wait: bool = False) -> None:
        """Cut a snapshot (the only synchronous part) and hand it off."""
        self.check_errors()
        step = int(step)
        t0 = time.perf_counter()
        with TRACER.span("ckpt.snapshot", cat="ckpt",
                         args={"step": step}) as sp:
            tctx = sp.context()
            cut = self._snapshot(state)
        pend = _PendingSave(step=step, attrs=dict(attrs or {}), tctx=tctx)
        with self._lock:
            if step in self._pending:
                raise ValueError(f"step {step} already in flight")
            self._pending[step] = pend
            self._order.append(step)
        self._q.put((step, cut))   # blocks while previous gather runs
        stall = time.perf_counter() - t0
        with self._lock:
            self._stall_total += stall
        if obs_metrics.ENABLED:
            self._h_stall.observe(stall)
        if wait:
            self.wait()

    def _snapshot(self, state) -> list:
        """Donation-safe consistent cut: device copies, no host traffic."""
        cut, fences = [], []
        for name, leaf in _leaf_paths(state):
            if leaf is None:
                continue
            if isinstance(leaf, jax.Array) and \
                    hasattr(leaf, "addressable_shards"):
                gshape = tuple(leaf.shape)
                seen = set()
                for sh in sorted(leaf.addressable_shards,
                                 key=lambda s: s.device.id):
                    key = tuple((s.start, s.stop, s.step) for s in sh.index)
                    if key in seen:
                        continue   # ghost replica — ownership pruning
                    seen.add(key)
                    data = jnp.array(sh.data)   # guaranteed device copy
                    fences.append(data)
                    cut.append([name, sh.device.id,
                                _slices_json(sh.index, gshape), gshape,
                                data])
            else:
                data = np.array(leaf, copy=True)
                cut.append([name, 0, [], tuple(data.shape), data])
        if fences:
            jax.block_until_ready(fences)
        return cut

    # ------------------------------------------------------------- gather
    def _gather_main(self) -> None:
        while True:
            job = self._q.get()
            if job is _SENTINEL:
                self._q.task_done()
                return
            step, cut = job
            try:
                self._gather_one(step, cut)
            except BaseException as e:   # noqa: BLE001 — surfaced on wait
                self._save_failed(step, e)
            finally:
                self._q.task_done()

    def _gather_one(self, step: int, cut: list) -> None:
        with self._lock:
            pend = self._pending.get(step)
        if pend is None:       # step already failed (e.g. lane crash)
            return
        full = (self.delta_every == 0 or self._prev_step is None
                or self._deltas_since_full >= self.delta_every)
        keep_prev = self.delta_every > 0
        new_prev: dict | None = {} if keep_prev else None
        g0 = time.perf_counter()
        count = 0
        for entry in cut:
            name, domain, slices, gshape, data = entry
            domain = int(domain)
            with TRACER.span("ckpt.stage", cat="ckpt", parent=pend.tctx,
                             args={"step": step, "tensor": name}):
                host = np.asarray(data)   # one tensor on the host at a time
                entry[4] = None           # release the device copy now
                codec, payload, meta = self._encode(name, domain, host,
                                                    full=full)
                crc = zlib.crc32(payload) & 0xFFFFFFFF
                desc = {
                    "rec_name": api.HPROT_SHARD.record_name(name),
                    "domain": domain, "dtype": str(host.dtype),
                    "shape": list(host.shape), "codec": codec,
                    "rec_meta": {**meta, "slices": slices,
                                 "global_shape": list(gshape),
                                 "crc32": int(crc)},
                    "_trace": pend.tctx,
                }
                self._backend.push(self.db.group_of(domain), step,
                                   np.frombuffer(payload, np.uint8), desc)
            count += 1
            if obs_metrics.ENABLED:
                self._c_bytes.labels(codec).inc(len(payload))
                self._c_records.inc()
            if keep_prev:
                new_prev[(name, domain)] = host
        mode = "full" if full else "delta"
        if full and self.delta_every > 0 and self._prev_step is not None:
            # a *scheduled* full over an existing delta chain = a rebase
            obs_events.EVENTS.emit(obs_events.CKPT_REBASE, step=step,
                                   chain_len=self._deltas_since_full)
        if keep_prev:
            self._prev = new_prev
            self._prev_step = step
            self._deltas_since_full = 0 if full else \
                self._deltas_since_full + 1
        if obs_metrics.ENABLED:
            self._h_gather.observe(time.perf_counter() - g0)
            self._c_saves.labels(mode).inc()
        with self._lock:
            pend.attrs["mode"] = mode
            pend.expected = count
        self._try_commit()

    def _encode(self, name: str, domain: int, data: np.ndarray, *,
                full: bool):
        """(codec, payload, meta) for one shard; delta when it pays."""
        raw = np.ascontiguousarray(data).tobytes()
        if not full:
            prev = self._prev.get((name, domain))
            if str(data.dtype) in _FLOATY and data.size >= 64 \
                    and prev is not None and prev.shape == data.shape \
                    and prev.dtype == data.dtype:
                dc = pyr.encode_delta(data, prev)
                payload = codecs.encode_delta(dc)
                if len(payload) < len(raw):
                    return ("fpdelta-delta", payload,
                            {"pred_step": self._prev_step, "pad": dc.pad})
        return "raw", raw, {}

    # -------------------------------------------------- lane-side reports
    def _shard_landed(self, step: int, group: int, records,
                      write_seconds: float | None = None) -> None:
        """One shard durable-in-page-cache; called from lane threads."""
        if write_seconds is not None and obs_metrics.ENABLED:
            self._h_write.labels(group).observe(write_seconds)
        with self._lock:
            pend = self._pending.get(step)
            if pend is None:
                return    # step failed after this shard was staged
            pend.records.extend(records)
            pend.landed += 1
        self._try_commit()

    def _lane_failed(self, group: int, exc: BaseException) -> None:
        """A writer lane crashed: no in-flight step can ever complete."""
        with self._lock:
            self._errors.append(exc)
            self._pending.clear()     # their manifests must never commit
            self._order.clear()
            self._done.notify_all()

    def _save_failed(self, step: int, exc: BaseException) -> None:
        with self._lock:
            self._errors.append(exc)
            self._pending.pop(step, None)
            if step in self._order:
                self._order.remove(step)
            self._done.notify_all()

    # -------------------------------------------------------------- commit
    def _try_commit(self) -> None:
        """Commit the oldest step once all its shards landed.

        Strictly save-ordered (head of ``_order`` only): a delta
        context becomes readable only after its predecessor's manifest
        exists. The ``committing`` flag serializes racing lane threads;
        the fsync+rename runs outside the manager lock.
        """
        while True:
            with self._lock:
                if not self._order:
                    return
                step = self._order[0]
                pend = self._pending.get(step)
                if pend is None:          # defensive: orphaned order slot
                    self._order.pop(0)
                    continue
                if pend.committing or pend.expected is None \
                        or pend.landed < pend.expected:
                    return
                pend.committing = True
                records = list(pend.records)
                attrs = dict(pend.attrs)
                tctx = pend.tctx
            try:
                c0 = time.perf_counter()
                with TRACER.span("ckpt.commit", cat="ckpt", parent=tctx,
                                 args={"step": step,
                                       "n_records": len(records)}):
                    self.db.commit_context(step, records, attrs=attrs)
                if obs_metrics.ENABLED:
                    self._h_commit.observe(time.perf_counter() - c0)
                with self._lock:
                    self._pending.pop(step, None)
                    if step in self._order:
                        self._order.remove(step)
                    self._committed += 1
                    self._done.notify_all()
                obs_events.EVENTS.emit(
                    obs_events.CKPT_COMMIT, step=step,
                    mode=attrs.get("mode", "full"),
                    n_records=len(records))
            except BaseException as e:    # noqa: BLE001
                self._save_failed(step, e)
                return

    # ---------------------------------------------------------------- sync
    def wait(self, timeout: float | None = None) -> None:
        """Barrier: every accepted save is committed (or failed)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._order and not self._errors:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"checkpoint steps {list(self._order)} still in "
                        f"flight after {timeout}s")
                self._done.wait(timeout=0.25 if remaining is None
                                else min(0.25, remaining))
        self.check_errors()

    def check_errors(self) -> None:
        with self._lock:
            errs = list(self._errors)
        if errs:
            raise RuntimeError(
                f"async checkpoint failed ({len(errs)} error(s)); "
                f"first: {errs[0]}") from errs[0]

    def close(self) -> None:
        """Drain, stop lanes, close the database. Idempotent; does not
        raise on previously accumulated errors (use ``wait`` for that)."""
        if self._closed:
            return
        self._closed = True
        self._q.join()
        self._q.put(_SENTINEL)
        self._gather.join()
        try:
            self._backend.stop()
        except TimeoutError as e:
            with self._lock:
                self._errors.append(e)
            return   # a lane may still be writing: leave the db open
        self.db.close()

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        """Newest *complete* step (manifest + every payload + delta chain)."""
        return latest_complete_step(self.db)

    def restore(self, template, step: int | None = None):
        """Verified elastic restore into ``template``'s topology.

        Every payload read is checksum-verified and delta chains replay
        through :func:`.restore.decode_verified` — corruption raises
        :class:`.restore.CorruptShardError` instead of restoring wrong
        weights. Returns ``(state, attrs)``.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no complete checkpoint context found")
        view = self.db.view(step)
        reader = verified_reader(self.db, step)
        kind = api.HPROT_SHARD

        def restore_leaf(path, leaf):
            if leaf is None:
                return None
            name = kind.record_name(jax.tree_util.keystr(path))
            recs = kind.shards(view, name)
            if not recs:
                raise KeyError(f"checkpoint {step} missing tensor {name!r}")
            gshape = tuple(recs[0].meta["global_shape"])

            def read_region(target_slices):
                return kind.read_region(view, name, target_slices,
                                        reader=reader)

            sharding = getattr(leaf, "sharding", None)
            if isinstance(leaf, (jax.Array, jax.ShapeDtypeStruct)) \
                    and sharding is not None:
                def cb(idx):
                    tslices = [slice(0 if s.start is None else s.start,
                                     dim if s.stop is None else s.stop)
                               for s, dim in zip(idx, gshape)]
                    return read_region(tslices)
                return jax.make_array_from_callback(gshape, sharding, cb)
            full = read_region([slice(0, d) for d in gshape]) if gshape \
                else read_region(())
            return jnp.asarray(full) if isinstance(leaf, jax.Array) \
                else full

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = [restore_leaf(p, leaf) for p, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves), view.attrs

    # ------------------------------------------------------------ telemetry
    def bind_ledger(self, ledger) -> None:
        """Register this manager with a run ledger: its metrics become
        a flush source and ``ckpt_stall_ratio`` — the fraction of wall
        time the train thread spent stalled in ``save()`` since the
        previous ledger sample — feeds the health rules."""
        ledger.add_source("ckpt", self.obs.snapshot)
        sample = {"t": time.monotonic(), "stall": 0.0}

        def stall_ratio():
            now = time.monotonic()
            total = self.stall_seconds_total
            dt, dstall = now - sample["t"], total - sample["stall"]
            sample["t"], sample["stall"] = now, total
            if dt <= 0:
                return None
            return min(1.0, max(0.0, dstall / dt))

        ledger.add_signal("ckpt_stall_ratio", stall_ratio)

    @property
    def stall_seconds_total(self) -> float:
        """Cumulative train-thread time spent inside ``save()``."""
        with self._lock:
            return self._stall_total

    def telemetry(self) -> dict:
        with self._lock:
            return {"committed": self._committed,
                    "pending": len(self._order),
                    "errors": len(self._errors),
                    "stall_seconds_total": self._stall_total,
                    "delta_every": self.delta_every,
                    "deltas_since_full": self._deltas_since_full,
                    "backend": self._backend.telemetry()}
