"""Verified HProt restore: per-record checksums + delta-chain integrity.

Restore is the one moment where protection data must be *proven* good:
a checkpoint that restores garbage is worse than no checkpoint. Two
layers (DESIGN.md §16):

  * **record integrity** — every payload read back on the restore path
    is length-checked against the manifest and CRC32-verified against
    the ``crc32`` the async manager stamped into the record meta at
    write time. Any mismatch (missing file, truncated append, bit rot)
    raises :class:`CorruptShardError` naming the record, instead of
    silently materializing wrong weights.
  * **chain integrity** — ``fpdelta-delta`` records replay their
    temporal predecessor chain through the same verified decode, so a
    corrupt link anywhere under a delta checkpoint surfaces even when
    the top record itself is pristine.

:func:`latest_complete_step` is the pre-restore filter: a context whose
manifest references missing/truncated files — or whose delta chain
crosses such a context — is skipped and the newest *complete* step
wins (kill-mid-save recovery: the half-landed step never had a
manifest, and a half-durable one is detected here).
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

from ..hercule.codecs import decode_delta_bytes
from ..hercule.database import HerculeDB, Record, _dtype_of, get_codec


class CorruptShardError(RuntimeError):
    """A checkpoint shard failed integrity verification on restore."""


def verify_payload(db: HerculeDB, step: int, rec: Record) -> bytes:
    """Read one record's payload, proving length + CRC32 first."""
    try:
        payload = db.read_payload(rec)
    except FileNotFoundError as e:
        raise CorruptShardError(
            f"step {step}: data file {rec.file!r} referenced by "
            f"({rec.domain}, {rec.name!r}) is missing") from e
    if len(payload) != rec.nbytes:
        raise CorruptShardError(
            f"step {step}: record ({rec.domain}, {rec.name!r}) is "
            f"truncated: {len(payload)} of {rec.nbytes} bytes in "
            f"{rec.file!r}@{rec.offset}")
    crc = rec.meta.get("crc32")
    if crc is not None and (zlib.crc32(payload) & 0xFFFFFFFF) != int(crc):
        raise CorruptShardError(
            f"step {step}: record ({rec.domain}, {rec.name!r}) failed "
            f"its CRC32 check ({rec.file!r}@{rec.offset}, "
            f"{rec.nbytes} bytes)")
    return payload


def decode_verified(db: HerculeDB, step: int, rec: Record) -> np.ndarray:
    """Decode one record, verifying every link of its delta chain.

    ``fpdelta-delta`` predecessors are resolved record-by-record (same
    domain + name in ``meta["pred_step"]``'s context) and decoded
    through this same function, so the whole temporal chain down to the
    last full rebase is checksum-verified — a bit flip in any ancestor
    surfaces as :class:`CorruptShardError`, not as silently wrong
    weights.
    """
    payload = verify_payload(db, step, rec)
    if rec.codec == "fpdelta-delta":
        pred_step = int(rec.meta["pred_step"])
        try:
            pview = db.view(pred_step)
        except FileNotFoundError as e:
            raise CorruptShardError(
                f"step {step}: delta record ({rec.domain}, {rec.name!r}) "
                f"references missing predecessor context {pred_step}") from e
        try:
            pred = pview.record(rec.domain, rec.name)
        except KeyError as e:
            raise CorruptShardError(
                f"step {step}: predecessor context {pred_step} has no "
                f"record ({rec.domain}, {rec.name!r})") from e
        prev = decode_verified(db, pred_step, pred)
        return decode_delta_bytes(payload, prev, rec.meta,
                                  _dtype_of(rec.dtype), rec.shape)
    return get_codec(rec.codec).decode(db, rec, payload)


def verified_reader(db: HerculeDB, step: int):
    """Batched-record reader injectable into ``ObjectKind.read_region``."""
    def read(recs):
        return [decode_verified(db, step, r) for r in recs]
    return read


# ------------------------------------------------------- completeness scan

def _complete(db: HerculeDB, step: int, memo: dict) -> bool:
    got = memo.get(step)
    if got is not None:
        return got
    memo[step] = False   # cycle guard: a predecessor loop is corruption
    try:
        idx = db.load_index(step)
    except (FileNotFoundError, json.JSONDecodeError, KeyError, TypeError):
        return False
    sizes: dict[str, int] = {}
    for rec in idx["records"]:
        size = sizes.get(rec.file)
        if size is None:
            path = os.path.join(db.root, "data", rec.file)
            size = os.path.getsize(path) if os.path.exists(path) else -1
            sizes[rec.file] = size
        if rec.offset + rec.nbytes > size:
            return False
        if rec.codec == "fpdelta-delta" and \
                not _complete(db, int(rec.meta["pred_step"]), memo):
            return False
    memo[step] = True
    return True


def context_complete(db: HerculeDB, step: int) -> bool:
    """True when every referenced payload extent is on disk, and every
    delta predecessor context is itself complete (recursively)."""
    return _complete(db, step, {})


def latest_complete_step(db: HerculeDB) -> int | None:
    """Newest step whose manifest — and delta chain — is fully durable.

    Steps referencing missing or truncated data files (a crash between
    manifest commit and disk sync on a non-ordered filesystem, manual
    deletion, partial copy) are skipped; the completeness memo is shared
    across candidates so each chain is checked once.
    """
    memo: dict[int, bool] = {}
    for step in reversed(db.contexts()):
        if _complete(db, step, memo):
            return step
    return None
