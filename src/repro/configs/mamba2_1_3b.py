"""mamba2-1.3b [ssm]: 48L d_model=2048, attn-free, ssm_state=128 (SSD).
vocab=50280. O(1)-state decode -> runs the long_500k cell.
[arXiv:2405.21060]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    ssm_chunk=8, tie_embeddings=True, remat="none",
)
