"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680,
vocab=256000; RG-LRU + local attention (window 2048), pattern
(rec, rec, attn) -> 8 macro blocks + 2 rec tail layers. O(1)/windowed
state -> runs long_500k. [arXiv:2402.19427; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, mlp_act="geglu", head_dim=256,
    block_pattern=("rec", "rec", "attn"), lru_width=2560, window=2048,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=256, mlp_act="geglu", head_dim=32,
    block_pattern=("rec", "rec", "attn"), lru_width=64, window=8,
    tie_embeddings=True, remat="none",
)
