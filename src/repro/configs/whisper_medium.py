"""whisper-medium [audio]: enc-dec, conv frontend stubbed to precomputed
frame embeddings (B, 1500, d). 24L decoder (+24L encoder), d_model=1024,
16H (kv=16), d_ff=4096, vocab=51865. [arXiv:2212.04356]

Deviation note (DESIGN.md §5): RoPE replaces whisper's sinusoidal/learned
positions; LayerNorm kept.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, n_frames=1500,
    mlp_act="gelu", norm="layernorm",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke", family="encdec",
    n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, n_frames=16,
    mlp_act="gelu", norm="layernorm", remat="none",
)
