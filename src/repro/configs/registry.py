"""Architecture registry and assigned input shapes.

Every assigned arch exposes ``CONFIG`` (exact published dims) and
``SMOKE`` (reduced same-family config for CPU tests) in its module.
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCHS = [
    "whisper_medium",
    "minicpm_2b",
    "internlm2_20b",
    "nemotron_4_340b",
    "stablelm_1_6b",
    "mamba2_1_3b",
    "mixtral_8x22b",
    "granite_moe_1b_a400m",
    "recurrentgemma_2b",
    "llava_next_34b",
]

# assigned shape cells: (name, kind, seq_len, global_batch)
SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# archs with sub-quadratic attention / O(1)-state decode run long_500k
LONG_OK = {"mamba2_1_3b", "recurrentgemma_2b", "mixtral_8x22b"}


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_norm(arch)}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_norm(arch)}", __package__)
    return mod.SMOKE


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells flagged."""
    out = []
    for arch in ARCHS:
        for shape, spec in SHAPES.items():
            skip = None
            if shape == "long_500k" and arch not in LONG_OK:
                skip = "full attention at 524288 context (DESIGN.md §5)"
            if skip is None or include_skipped:
                out.append({"arch": arch, "shape": shape, "skip": skip, **spec})
    return out


def scale_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)
