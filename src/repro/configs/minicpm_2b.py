"""minicpm-2b [dense]: 40L d_model=2304 36H (kv=36) d_ff=5760
vocab=122753; llama-like with the WSD schedule (train/optim.py).
[arXiv:2404.06395; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, mlp_act="swiglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=6, d_ff=144,
    vocab_size=256, mlp_act="swiglu", tie_embeddings=True, remat="none",
)
