"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA window 4096. The windowed KV cache is
what lets long_500k run for this arch. [arXiv:2401.04088; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, mlp_act="swiglu",
    n_experts=8, top_k=2, capacity_factor=1.25, window=4096,
    moe_groups=16, num_microbatches=4,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, mlp_act="swiglu",
    n_experts=4, top_k=2, capacity_factor=1.25, window=16,
    remat="none",
)
