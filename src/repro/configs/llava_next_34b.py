"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling stubbed to precomputed patch embeddings
(B, 2880, d) prefix. [hf:llava-hf/llava-v1.6]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, mlp_act="swiglu",
    n_patches=2880,  # anyres: 5 tiles x 576 patches
    num_microbatches=4,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=7, d_ff=112,
    vocab_size=256, mlp_act="swiglu", n_patches=8, remat="none",
)
