"""Assigned architecture configs + registry (``--arch <id>``)."""
from .registry import ARCHS, get_config, get_smoke_config, SHAPES  # noqa: F401
