"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000; squared-ReLU MLP. [arXiv:2402.16819]

The largest assigned arch: train_4k requires FSDP+TP and gradient
accumulation (num_microbatches=8) to fit; see EXPERIMENTS.md §Dry-run.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, mlp_act="relu2", head_dim=192,
    num_microbatches=8,
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=384,
    vocab_size=256, mlp_act="relu2", head_dim=16,
    num_microbatches=2, remat="none",
)
