"""Per-(arch, shape, mesh) sharding-rule overrides — the §Perf lever.

``rules_for`` starts from ``sharding.DEFAULT_RULES`` and applies
arch/shape-specific overrides. Hillclimb iterations land here so every
perf experiment is reproducible from the config alone.
"""
from __future__ import annotations

from .. import sharding

# baseline overrides (paper-faithful runs = defaults; entries below are
# required for memory feasibility, documented in EXPERIMENTS.md §Dry-run)
_ARCH_RULES: dict[str, dict] = {
    # 340B params: ZeRO over pod+data so params+opt fit 512 chips
    "nemotron_4_340b": {"fsdp": ("data", "pod")},
    # 141B total: same treatment
    "mixtral_8x22b": {"fsdp": ("data", "pod")},
    "llava_next_34b": {"fsdp": ("data", "pod")},
}

# shape-specific overrides
_SHAPE_RULES: dict[str, dict] = {
    # decode_32k: shard the KV-cache sequence axis over 'model'
    # (sequence-parallel attention; XLA inserts the softmax collectives)
    "decode_32k": {"kv_seq": "model"},
    # long_500k has batch=1: batch falls back to replicated automatically
    "long_500k": {"kv_seq": "model"},
}

# hillclimbed overrides (EXPERIMENTS.md §Perf); keyed (arch, shape)
# (i5 tried {"seq": "model"} sequence parallelism for nemotron train_4k:
# temp memory 107 GB -> 33 GB but collectives 156 s -> 440 s; kept OFF for
# step time — re-enable when HBM, not ICI, is the binding constraint.)
# (i7 tried {"head_dim": "model"} for nemotron train_4k to turn the GQA
# KV-projection grad all-reduce into a reduce-scatter: collective went
# 155 s -> 183 s — the hd-sharded K/V pushed communication into the
# attention score contraction instead. Reverted.)
_PERF_RULES: dict[tuple, dict] = {
}


def rules_for(arch: str, shape: str, *, multi_pod: bool,
              override: dict | None = None) -> dict:
    return sharding.merge_rules(
        _ARCH_RULES.get(arch, {}),
        _SHAPE_RULES.get(shape, {}),
        _PERF_RULES.get((arch, shape), {}),
        override or {},
    )
