import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything else follows.

"""Multi-pod dry-run (deliverable e).

For every (arch x shape) cell, lower + compile the real step function
(train_step / prefill / decode_step) against the production mesh with
full shardings, print memory_analysis() + cost_analysis(), and persist
roofline terms (deliverable g) to JSON.

    python -m repro.launch.dryrun --arch internlm2_20b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out dryrun_results
    python -m repro.launch.dryrun --all --jobs-as-subprocesses

Compile failures here are bugs in the system (sharding mismatch, OOM at
compile, unsupported collective).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax

from .. import sharding as shlib
from ..configs import SHAPES, get_config
from ..configs.registry import ARCHS, cells
from . import roofline as rl
from .mesh import make_production_mesh
from .rules import rules_for
from .specs import build_callable, input_specs


def _depth_variant(cfg, n_rep: int):
    """Config with the layer-scan trip count set to ``n_rep`` (same body)."""
    if cfg.block_pattern:
        pat = len(cfg.block_pattern)
        tail = cfg.n_layers % pat
        return dataclasses.replace(cfg, n_layers=n_rep * pat + tail)
    kw = {"n_layers": n_rep}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_rep
    return dataclasses.replace(cfg, **kw)


def _trip_count(cfg) -> int:
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


def _cost_point(arch, shape, cfg, mesh, rules, n_dev):
    """Compile one reduced config and return raw cost terms."""
    kind, kwargs, axes = input_specs(arch, shape, cfg=cfg)
    fn = build_callable(arch, shape, cfg=cfg)
    in_sh = {k: shlib.tree_shardings(kwargs[k], axes[k], rules, mesh)
             for k in kwargs}
    kwargs = {k: jax.tree.map(
                  lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                     sharding=sh),
                  kwargs[k], in_sh[k])
              for k in kwargs}
    with mesh:
        with shlib.use_rules(rules, mesh):
            compiled = jax.jit(fn).lower(**kwargs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = rl.collective_stats(compiled.as_text(), n_dev)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_s": coll["total_seconds"],
            "coll_bytes": coll["total_bytes"]}


def extrapolated_cost(arch: str, shape: str, cfg, mesh, rules, n_dev,
                      kind: str) -> dict:
    """Depth-extrapolated per-device cost (XLA counts loop bodies once).

    Compile trip counts 2 and 4 with num_microbatches=1 (train uses the
    true microbatch size), fit cost(t) = a + b*t, evaluate at the full
    trip count, then scale train costs by num_microbatches (the grad-
    accumulation scan is also counted once).
    """
    nmb = max(1, cfg.num_microbatches) if kind == "train" else 1
    probe = dataclasses.replace(cfg, num_microbatches=1, unroll_layers=True)
    t_full = _trip_count(cfg)
    pts = {}
    for t in (2, 4):
        pcfg = _depth_variant(probe, t)
        if kind == "train" and nmb > 1:
            # lower the probe on the microbatch slice
            orig = SHAPES[shape]["batch"]
            SHAPES[shape]["batch"] = orig // nmb
            try:
                pts[t] = _cost_point(arch, shape, pcfg, mesh, rules, n_dev)
            finally:
                SHAPES[shape]["batch"] = orig
        else:
            pts[t] = _cost_point(arch, shape, pcfg, mesh, rules, n_dev)
    out = {}
    for key in ("flops", "bytes", "coll_s", "coll_bytes"):
        slope = (pts[4][key] - pts[2][key]) / 2.0
        base = pts[2][key] - 2.0 * slope
        val = base + slope * t_full
        out[key] = val * nmb
    return out


def model_flops(cfg, shape: str) -> float:
    n = cfg.active_param_count()
    cell = SHAPES[shape]
    tokens = {"train": cell["batch"] * cell["seq"],
              "prefill": cell["batch"] * cell["seq"],
              "decode": cell["batch"]}[cell["kind"]]
    mult = 6 if cell["kind"] == "train" else 2
    return float(mult) * n * tokens


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             rules_override: dict | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = rules_for(arch, shape, multi_pod=multi_pod,
                      override=rules_override)
    kind, kwargs, axes = input_specs(arch, shape, cfg=cfg)
    fn = build_callable(arch, shape, cfg=cfg)
    in_sh = {k: shlib.tree_shardings(kwargs[k], axes[k], rules, mesh)
             for k in kwargs}

    # attach shardings to the abstract inputs; jit infers in_shardings from
    # the avals. Donation: train donates the state, decode donates the cache.
    kwargs = {k: jax.tree.map(
                  lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                     sharding=sh),
                  kwargs[k], in_sh[k])
              for k in kwargs}
    donate = {"train": ("state",), "decode": ("cache",)}.get(kind, ())

    t0 = time.time()
    with mesh:
        with shlib.use_rules(rules, mesh):
            jitted = jax.jit(fn, donate_argnames=donate)
            lowered = jitted.lower(**kwargs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"== {arch} x {shape} mesh={'2x16x16' if multi_pod else '16x16'} "
              f"({kind}) lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {mem}")
        flops = cost.get('flops', 0) if isinstance(cost, dict) else cost[0].get('flops', 0)
        print(f"   cost_analysis: flops/device={flops:.3e} "
              f"bytes/device={cost.get('bytes accessed', 0):.3e}")
    terms = rl.roofline(compiled, n_dev, model_flops(cfg, shape))
    # scan-aware correction: extrapolate costs over the layer trip count
    extr = extrapolated_cost(arch, shape, cfg, mesh, rules, n_dev, kind)
    terms["raw_loop_once"] = {k: terms[k] for k in
                              ("flops_per_device", "bytes_per_device",
                               "collective_s")}
    terms["flops_per_device"] = extr["flops"]
    terms["flops_global"] = extr["flops"] * n_dev
    terms["bytes_per_device"] = extr["bytes"]
    terms["compute_s"] = extr["flops"] / rl.PEAK_FLOPS
    terms["memory_s"] = extr["bytes"] / rl.HBM_BW
    terms["collective_s"] = extr["coll_s"]
    terms["collective_bytes_per_device"] = extr["coll_bytes"]
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    terms["step_time_lower_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    mf = model_flops(cfg, shape)
    terms["model_flops"] = mf
    terms["useful_flops_ratio"] = mf / terms["flops_global"] \
        if terms["flops_global"] else 0.0
    terms["mfu_upper_bound"] = mf / (n_dev * rl.PEAK_FLOPS *
                                     terms["step_time_lower_bound_s"]) \
        if terms["step_time_lower_bound_s"] else 0.0
    terms.update(arch=arch, shape=shape, kind=kind,
                 mesh="multi" if multi_pod else "single",
                 lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
                 params=cfg.param_count(), active_params=cfg.active_param_count())
    if verbose:
        print(f"   roofline: compute {terms['compute_s']*1e3:.2f} ms | "
              f"memory {terms['memory_s']*1e3:.2f} ms | "
              f"collective {terms['collective_s']*1e3:.2f} ms "
              f"-> dominant: {terms['dominant']}")
    return terms


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="dryrun_results")
    p.add_argument("--subprocesses", action="store_true",
                   help="one subprocess per cell (isolates compile memory)")
    args = p.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        todo = [(c["arch"], c["shape"]) for c in cells()]
        failures = []
        for arch, shape in todo:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                out_file = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_file):
                    print(f"skip {tag} (cached)")
                    continue
                if args.subprocesses:
                    rc = subprocess.run(
                        [sys.executable, "-m", "repro.launch.dryrun",
                         "--arch", arch, "--shape", shape,
                         "--mesh", "multi" if mp else "single",
                         "--out", args.out]).returncode
                    if rc != 0:
                        failures.append(tag)
                else:
                    try:
                        terms = run_cell(arch, shape, mp)
                        with open(out_file, "w") as f:
                            json.dump(terms, f, indent=1)
                    except Exception as e:  # noqa: BLE001
                        print(f"FAIL {tag}: {e}")
                        failures.append(tag)
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape or --all required"
    for mp in meshes:
        terms = run_cell(args.arch, args.shape, mp)
        tag = f"{args.arch}__{args.shape}__{'multi' if mp else 'single'}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(terms, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
