"""GPipe-style pipeline parallelism over the 'pod' mesh axis.

Between pods the links are DCN-class, so instead of folding `pod` into
data parallel, the layer stack can be split into `pod`-many stages and
microbatches streamed through with point-to-point `ppermute`s — the only
inter-pod traffic becomes one activation tensor per microbatch per tick
(vs gradient all-reduces in DP).

Implementation: `shard_map` manual over 'pod' (other axes stay auto, so
the per-stage body may itself be TP/FSDP-sharded). Schedule is the
classic GPipe fill-compute-drain: `n_micro + n_stages - 1` ticks; stage s
works on microbatch `t - s` at tick t (bubble fraction
`(S-1)/(M+S-1)`).

``gpipe_forward`` is generic over ``stage_fn(stage_params, x) -> x``; the
dry-run demonstrates it on transformer blocks and
``tests/test_pipeline.py`` proves tick-for-tick equivalence with the
sequential forward.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def stack_stages(params_layers, n_stages: int):
    """Split a stacked-layer pytree (leading dim L) into (n_stages, L/S, ...)."""
    def split(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(split, params_layers)


def gpipe_forward(stage_fn, stage_params, microbatches, *, mesh,
                  axis: str = "pod"):
    """Run microbatches through pod-sharded pipeline stages.

    Args:
      stage_fn: (params_one_stage, x) -> y, same x/y shape.
      stage_params: pytree with leading dim n_stages (will be sharded over
        ``axis``).
      microbatches: (n_micro, mb, ...) inputs.
      mesh: mesh containing ``axis``.

    Returns (n_micro, mb, ...) outputs, replicated over ``axis``.
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    perm_fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def body(params_sharded, x_all):
        # params_sharded: leading dim 1 (this pod's stage)
        my_params = jax.tree.map(lambda p: p[0], params_sharded)
        sid = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_all[0])

        def tick(buf, t):
            # stage 0 injects microbatch t; others consume the permuted buf
            inject = x_all[jnp.clip(t, 0, n_micro - 1)]
            xin = jnp.where(sid == 0, inject, buf)
            y = stage_fn(my_params, xin)
            # tick t at stage s works on microbatch t-s; only forward
            # valid work (the bubble computes but emits nothing)
            emit_t = t - (n_stages - 1)           # microbatch leaving the end
            is_out = (sid == n_stages - 1) & (emit_t >= 0)
            out = jnp.where(is_out, y, zero)
            nxt = jax.lax.ppermute(y, axis, perm_fwd)
            return nxt, (out, emit_t)

        _, (outs, emit_ts) = jax.lax.scan(
            tick, zero, jnp.arange(n_micro + n_stages - 1))
        # keep the n_micro emitted outputs (ticks S-1 .. S-1+n_micro-1)
        outs = outs[n_stages - 1:]
        # broadcast results from the last stage to every pod (only the
        # last stage emitted nonzero, so the sum selects it)
        return jax.lax.psum(outs, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(pspec, P()), out_specs=P(),
                       check_vma=False)
    return fn(stage_params, microbatches)


def sequential_forward(stage_fn, stage_params, microbatches, n_stages: int):
    """Reference: apply all stages in order (no pipelining)."""
    def apply_all(x):
        for s in range(n_stages):
            params_s = jax.tree.map(lambda p: p[s], stage_params)
            x = stage_fn(params_s, x)
        return x
    return jax.vmap(apply_all)(microbatches)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
