"""Production meshes. A FUNCTION, not a module constant — importing this
module never touches jax device state (required by the dry-run protocol).

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis folds
into data-parallel batch by default (DCN-friendly). GPipe-style pipeline
parallelism over 'pod' lives in :mod:`repro.launch.pipeline`
(shard_map + ppermute; equivalence-tested in tests/test_pipeline.py).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) != ndev:
        if len(devices) < ndev:
            raise RuntimeError(
                f"need {ndev} devices for mesh {shape}; have {len(devices)} "
                "(dryrun.py must set XLA_FLAGS=--xla_force_host_platform_"
                "device_count=512 before any jax import)")
        devices = devices[:ndev]
    return jax.make_mesh(shape, axes, devices=devices)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    ndev = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev])
