"""Run-ledger inspection: ``python -m repro.launch.obs <cmd> <run>``.

Three views over the ``telemetry/`` database a ledger-enabled run
leaves behind (``launch/insitu.py --ledger``, ``launch/train.py
--ledger``, ``launch/catalog_serve.py --ledger``):

  ``tail <run>``    live(ish) event stream: poll the ledger and print
                    newly-persisted events as they flush (``--once``
                    prints the current stream and exits — CI mode).
  ``report <run>``  postmortem: flush inventory per process, slowest
                    steps with critical-path attribution, the alert
                    timeline, crash dumps, and the run verdict. Works
                    on the ledger a SIGKILLed run left behind — every
                    committed flush is readable.
  ``export <run> --perfetto out.json``
                    one merged Chrome-trace/Perfetto JSON spanning
                    trainer, lane and server spans.

The reader merges every writer's flushes (trainer, catalog server,
relayed lane domains), so one command sees the whole run regardless of
how many processes wrote telemetry.
"""
from __future__ import annotations

import argparse
import json
import time

from ..obs.ledger import LedgerReader


def _fmt_ts(ts_us: float) -> str:
    if not ts_us:
        return "--:--:--"
    return time.strftime("%H:%M:%S", time.localtime(ts_us / 1e6)) \
        + f".{int(ts_us % 1e6) // 1000:03d}"


def _fmt_event(ev: dict) -> str:
    fields = " ".join(f"{k}={v}" for k, v in
                      sorted(ev.get("fields", {}).items()))
    return (f"{_fmt_ts(ev.get('ts_us', 0))} "
            f"[pid {ev.get('pid', '?')}] {ev.get('type', '?'):<22} "
            f"{fields}")


def _fmt_attrib(a: dict) -> str:
    stages = " ".join(f"{st}={sec * 1e3:.1f}ms"
                      for st, sec in sorted(a["stages"].items(),
                                            key=lambda kv: -kv[1]))
    tag = " PARTIAL" if a["partial"] else ""
    return (f"step {a['step']:>6}  total {a['total_s'] * 1e3:8.1f} ms  "
            f"critical={a['critical'] or '-':<8} {stages}{tag}")


def cmd_tail(args) -> int:
    seen: set = set()
    while True:
        reader = LedgerReader(args.run)
        try:
            events = reader.events()
        finally:
            reader.close()
        for ev in events:
            key = (ev.get("pid"), ev.get("seq"), ev.get("type"),
                   ev.get("ts_us"))
            if key not in seen:
                seen.add(key)
                print(_fmt_event(ev), flush=True)
        if args.once:
            return 0
        time.sleep(args.interval)


def cmd_report(args) -> int:
    reader = LedgerReader(args.run)
    try:
        flushes = reader.flushes()
        if not flushes:
            print("ledger is empty (no flush committed yet)")
            return 1
        events = reader.events(flushes)
        attribs = reader.attribs(flushes)
        alerts = reader.alerts(flushes)
        dumps = reader.crash_dumps(flushes)
        verdict = reader.verdict(flushes)

        procs: dict[str, int] = {}
        for fl in flushes:
            procs[fl["proc"]] = procs.get(fl["proc"], 0) + 1
        print(f"== run ledger: {args.run}")
        print(f"   flushes: {len(flushes)} "
              f"({', '.join(f'{p}:{n}' for p, n in sorted(procs.items()))})"
              f"; events: {len(events)}; steps attributed: {len(attribs)}")
        print(f"   verdict: {verdict.upper()}")

        if attribs:
            print(f"\n== slowest steps (critical-path attribution, "
                  f"top {args.slowest})")
            ranked = sorted(attribs.values(),
                            key=lambda a: -a["total_s"])[:args.slowest]
            for a in ranked:
                print("   " + _fmt_attrib(a))
            crit: dict[str, int] = {}
            for a in attribs.values():
                if a["critical"]:
                    crit[a["critical"]] = crit.get(a["critical"], 0) + 1
            dist = ", ".join(f"{st}:{n}" for st, n in
                             sorted(crit.items(), key=lambda kv: -kv[1]))
            print(f"   critical-path distribution: {dist}")

        if alerts:
            print("\n== alert timeline")
            for ev in alerts:
                f = ev.get("fields", {})
                cleared = f" (cleared sample {f['cleared_sample']})" \
                    if "cleared_sample" in f else " (still active)"
                print(f"   {_fmt_ts(ev.get('ts_us', 0))} "
                      f"[{f.get('severity', '?'):>4}] {f.get('rule')}: "
                      f"{f.get('signal')}={f.get('value')} "
                      f"{f.get('op')} {f.get('threshold')}{cleared}")

        if dumps:
            print("\n== crash dumps")
            for ev in dumps:
                print("   " + _fmt_event(ev))

        partial = [a for a in attribs.values() if a["partial"]]
        if partial:
            print(f"\n== interrupted steps ({len(partial)} partial "
                  f"attributions — steps in flight at a crash/dump)")
            for a in sorted(partial, key=lambda a: a["step"]):
                print("   " + _fmt_attrib(a))
    finally:
        reader.close()
    return 0


def cmd_export(args) -> int:
    reader = LedgerReader(args.run)
    try:
        if args.perfetto:
            n = reader.export_perfetto(args.perfetto)
            pids = {s["pid"] for s in reader.spans()}
            print(f"perfetto: {n} spans across {len(pids)} process(es) "
                  f"-> {args.perfetto}")
        if args.json:
            doc = {"flushes": reader.flushes(),
                   "events": reader.events(),
                   "attribs": {str(k): v
                               for k, v in reader.attribs().items()},
                   "verdict": reader.verdict()}
            with open(args.json, "w") as fh:
                json.dump(doc, fh, indent=1)
            print(f"json: {len(doc['flushes'])} flushes -> {args.json}")
        if not args.perfetto and not args.json:
            print("nothing to export: pass --perfetto PATH and/or "
                  "--json PATH")
            return 2
    finally:
        reader.close()
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="repro.launch.obs",
        description="inspect the telemetry ledger of a run")
    sub = p.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("tail", help="print the persisted event stream")
    t.add_argument("run", help="run root (or its telemetry/ directory)")
    t.add_argument("--interval", type=float, default=1.0)
    t.add_argument("--once", action="store_true",
                   help="print the current stream and exit")
    t.set_defaults(fn=cmd_tail)

    r = sub.add_parser("report", help="postmortem report")
    r.add_argument("run")
    r.add_argument("--slowest", type=int, default=10,
                   help="steps to list in the attribution ranking")
    r.set_defaults(fn=cmd_report)

    e = sub.add_parser("export", help="export merged telemetry")
    e.add_argument("run")
    e.add_argument("--perfetto", default=None, metavar="PATH",
                   help="merged Chrome-trace JSON (trainer+lanes+server)")
    e.add_argument("--json", default=None, metavar="PATH",
                   help="full merged ledger as one JSON document")
    e.set_defaults(fn=cmd_export)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
