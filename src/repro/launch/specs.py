"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation anywhere — these drive ``jit(...).lower(**specs)``.
Modality frontends are stubs per the assignment: [audio] supplies frame
embeddings (B, n_frames, d_model); [vlm] supplies patch embeddings
(B, n_patches, d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config
from ..models import serving
from ..models.transformer import LM
from ..train import step as step_lib


def _extras_specs(cfg, batch: int):
    cdt = jnp.dtype(cfg.compute_dtype)
    out = {}
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), cdt)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), cdt)
    return out


def _extras_axes(cfg):
    out = {}
    if cfg.family == "vlm":
        out["patch_embeds"] = ("batch", "patches", "embed")
    if cfg.family == "encdec":
        out["frames"] = ("batch", "frames", "embed")
    return out


def input_specs(arch: str, shape: str, cfg=None):
    """Abstract inputs for one dry-run cell.

    Returns (kind, kwargs, axes) where kwargs feed ``lower(**kwargs)`` and
    ``axes`` mirrors kwargs with logical-axis tuples for in_shardings.
    """
    cfg = cfg or get_config(arch)
    lm = LM(cfg)
    cell = SHAPES[shape]
    b, s = cell["batch"], cell["seq"]
    kind = cell["kind"]

    if kind == "train":
        state = step_lib.abstract_state(lm)
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 **_extras_specs(cfg, b)}
        batch_axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
                      **_extras_axes(cfg)}
        return kind, {"state": state, "batch": batch}, \
            {"state": step_lib.state_axes(lm), "batch": batch_axes}

    params = lm.abstract_params()
    p_axes = lm.param_axes()
    if kind == "prefill":
        kwargs = {"params": params,
                  "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                  **_extras_specs(cfg, b)}
        axes = {"params": p_axes, "tokens": ("batch", "seq"),
                **_extras_axes(cfg)}
        return kind, kwargs, axes

    # decode: one new token against a seq_len-deep cache
    cache, cache_axes = serving.cache_specs(lm, b, s)
    kwargs = {"params": params,
              "token": jax.ShapeDtypeStruct((b,), jnp.int32),
              "pos": jax.ShapeDtypeStruct((), jnp.int32),
              "cache": cache}
    axes = {"params": p_axes, "token": ("batch",), "pos": (),
            "cache": cache_axes}
    return kind, kwargs, axes


def build_callable(arch: str, shape: str, cfg=None):
    """The function each cell lowers: train_step / prefill / decode_step."""
    from ..train import optim
    cfg = cfg or get_config(arch)
    lm = LM(cfg)
    kind = SHAPES[shape]["kind"]
    cell = SHAPES[shape]

    if kind == "train":
        ts = step_lib.make_train_step(lm, optim.OptConfig())

        def train_fn(state, batch):
            return ts(state, batch)
        return train_fn

    if kind == "prefill":
        def prefill_fn(params, tokens, **extras):
            return serving.prefill(lm, params, tokens, extras=extras,
                                   max_seq=cell["seq"])
        return prefill_fn

    def decode_fn(params, token, pos, cache):
        return serving.decode_step(lm, params, token, pos, cache)
    return decode_fn
