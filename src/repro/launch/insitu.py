"""In-transit analysis driver: ``python -m repro.launch.insitu ...``

Simulates a time-dependent Sedov blast (the shock radius grows step by
step), pushes every step's AMR tree through the in-transit engine, and
then replays viewer queries against the reduced catalog — the full
compute → staging → reducers → HDep → catalog pipeline on one box.
"""
from __future__ import annotations

import argparse
import shutil
import time

import numpy as np

from ..insitu import (Catalog, InTransitEngine, LevelHistogramReducer,
                      LODCutReducer, ProjectionReducer, SliceReducer)
from ..sim import amrgen, fields


def default_reducers(resolution: int, lod: int):
    lodname = f"lod{lod}"
    return [
        LODCutReducer(max_level=lod),
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=resolution),
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=resolution, source=lodname),
        ProjectionReducer(field="density", axis=2, resolution=resolution),
        LevelHistogramReducer(field="density", bins=32),
    ]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="/tmp/hx_insitu")
    p.add_argument("--steps", type=int, default=12)
    p.add_argument("--max-level", type=int, default=6)
    p.add_argument("--resolution", type=int, default=128)
    p.add_argument("--lod", type=int, default=4)
    p.add_argument("--output-every", type=int, default=2,
                   help="reduced-output cadence (independent of compute)")
    p.add_argument("--policy", default="drop-oldest",
                   choices=["block", "drop-oldest", "subsample"])
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--queue-capacity", type=int, default=4)
    p.add_argument("--queries", type=int, default=16,
                   help="viewer queries to replay against the catalog")
    args = p.parse_args(argv)

    shutil.rmtree(args.out, ignore_errors=True)
    reducers = default_reducers(args.resolution, args.lod)
    engine = InTransitEngine(
        args.out, reducers,
        output_every=args.output_every, workers=args.workers,
        queue_capacity=args.queue_capacity, policy=args.policy).start()

    print(f"== compute flow: {args.steps} Sedov steps "
          f"(policy={args.policy}, output_every={args.output_every})")
    t_compute = t_submit = 0.0
    for s in range(1, args.steps + 1):
        t0 = time.perf_counter()
        r_shock = 0.1 + 0.25 * s / args.steps     # expanding blast wave
        field = fields.sedov(r_shock=r_shock)
        tree = amrgen.generate_tree(field, min_level=3,
                                    max_level=args.max_level,
                                    threshold=1.15, level_factor=1.05)
        t1 = time.perf_counter()
        staged = engine.submit(s, tree)
        t2 = time.perf_counter()
        t_compute += t1 - t0
        t_submit += t2 - t1
        print(f"   step {s:3d}: {tree.n_nodes:7d} nodes "
              f"staged={'yes' if staged else 'no '} "
              f"(gen {1e3*(t1-t0):6.1f} ms, submit {1e6*(t2-t1):6.1f} us)")
    engine.drain()
    stats = engine.staging.stats
    print(f"   compute {t_compute:.2f} s, total submit {t_submit*1e3:.2f} ms "
          f"({100*t_submit/max(t_compute,1e-9):.2f} % overhead)")
    print(f"   staging: accepted={stats.accepted} evicted={stats.evicted} "
          f"dropped={stats.dropped} reuses={stats.buffer_reuses} "
          f"allocs={stats.buffer_allocs}")
    engine.close()

    print("== analysis flow: catalog replay")
    cat = Catalog(args.out)
    steps = cat.steps()
    print(f"   contexts: {steps}")
    if not steps:
        return 1
    names = cat.reducers(steps[-1])
    print(f"   reducers: {names}")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.queries):
        s = int(rng.choice(steps))
        name = str(rng.choice(names))
        obj = cat.query(s, name)
        sizes = {k: v.shape for k, v in obj.items()}
        print(f"   query step={s} {name}: "
              f"{sum(v.nbytes for v in obj.values())/1e3:.1f} kB {sizes}")
    dt = time.perf_counter() - t0
    info = cat.cache_info()
    print(f"   {args.queries} queries in {dt*1e3:.1f} ms — "
          f"hits={info['hits']} misses={info['misses']} "
          f"io_reads={info['io_reads']}")
    # selector-driven sweep: all slice/projection images, one indexed pass
    n_img = size_img = 0
    for ref in cat.scan(names="reduced/*/image"):
        n_img += 1
        size_img += ref.record.nbytes
    print(f"   selector sweep reduced/*/image: {n_img} records, "
          f"{size_img/1e3:.1f} kB on disk")
    full_slice = next(r for r in reducers
                      if isinstance(r, SliceReducer) and r.source is None)
    img = cat.query(steps[-1], full_slice.name)["image"]
    q = np.nanquantile(img, [0.5, 0.8, 0.95])
    chars = np.full(img.shape, " ")
    chars[img > q[0]] = "."
    chars[img > q[1]] = "o"
    chars[img > q[2]] = "#"
    stride = max(1, img.shape[0] // 24)
    for row in chars[::stride]:
        print("   " + "".join(row[::max(1, stride // 2)]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
