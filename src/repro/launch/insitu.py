"""In-transit analysis driver: ``python -m repro.launch.insitu ...``

Simulates a time-dependent Sedov blast (the shock radius grows step by
step), pushes every step's AMR tree through the in-transit engine, and
then replays viewer queries against the reduced catalog — the full
compute → staging → reducers → HDep → catalog pipeline on one box.
"""
from __future__ import annotations

import argparse
import shutil
import time

import numpy as np

from ..insitu import (Catalog, InTransitEngine, LevelHistogramReducer,
                      LODCutReducer, ProjectionReducer, SliceReducer)
from ..sim import amrgen, fields


def default_reducers(resolution: int, lod: int, domains: int = 1):
    lodname = f"lod{lod}"
    # multi-domain histograms need fixed bounds: per-partition auto
    # bounds produce incompatible bin edges that cannot sum at read
    hist = LevelHistogramReducer(field="density", bins=32, lo=0.0, hi=8.0) \
        if domains > 1 else LevelHistogramReducer(field="density", bins=32)
    return [
        LODCutReducer(max_level=lod),
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=resolution),
        SliceReducer(field="density", axis=2, position=0.5,
                     resolution=resolution, source=lodname),
        ProjectionReducer(field="density", axis=2, resolution=resolution),
        hist,
    ]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="/tmp/hx_insitu")
    p.add_argument("--steps", type=int, default=12)
    p.add_argument("--max-level", type=int, default=6)
    p.add_argument("--resolution", type=int, default=128)
    p.add_argument("--lod", type=int, default=4)
    p.add_argument("--output-every", type=int, default=2,
                   help="reduced-output cadence (independent of compute)")
    p.add_argument("--policy", default="drop-oldest",
                   choices=["block", "drop-oldest", "subsample"])
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--queue-capacity", type=int, default=4)
    p.add_argument("--domains", type=int, default=1,
                   help="contributor groups: each step is partitioned, "
                        "each group writes its own Hercule domain, and "
                        "catalog queries merge them back at read")
    p.add_argument("--backend", default="thread",
                   choices=["thread", "process"],
                   help="lane runtime: in-process worker threads, or one "
                        "OS process per group over shared-memory staging")
    p.add_argument("--device-reduce", action="store_true",
                   help="stage snapshots on the accelerator and reduce "
                        "with the Pallas raster kernels; only reduced "
                        "objects cross the device->host boundary")
    p.add_argument("--device-mesh", type=int, default=0, metavar="N",
                   help="shard each snapshot's leaf table over N jax "
                        "devices and reduce under shard_map with an "
                        "on-device merge tree (0 = off; on CPU force "
                        "devices with XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N)")
    p.add_argument("--lane-pool", action="store_true",
                   help="with --backend process: borrow lanes from the "
                        "persistent module pool instead of spawning")
    p.add_argument("--queries", type=int, default=16,
                   help="viewer queries to replay against the catalog")
    p.add_argument("--serve-check", action="store_true",
                   help="also serve the catalog on an ephemeral port and "
                        "verify RemoteCatalog == local merge-at-read")
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="record per-step spans (submit -> staging -> "
                        "reduce -> write -> commit, across process lanes) "
                        "and write a Chrome-trace JSON loadable in "
                        "Perfetto / chrome://tracing")
    p.add_argument("--ledger", action="store_true",
                   help="persist a run ledger (metrics/spans/events/"
                        "attribution/health) into <out>/telemetry/; "
                        "inspect with python -m repro.launch.obs")
    p.add_argument("--ledger-interval", type=float, default=1.0,
                   help="seconds between background ledger flushes "
                        "(0 = flush only at exit)")
    args = p.parse_args(argv)

    if args.trace_out or args.ledger:
        from ..obs import TRACER
        TRACER.enable()

    if args.device_mesh and args.device_reduce:
        p.error("--device-mesh and --device-reduce are exclusive paths")

    shutil.rmtree(args.out, ignore_errors=True)
    ledger = None
    if args.ledger:
        from ..obs import RunLedger
        ledger = RunLedger(args.out, "trainer",
                           interval=args.ledger_interval)
    reducers = default_reducers(args.resolution, args.lod, args.domains)
    device_reduce = "mesh" if args.device_mesh else args.device_reduce
    engine = InTransitEngine(
        args.out, reducers,
        output_every=args.output_every, workers=args.workers,
        queue_capacity=args.queue_capacity, policy=args.policy,
        domains=args.domains, backend=args.backend,
        device_reduce=device_reduce,
        mesh_devices=args.device_mesh or None,
        lane_pool=args.lane_pool, ledger=ledger).start()

    print(f"== compute flow: {args.steps} Sedov steps "
          f"(policy={args.policy}, output_every={args.output_every}, "
          f"domains={args.domains}, backend={args.backend}, "
          f"device_reduce={device_reduce})")
    t_compute = t_submit = 0.0
    for s in range(1, args.steps + 1):
        t0 = time.perf_counter()
        r_shock = 0.1 + 0.25 * s / args.steps     # expanding blast wave
        field = fields.sedov(r_shock=r_shock)
        tree = amrgen.generate_tree(field, min_level=3,
                                    max_level=args.max_level,
                                    threshold=1.15, level_factor=1.05)
        t1 = time.perf_counter()
        staged = engine.submit(s, tree)
        t2 = time.perf_counter()
        t_compute += t1 - t0
        t_submit += t2 - t1
        print(f"   step {s:3d}: {tree.n_nodes:7d} nodes "
              f"staged={'yes' if staged else 'no '} "
              f"(gen {1e3*(t1-t0):6.1f} ms, submit {1e6*(t2-t1):6.1f} us)")
    engine.drain()
    print(f"   compute {t_compute:.2f} s, total submit {t_submit*1e3:.2f} ms "
          f"({100*t_submit/max(t_compute,1e-9):.2f} % overhead)")
    for g, area in enumerate(engine.stages):
        stats = area.stats
        print(f"   staging[g{g}]: accepted={stats.accepted} "
              f"evicted={stats.evicted} dropped={stats.dropped} "
              f"reuses={stats.buffer_reuses} allocs={stats.buffer_allocs}")
    if args.device_reduce:
        ds = engine.device_stats
        staged = sum(a.stats.bytes_staged for a in engine.stages)
        print(f"   device reduce: {ds['bytes_to_host']/1e6:.2f} MB to host "
              f"vs {staged/1e6:.2f} MB staged on device "
              f"({ds['device_objects']} device objects, "
              f"fallback_runs={ds['fallback_runs']})")
    if args.device_mesh:
        ds = engine.device_stats
        print(f"   mesh reduce[{ds['mesh_devices']}d]: "
              f"peak_leaf_frac={ds['peak_leaf_frac']:.3f} "
              f"({ds['leaf_rows']} rows total, "
              f"peak table {ds['peak_device_table_bytes']/1e6:.2f} MB + "
              f"partial {ds['peak_device_partial_bytes']/1e6:.2f} MB "
              f"per device; {ds['bytes_tables_to_device']/1e6:.2f} MB "
              f"sharded up, {ds['bytes_reduced_to_host']/1e6:.2f} MB "
              f"reduced down, fallback_runs={ds['fallback_runs']})")
    tel = engine.telemetry()
    tot = tel["staging"]["totals"]
    print(f"   telemetry[{tel['backend']}]: accepted={tot['accepted']} "
          f"popped={tot['popped']} released={tot['released']} "
          f"bytes_staged={tot['bytes_staged']/1e6:.2f} MB; "
          f"lanes={tel['lanes']}")
    engine.close()
    if ledger is not None:
        verdict = ledger.verdict()
        ledger.close()
        lt = ledger.telemetry()
        print(f"   ledger: {lt['flushes']} flushes, "
              f"{lt['bytes_written']/1e3:.1f} kB, "
              f"{lt['steps_attributed']} steps attributed, "
              f"verdict={verdict} -> {args.out}/telemetry/ "
              f"(python -m repro.launch.obs report {args.out})")
    if args.lane_pool:
        from ..insitu import shutdown_pool
        shutdown_pool()       # reclaim the resident lanes before exit
    if args.trace_out:
        from ..obs import TRACER
        n_spans = TRACER.write_chrome_trace(args.trace_out)
        print(f"   trace: {n_spans} spans -> {args.trace_out} "
              f"(open in Perfetto or chrome://tracing)")

    print("== analysis flow: catalog replay (domain-merged queries)")
    cat = Catalog(args.out)
    steps = cat.steps()
    print(f"   contexts: {steps}")
    if not steps:
        return 1
    names = cat.reducers(steps[-1])
    print(f"   reducers: {names}")
    if args.domains > 1:
        att = cat.attrs(steps[-1])["insitu"]
        print(f"   latest context domains={att['domains']} "
              f"merge={att['merge']}")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.queries):
        s = int(rng.choice(steps))
        name = str(rng.choice(names))
        obj = cat.query(s, name)
        sizes = {k: v.shape for k, v in obj.items()}
        print(f"   query step={s} {name}: "
              f"{sum(v.nbytes for v in obj.values())/1e3:.1f} kB {sizes}")
    dt = time.perf_counter() - t0
    info = cat.cache_info()
    print(f"   {args.queries} queries in {dt*1e3:.1f} ms — "
          f"hits={info['hits']} misses={info['misses']} "
          f"io_reads={info['io_reads']}")
    # selector-driven sweep: all slice/projection images, one indexed pass
    n_img = size_img = 0
    for ref in cat.scan(names="reduced/*/image"):
        n_img += 1
        size_img += ref.record.nbytes
    print(f"   selector sweep reduced/*/image: {n_img} records, "
          f"{size_img/1e3:.1f} kB on disk")
    if args.domains > 1:
        # merge-at-read spot check: the merged histogram must carry
        # exactly the per-domain partial counts, summed
        hname = next(n for n in names if n.startswith("hist-"))
        merged = cat.query(steps[-1], hname)["hist"]
        parts = [cat.query(steps[-1], hname, domain=d)["hist"]
                 for d in cat.domains(steps[-1], hname)]
        total = sum(int(p.sum()) for p in parts)
        ok = int(merged.sum()) == total
        print(f"   merge check {hname}: {len(parts)} domains, "
              f"counts {int(merged.sum())} == sum(parts) {total}: {ok}")
        if not ok:
            return 1
    if args.serve_check:
        # server-mode catalog: remote viewers must see exactly the local
        # merge-at-read answers, served from one shared cache
        from ..insitu import CatalogServer, RemoteCatalog
        srv = CatalogServer(cat, port=0).start()
        rc = RemoteCatalog(srv.url)
        n_arr = bad = 0
        for name in names:
            remote = rc.query(steps[-1], name)
            local = cat.query(steps[-1], name)
            for k, v in local.items():
                n_arr += 1
                if not np.array_equal(v, remote[k], equal_nan=True):
                    bad += 1
        print(f"   serve check {srv.url}: {n_arr} arrays, "
              f"{bad} mismatched; server cache {rc.cache_info()}")
        srv.close()
        if bad:
            return 1
    full_slice = next(r for r in reducers
                      if isinstance(r, SliceReducer) and r.source is None)
    img = cat.query(steps[-1], full_slice.name)["image"]
    q = np.nanquantile(img, [0.5, 0.8, 0.95])
    chars = np.full(img.shape, " ")
    chars[img > q[0]] = "."
    chars[img > q[1]] = "o"
    chars[img > q[2]] = "#"
    stride = max(1, img.shape[0] // 24)
    for row in chars[::stride]:
        print("   " + "".join(row[::max(1, stride // 2)]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
