"""Roofline terms from a compiled dry-run artifact (deliverable g).

No wall clock exists for TPUs in this container, so the three terms come
from the compiled module itself:

  compute_s    = HLO_FLOPs_global / (chips * 197e12)      [bf16 MXU peak]
  memory_s     = HLO_bytes_global / (chips * 819e9)       [HBM BW]
  collective_s = sum over collectives of ring-model time at 50 GB/s/link

``compiled.cost_analysis()`` reports the *per-device* (post-SPMD) program;
global = per-device x chips. Collective bytes are parsed from the
optimized HLO text (per-device shapes). Ring-model factors: all-reduce
moves 2(n-1)/n x bytes, all-gather/reduce-scatter (n-1)/n x bytes
(output/input respectively), all-to-all (n-1)/n, collective-permute 1.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'f32[128,256]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups,size]
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    """Per-device collective byte counts + ring-model seconds by op type."""
    out = {k: {"bytes": 0, "count": 0, "seconds": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", s)
        if not m:
            continue
        type_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        nbytes = _shape_bytes(type_str)
        n = _group_size(s, n_devices)
        if op == "all-reduce":
            secs = 2.0 * nbytes * (n - 1) / max(n, 1) / ICI_BW
        elif op in ("all-gather", "all-to-all"):
            secs = nbytes * (n - 1) / max(n, 1) / ICI_BW
        elif op == "reduce-scatter":
            secs = nbytes * (n - 1) / max(n, 1) / ICI_BW
        else:  # collective-permute
            secs = nbytes / ICI_BW
        out[op]["bytes"] += nbytes
        out[op]["count"] += 1
        out[op]["seconds"] += secs
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_seconds"] = sum(v["seconds"] for v in out.values()
                               if isinstance(v, dict))
    return out


def roofline(compiled, n_devices: int, model_flops: float | None = None) -> dict:
    """All three terms + bookkeeping from a compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_stats(hlo, n_devices)
    mem = compiled.memory_analysis()
    terms = {
        "chips": n_devices,
        "flops_per_device": flops_dev,
        "flops_global": flops_dev * n_devices,
        "bytes_per_device": bytes_dev,
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll["total_seconds"],
        "collective_bytes_per_device": coll["total_bytes"],
        "collectives": {k: coll[k] for k in _COLLECTIVES},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["step_time_lower_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"])
    if model_flops:
        terms["model_flops"] = model_flops
        terms["useful_flops_ratio"] = (model_flops / terms["flops_global"]
                                       if terms["flops_global"] else 0.0)
        terms["mfu_upper_bound"] = model_flops / (
            n_devices * PEAK_FLOPS * terms["step_time_lower_bound_s"]) \
            if terms["step_time_lower_bound_s"] else 0.0
    return terms
