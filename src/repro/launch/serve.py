"""Serving launcher: batched prefill+decode driver.

``python -m repro.launch.serve --arch mamba2_1_3b --smoke --tokens 32``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke_config
from ..models import serving
from ..models.transformer import LM


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS, required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_seq = args.prompt_len + args.tokens
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)),
            jnp.float32) * 0.1
    if cfg.family == "encdec":
        extras["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_frames, cfg.d_model)),
            jnp.float32) * 0.1

    prefill_fn = jax.jit(lambda p, t: serving.prefill(
        lm, p, t, extras=extras, max_seq=max_seq))
    decode_fn = jax.jit(lambda p, tok, pos, c: serving.decode_step(
        lm, p, tok, pos, c))

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = decode_fn(params, out[-1],
                                  jnp.int32(args.prompt_len + i), cache)
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    seqs = jnp.stack(out, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.tokens-1} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.tokens-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", np.asarray(seqs[0, :16]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
