"""Catalog server CLI: ``python -m repro.launch.catalog_serve ...``

Serves an in-transit HDep database to remote viewer processes over the
``hx-frame/1`` wire format (see ``repro.insitu.server``): one process
holds the reduction cache and performs merge-at-read; every viewer —
``RemoteCatalog`` in Python, or anything that can parse a JSON header
plus raw codec bytes — shares it.

    python -m repro.launch.catalog_serve --root /tmp/hx_insitu
    python -m repro.launch.catalog_serve --root ... --port 8265 --compress

``--selftest`` is the CI smoke: it generates a small 2-domain in-transit
database (unless ``--root`` points at an existing one), serves it on an
ephemeral port, and verifies that ``RemoteCatalog.query(domain=None)``
returns arrays equal to the local ``Catalog.query`` merge-at-read for
every reduced object — plus single-flight coalescing and progressive
(coarse-first) stream bit-exactness — then exits 0/1.

``--selftest --load N`` additionally runs the serving-engine load test:
N concurrent viewer clients hammer the server through cold-cache rounds
(thundering herds) and report sustained QPS, p99 latency, and the
engine's coalesce/batch/rejection counters. The step fails on any 5xx
response or when no request was ever coalesced or batched.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import threading
import time

import numpy as np


def _make_demo_db(root: str, *, domains: int = 2, steps: int = 2) -> None:
    """Small Sedov-based 2-domain in-transit database for the selftest."""
    from ..insitu import (InTransitEngine, LevelHistogramReducer,
                          LODCutReducer, ProjectionReducer, SliceReducer)
    from ..sim import amrgen, fields
    eng = InTransitEngine(root, [
        LODCutReducer(max_level=3),
        SliceReducer(field="density", axis=2, position=0.5, resolution=64),
        ProjectionReducer(field="density", axis=2, resolution=64),
        LevelHistogramReducer(field="density", bins=16, lo=0.0, hi=8.0),
    ], domains=domains).start()
    for s in range(1, steps + 1):
        r_shock = 0.1 + 0.25 * s / steps
        tree = amrgen.generate_tree(fields.sedov(r_shock=r_shock),
                                    min_level=2, max_level=5, threshold=1.2)
        eng.submit(s, tree)
    eng.close()


def _selftest(root: str | None, compress: bool,
              token: str | None = None, *, engine: bool = True,
              serve_workers: int = 4, max_pending: int = 256,
              max_connections: int = 32, load: int = 0) -> int:
    from ..insitu import Catalog, CatalogServer, RemoteCatalog
    tmp = None
    if root is None:
        tmp = tempfile.mkdtemp(prefix="hx_catalog_selftest_")
        root = tmp
        print(f"== selftest: generating 2-domain in-transit db in {root}")
        _make_demo_db(root)
    token = token or "selftest-secret"
    srv = CatalogServer(root, port=0, compress=compress, token=token,
                        engine=engine, serve_workers=serve_workers,
                        max_pending=max_pending,
                        max_connections=max_connections).start()
    local = Catalog(root)
    try:
        # auth: no/wrong token must bounce with 401 before touching data
        for bad in (RemoteCatalog(srv.url),
                    RemoteCatalog(srv.url, token="wrong")):
            try:
                bad.steps()
            except PermissionError:
                pass
            else:
                print("   FAIL: unauthenticated request was served")
                return 1
        rc = RemoteCatalog(srv.url, token=token)
        steps = rc.steps()
        print(f"== serving {srv.url}: steps={steps}")
        if steps != local.steps() or not steps:
            print("   FAIL: step listing mismatch")
            return 1
        checked = mismatched = 0
        for s in steps:
            for reducer in local.reducers(s):
                remote = rc.query(s, reducer)       # merge-at-read,
                ref = local.query(s, reducer)       # server-side
                for k, a in ref.items():
                    checked += 1
                    if not np.array_equal(a, remote[k], equal_nan=True):
                        mismatched += 1
                        print(f"   MISMATCH step={s} {reducer}/{k}")
                if rc.domains(s, reducer) != local.domains(s, reducer):
                    mismatched += 1
                    print(f"   MISMATCH domains step={s} {reducer}")
        # ETag revalidation: a re-query of every object must 304 and
        # serve from the client cache (zero payload bytes)
        requeries = 0
        t_304 = 0.0
        for s in steps:
            for reducer in local.reducers(s):
                t0 = time.perf_counter()
                rc.query(s, reducer)
                t_304 += time.perf_counter() - t0
                requeries += 1
        cinfo = rc.client_cache_info()
        if cinfo["etag_hits"] < requeries:
            print(f"   FAIL: expected {requeries} ETag revalidation "
                  f"hits, got {cinfo}")
            return 1
        # cold-vs-304 split: a fresh viewer (empty ETag cache, warm
        # server cache) pays the full payload transfer each query
        rc_cold = RemoteCatalog(srv.url, token=token)
        t_cold = 0.0
        for s in steps:
            for reducer in local.reducers(s):
                t0 = time.perf_counter()
                rc_cold.query(s, reducer)
                t_cold += time.perf_counter() - t0
        print(f"   latency split over {requeries} queries: full transfer "
              f"{1e3 * t_cold / requeries:.2f} ms/q vs ETag-304 "
              f"revalidation {1e3 * t_304 / requeries:.2f} ms/q")
        # observability surface: /metrics must expose the request and
        # catalog latency families, behind the same bearer auth
        text = rc.metrics()
        required = ("catalog_requests_total", "catalog_request_seconds",
                    "catalog_bytes_sent_total", "catalog_etag_304_total",
                    "catalog_query_seconds", "catalog_cache_hits")
        missing = [f for f in required if f"# TYPE {f} " not in text]
        if missing:
            print(f"   FAIL: /metrics missing families: {missing}")
            return 1
        try:
            RemoteCatalog(srv.url).metrics()
        except PermissionError:
            pass
        else:
            print("   FAIL: /metrics served without a bearer token")
            return 1
        info = rc.cache_info()
        sv = info["server"]
        if sv["etag_304"] < requeries:
            print(f"   FAIL: server counted {sv['etag_304']} 304s, "
                  f"expected >= {requeries}")
            return 1
        print(f"   /metrics: {len(text.splitlines())} lines, "
              f"{len(required)} required families present")
        print(f"   {checked} arrays compared, {mismatched} mismatched; "
              f"server cache: hits={info['hits']} misses={info['misses']}; "
              f"server 304s={sv['etag_304']} "
              f"query requests={sv['requests'].get('/v1/query')}; "
              f"client etag cache: {cinfo}")
        if mismatched or not checked:
            return 1
        # progressive stream: the chunked coarse-first frames must
        # reassemble to the same bytes as the buffered response
        prog_checked = 0
        for s in steps:
            for reducer in local.reducers(s):
                ref = local.query(s, reducer)
                final = None
                for final in rc.query_progressive(s, reducer):
                    pass
                for k, a in ref.items():
                    prog_checked += 1
                    if not np.array_equal(a, final[k], equal_nan=True):
                        print(f"   FAIL: progressive mismatch "
                              f"step={s} {reducer}/{k}")
                        return 1
        print(f"   progressive streams bit-exact "
              f"({prog_checked} arrays reassembled)")
        if engine:
            # the demo objects decode in well under a millisecond —
            # faster than HTTP arrival jitter, so concurrent requests
            # would rarely overlap an in-flight read. Pace the backend
            # to a production-sized decode+merge cost so the storm
            # phases below behave deterministically.
            real_query = srv.catalog.query

            def _paced_query(*a, **kw):
                time.sleep(0.005)
                return real_query(*a, **kw)
            srv.catalog.query = _paced_query
            # thundering herd: identical cold-cache queries from many
            # fresh clients must collapse onto one backend read
            srv.catalog.clear_cache()
            s0, red0 = steps[0], local.reducers(steps[0])[0]
            before = srv.engine.stats()
            herd_errs: list[Exception] = []
            bar = threading.Barrier(16)

            def _herd(i: int) -> None:
                c = RemoteCatalog(srv.url, token=token,
                                  client_id=f"herd-{i}", busy_retries=8)
                bar.wait()
                try:
                    c.query(s0, red0)
                except Exception as exc:       # noqa: BLE001 — report all
                    herd_errs.append(exc)
            ts = [threading.Thread(target=_herd, args=(i,))
                  for i in range(16)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            after = srv.engine.stats()
            coalesced = after["coalesced"] - before["coalesced"]
            reads = after["backend_reads"] - before["backend_reads"]
            if herd_errs:
                print(f"   FAIL: herd errors: {herd_errs[:3]}")
                return 1
            if coalesced <= 0:
                print(f"   FAIL: no coalescing under a 16-client herd "
                      f"(stats={after})")
                return 1
            print(f"   herd of 16 identical queries: {reads} backend "
                  f"read(s), {coalesced} coalesced")
        if load:
            rcode = _load_test(srv, token, load)
            if rcode:
                return rcode
        return 0
    finally:
        srv.close()
        local.close()
        if tmp:
            shutil.rmtree(tmp, ignore_errors=True)


def _load_test(srv, token: str, n_clients: int, *, rounds: int = 3) -> int:
    """Concurrent-viewer load test against a live ``CatalogServer``.

    ``n_clients`` threads run ``rounds`` cold-cache rounds. Each round
    clears the server's reduction cache and barrier-releases every
    client at once (a thundering herd), so the serving engine must
    coalesce identical queries and batch the per-client region crops.
    Clients are re-created every round with empty ETag caches — a 304
    revalidation would bypass the engine and mask the storm.

    Fails (returns 1) on any 5xx/transport error, or when the engine
    never coalesced or never batched a read. 429s are retried
    client-side and the residue is reported as throttled, not failure.
    """
    from ..insitu import CatalogBusy, RemoteCatalog
    probe = RemoteCatalog(srv.url, token=token)
    steps = probe.steps()
    work = [(s, r) for s in steps for r in probe.reducers(s)]
    regions = [None, ((0, 32), (0, 32)), ((8, 48), (8, 48)),
               ((0, 16), (16, 64))]
    before = srv.engine.stats()
    lat: list[float] = []
    errors: list[str] = []
    throttled = [0]
    lock = threading.Lock()
    bar = threading.Barrier(n_clients)

    def _client(i: int) -> None:
        rc = RemoteCatalog(srv.url, token=token,
                           client_id=f"load-{i}", busy_retries=16)
        try:
            bar.wait()
        except threading.BrokenBarrierError:
            return
        my_lat, my_thr = [], 0
        for s, reducer in work:
            t0 = time.perf_counter()
            try:
                rc.query(s, reducer, region=regions[i % len(regions)])
            except CatalogBusy:
                my_thr += 1
                continue
            except Exception as exc:           # noqa: BLE001 — 5xx/socket
                with lock:
                    errors.append(f"{type(exc).__name__}: {exc}")
                continue
            my_lat.append(time.perf_counter() - t0)
        with lock:
            lat.extend(my_lat)
            throttled[0] += my_thr

    t_start = time.perf_counter()
    for rnd in range(rounds):
        srv.catalog.clear_cache()
        bar.reset()
        ts = [threading.Thread(target=_client, args=(i,))
              for i in range(n_clients)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        print(f"   round {rnd + 1}/{rounds}: {len(lat)} ok so far, "
              f"{throttled[0]} throttled, {len(errors)} errors")
    elapsed = time.perf_counter() - t_start
    after = srv.engine.stats()
    d = {k: after[k] - before[k] for k in
         ("coalesced", "batched_reads", "backend_reads", "rejections",
          "cache_serves")}
    qps = len(lat) / elapsed if elapsed > 0 else 0.0
    p99 = float(np.percentile(np.asarray(lat) * 1e3, 99)) if lat else 0.0
    requests = len(lat) + throttled[0]
    ratio = requests / max(1, d["backend_reads"])
    print(f"== load test: {n_clients} clients x {rounds} rounds x "
          f"{len(work)} queries")
    print(f"   {len(lat)} ok, {throttled[0]} throttled (429 after "
          f"retries), {len(errors)} errors in {elapsed:.2f}s")
    print(f"   sustained {qps:.0f} q/s, p99 {p99:.1f} ms; engine: "
          f"{d['backend_reads']} backend reads for {requests} requests "
          f"({ratio:.1f}x), {d['coalesced']} coalesced, "
          f"{d['batched_reads']} batched, {d['rejections']} rejected, "
          f"{d['cache_serves']} cache-served")
    if errors:
        print(f"   FAIL: {len(errors)} non-429 errors, first 3: "
              f"{errors[:3]}")
        return 1
    if d["coalesced"] <= 0 or d["batched_reads"] <= 0:
        print("   FAIL: engine never coalesced/batched under load "
              f"(stats delta: {d})")
        return 1
    if not lat:
        print("   FAIL: every request was throttled")
        return 1
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--root", default=None,
                   help="in-transit HDep database directory")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8265,
                   help="0 binds an ephemeral port")
    p.add_argument("--cache-entries", type=int, default=64,
                   help="shared reduction-cache capacity")
    p.add_argument("--compress", action="store_true",
                   help="fpdelta-pyramid-encode large float payloads")
    p.add_argument("--token", default=None,
                   help="require 'Authorization: Bearer <token>' on every "
                        "request (default: the HX_TOKEN environment "
                        "variable; unset = no auth, localhost only)")
    p.add_argument("--serve-workers", type=int, default=4,
                   help="serving-engine backend read workers")
    p.add_argument("--max-pending", type=int, default=256,
                   help="admission-control bound on queued backend reads")
    p.add_argument("--max-connections", type=int, default=32,
                   help="HTTP connection-worker pool size")
    p.add_argument("--no-engine", action="store_true",
                   help="bypass the serving engine (no coalescing, "
                        "batching, or admission control)")
    p.add_argument("--selftest", action="store_true",
                   help="serve a demo db on an ephemeral port, verify "
                        "RemoteCatalog == local Catalog (incl. bearer "
                        "auth, ETag revalidation, coalescing, and "
                        "progressive streams), exit")
    p.add_argument("--load", type=int, default=0, metavar="N",
                   help="with --selftest: also run the load test with N "
                        "concurrent clients")
    p.add_argument("--ledger", action="store_true",
                   help="write this server's telemetry (metrics, serve "
                        "events, health) into <root>/telemetry/ as its "
                        "own ledger domain, merged at read with the "
                        "trainer's flushes")
    p.add_argument("--ledger-interval", type=float, default=5.0,
                   help="seconds between background ledger flushes")
    args = p.parse_args(argv)

    import os
    token = args.token if args.token is not None \
        else os.environ.get("HX_TOKEN") or None
    if args.selftest:
        return _selftest(args.root, args.compress, token,
                         engine=not args.no_engine,
                         serve_workers=args.serve_workers,
                         max_pending=args.max_pending,
                         max_connections=args.max_connections,
                         load=args.load)
    if args.root is None:
        p.error("--root is required (or use --selftest)")
    from ..insitu import CatalogServer
    srv = CatalogServer(args.root, host=args.host, port=args.port,
                        cache_entries=args.cache_entries,
                        compress=args.compress, token=token,
                        engine=not args.no_engine,
                        serve_workers=args.serve_workers,
                        max_pending=args.max_pending,
                        max_connections=args.max_connections)
    ledger = None
    if args.ledger:
        from ..obs import RunLedger
        ledger = RunLedger(args.root, "server",
                           interval=args.ledger_interval)
        srv.bind_ledger(ledger)
    print(f"catalog server on {srv.url} (root={args.root}, "
          f"cache={args.cache_entries} entries, "
          f"compress={args.compress}, auth={'on' if token else 'off'}, "
          f"ledger={'on' if ledger else 'off'}) "
          f"— Ctrl-C to stop")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()
        if ledger is not None:
            ledger.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
