"""Catalog server CLI: ``python -m repro.launch.catalog_serve ...``

Serves an in-transit HDep database to remote viewer processes over the
``hx-frame/1`` wire format (see ``repro.insitu.server``): one process
holds the reduction cache and performs merge-at-read; every viewer —
``RemoteCatalog`` in Python, or anything that can parse a JSON header
plus raw codec bytes — shares it.

    python -m repro.launch.catalog_serve --root /tmp/hx_insitu
    python -m repro.launch.catalog_serve --root ... --port 8265 --compress

``--selftest`` is the CI smoke: it generates a small 2-domain in-transit
database (unless ``--root`` points at an existing one), serves it on an
ephemeral port, and verifies that ``RemoteCatalog.query(domain=None)``
returns arrays equal to the local ``Catalog.query`` merge-at-read for
every reduced object — then exits 0/1.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import numpy as np


def _make_demo_db(root: str, *, domains: int = 2, steps: int = 2) -> None:
    """Small Sedov-based 2-domain in-transit database for the selftest."""
    from ..insitu import (InTransitEngine, LevelHistogramReducer,
                          LODCutReducer, ProjectionReducer, SliceReducer)
    from ..sim import amrgen, fields
    eng = InTransitEngine(root, [
        LODCutReducer(max_level=3),
        SliceReducer(field="density", axis=2, position=0.5, resolution=64),
        ProjectionReducer(field="density", axis=2, resolution=64),
        LevelHistogramReducer(field="density", bins=16, lo=0.0, hi=8.0),
    ], domains=domains).start()
    for s in range(1, steps + 1):
        r_shock = 0.1 + 0.25 * s / steps
        tree = amrgen.generate_tree(fields.sedov(r_shock=r_shock),
                                    min_level=2, max_level=5, threshold=1.2)
        eng.submit(s, tree)
    eng.close()


def _selftest(root: str | None, compress: bool,
              token: str | None = None) -> int:
    from ..insitu import Catalog, CatalogServer, RemoteCatalog
    tmp = None
    if root is None:
        tmp = tempfile.mkdtemp(prefix="hx_catalog_selftest_")
        root = tmp
        print(f"== selftest: generating 2-domain in-transit db in {root}")
        _make_demo_db(root)
    token = token or "selftest-secret"
    srv = CatalogServer(root, port=0, compress=compress,
                        token=token).start()
    local = Catalog(root)
    try:
        # auth: no/wrong token must bounce with 401 before touching data
        for bad in (RemoteCatalog(srv.url),
                    RemoteCatalog(srv.url, token="wrong")):
            try:
                bad.steps()
            except PermissionError:
                pass
            else:
                print("   FAIL: unauthenticated request was served")
                return 1
        rc = RemoteCatalog(srv.url, token=token)
        steps = rc.steps()
        print(f"== serving {srv.url}: steps={steps}")
        if steps != local.steps() or not steps:
            print("   FAIL: step listing mismatch")
            return 1
        checked = mismatched = 0
        for s in steps:
            for reducer in local.reducers(s):
                remote = rc.query(s, reducer)       # merge-at-read,
                ref = local.query(s, reducer)       # server-side
                for k, a in ref.items():
                    checked += 1
                    if not np.array_equal(a, remote[k], equal_nan=True):
                        mismatched += 1
                        print(f"   MISMATCH step={s} {reducer}/{k}")
                if rc.domains(s, reducer) != local.domains(s, reducer):
                    mismatched += 1
                    print(f"   MISMATCH domains step={s} {reducer}")
        # ETag revalidation: a re-query of every object must 304 and
        # serve from the client cache (zero payload bytes)
        requeries = 0
        t_304 = 0.0
        for s in steps:
            for reducer in local.reducers(s):
                t0 = time.perf_counter()
                rc.query(s, reducer)
                t_304 += time.perf_counter() - t0
                requeries += 1
        cinfo = rc.client_cache_info()
        if cinfo["etag_hits"] < requeries:
            print(f"   FAIL: expected {requeries} ETag revalidation "
                  f"hits, got {cinfo}")
            return 1
        # cold-vs-304 split: a fresh viewer (empty ETag cache, warm
        # server cache) pays the full payload transfer each query
        rc_cold = RemoteCatalog(srv.url, token=token)
        t_cold = 0.0
        for s in steps:
            for reducer in local.reducers(s):
                t0 = time.perf_counter()
                rc_cold.query(s, reducer)
                t_cold += time.perf_counter() - t0
        print(f"   latency split over {requeries} queries: full transfer "
              f"{1e3 * t_cold / requeries:.2f} ms/q vs ETag-304 "
              f"revalidation {1e3 * t_304 / requeries:.2f} ms/q")
        # observability surface: /metrics must expose the request and
        # catalog latency families, behind the same bearer auth
        text = rc.metrics()
        required = ("catalog_requests_total", "catalog_request_seconds",
                    "catalog_bytes_sent_total", "catalog_etag_304_total",
                    "catalog_query_seconds", "catalog_cache_hits")
        missing = [f for f in required if f"# TYPE {f} " not in text]
        if missing:
            print(f"   FAIL: /metrics missing families: {missing}")
            return 1
        try:
            RemoteCatalog(srv.url).metrics()
        except PermissionError:
            pass
        else:
            print("   FAIL: /metrics served without a bearer token")
            return 1
        info = rc.cache_info()
        sv = info["server"]
        if sv["etag_304"] < requeries:
            print(f"   FAIL: server counted {sv['etag_304']} 304s, "
                  f"expected >= {requeries}")
            return 1
        print(f"   /metrics: {len(text.splitlines())} lines, "
              f"{len(required)} required families present")
        print(f"   {checked} arrays compared, {mismatched} mismatched; "
              f"server cache: hits={info['hits']} misses={info['misses']}; "
              f"server 304s={sv['etag_304']} "
              f"query requests={sv['requests'].get('/v1/query')}; "
              f"client etag cache: {cinfo}")
        return 1 if mismatched or not checked else 0
    finally:
        srv.close()
        local.close()
        if tmp:
            shutil.rmtree(tmp, ignore_errors=True)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--root", default=None,
                   help="in-transit HDep database directory")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8265,
                   help="0 binds an ephemeral port")
    p.add_argument("--cache-entries", type=int, default=64,
                   help="shared reduction-cache capacity")
    p.add_argument("--compress", action="store_true",
                   help="fpdelta-pyramid-encode large float payloads")
    p.add_argument("--token", default=None,
                   help="require 'Authorization: Bearer <token>' on every "
                        "request (default: the HX_TOKEN environment "
                        "variable; unset = no auth, localhost only)")
    p.add_argument("--selftest", action="store_true",
                   help="serve a demo db on an ephemeral port, verify "
                        "RemoteCatalog == local Catalog (incl. bearer "
                        "auth and ETag revalidation), exit")
    args = p.parse_args(argv)

    import os
    token = args.token if args.token is not None \
        else os.environ.get("HX_TOKEN") or None
    if args.selftest:
        return _selftest(args.root, args.compress, token)
    if args.root is None:
        p.error("--root is required (or use --selftest)")
    from ..insitu import CatalogServer
    srv = CatalogServer(args.root, host=args.host, port=args.port,
                        cache_entries=args.cache_entries,
                        compress=args.compress, token=token)
    print(f"catalog server on {srv.url} (root={args.root}, "
          f"cache={args.cache_entries} entries, "
          f"compress={args.compress}, auth={'on' if token else 'off'}) "
          f"— Ctrl-C to stop")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
