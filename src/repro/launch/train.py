"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the real training loop (smoke-scale on CPU, production mesh when
devices exist) with Hercule HProt checkpointing; resume is automatic.
"""
from __future__ import annotations

import argparse

from ..configs import ARCHS, get_config, get_smoke_config
from ..data.pipeline import DataConfig
from ..models.transformer import LM
from ..train import optim
from ..train.trainer import Trainer


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCHS, required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="/tmp/hx_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--ckpt-mode", default="raw",
                   choices=["raw", "delta", "pyramid", "auto"])
    p.add_argument("--ckpt-async", action="store_true",
                   help="HProt async checkpointing: device-side snapshot "
                        "only on the train thread; encode/write/fsync "
                        "behind staged writer lanes")
    p.add_argument("--ckpt-delta-every", type=int, default=0, metavar="K",
                   help="with --ckpt-async: K incremental delta "
                        "checkpoints between full rebases (0 = always full)")
    p.add_argument("--ckpt-lane-backend", default="thread",
                   choices=["thread", "process"],
                   help="async checkpoint writer lanes: in-process "
                        "threads, or one OS process per contributor group")
    p.add_argument("--ncf", type=int, default=8,
                   help="Hercule contributors per file")
    p.add_argument("--hdep-dir", default=None)
    p.add_argument("--hdep-every", type=int, default=0)
    p.add_argument("--insitu-dir", default=None,
                   help="in-transit reduced HDep output (repro.insitu)")
    p.add_argument("--insitu-every", type=int, default=0)
    p.add_argument("--insitu-policy", default="drop-oldest",
                   choices=["block", "drop-oldest", "subsample"])
    p.add_argument("--insitu-domains", type=int, default=1,
                   help="in-transit contributor groups (reduced objects "
                        "are written one domain per group, merged at read)")
    p.add_argument("--insitu-backend", default="thread",
                   choices=["thread", "process"],
                   help="lane runtime: in-process worker threads, or one "
                        "OS process per group over shared-memory staging")
    p.add_argument("--insitu-device-reduce", action="store_true",
                   help="stage train-state snapshots on the accelerator "
                        "(zero-copy) and transfer only reduced objects")
    p.add_argument("--insitu-device-mesh", type=int, default=0,
                   metavar="N",
                   help="shard in-transit AMR reductions over N jax "
                        "devices (shard_map + on-device merge; 0 = off)")
    p.add_argument("--insitu-trace-out", default=None, metavar="PATH",
                   help="record in-transit spans and write a Chrome-trace "
                        "JSON (Perfetto) when training finishes")
    p.add_argument("--ledger", action="store_true",
                   help="persist a run ledger (metrics/spans/events/"
                        "attribution/health) into <insitu-dir or "
                        "ckpt-dir>/telemetry/; inspect with "
                        "python -m repro.launch.obs")
    p.add_argument("--ledger-interval", type=float, default=2.0,
                   help="seconds between background ledger flushes")
    p.add_argument("--metrics-port", type=int, default=None, metavar="P",
                   help="expose a Prometheus /metrics endpoint from the "
                        "trainer process on this port (0 = ephemeral)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg)
    trainer = Trainer(
        lm,
        opt_cfg=optim.OptConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10),
                                stable_steps=args.steps, decay_steps=args.steps // 5 + 1),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            global_batch=args.global_batch, seed=args.seed),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        ckpt_mode=args.ckpt_mode, ncf=args.ncf,
        ckpt_async=args.ckpt_async,
        ckpt_delta_every=args.ckpt_delta_every,
        ckpt_lane_backend=args.ckpt_lane_backend,
        hdep_dir=args.hdep_dir, hdep_every=args.hdep_every,
        insitu_dir=args.insitu_dir, insitu_every=args.insitu_every,
        insitu_policy=args.insitu_policy,
        insitu_domains=args.insitu_domains,
        insitu_backend=args.insitu_backend,
        insitu_device_reduce=args.insitu_device_reduce,
        insitu_device_mesh=args.insitu_device_mesh,
        insitu_trace_out=args.insitu_trace_out,
        ledger=args.ledger, ledger_interval=args.ledger_interval,
        metrics_port=args.metrics_port,
        seed=args.seed)
    trainer.run(args.steps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
