"""train_step: microbatched grad accumulation + AdamW, jit/pjit-ready.

``cfg.num_microbatches`` splits the global batch inside the step with a
``lax.scan`` so peak activation memory scales with the microbatch — the
lever that fits nemotron-340b's train_4k cell (DESIGN.md §5). The whole
state is donated; under a mesh everything runs SPMD from the in/out
shardings that launch/dryrun.py attaches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.transformer import LM
from . import optim


def make_train_step(lm: LM, opt_cfg: optim.OptConfig):
    cfg = lm.cfg

    def loss_for(params, batch):
        return lm.loss_fn(params, batch)

    def train_step(state, batch):
        params = state["params"]
        nmb = max(1, cfg.num_microbatches)

        if nmb == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (_, m), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + m["loss"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(accum, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            metrics = {"loss": loss_sum / nmb, "aux": jnp.zeros((), jnp.float32)}

        params, opt_state, opt_metrics = optim.adamw_step(
            params, grads, {k: state[k] for k in ("mu", "nu", "step")}, opt_cfg)
        new_state = {"params": params, **opt_state}
        return new_state, {**metrics, **opt_metrics}

    return train_step


def init_state(lm: LM, key):
    params = lm.init(key)
    return {"params": params, **optim.init_opt_state(params)}


def abstract_state(lm: LM):
    params = lm.abstract_params()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"params": params,
            "mu": jax.tree.map(f32, params),
            "nu": jax.tree.map(f32, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(lm: LM):
    axes = lm.param_axes()
    return {"params": axes, "mu": axes, "nu": axes, "step": ()}
