"""Supervisor: relaunch a training subprocess until it completes.

The elastic/fault-tolerant outer loop: each attempt resumes from the
latest complete HProt context, so induced crashes (or preemptions) only
cost the steps since the last checkpoint. Exercised by
``examples/fault_tolerant_training.py`` and the integration tests.
"""
from __future__ import annotations

import os
import subprocess
import sys


def run_supervised(cmd: list[str], *, max_restarts: int = 5,
                   env: dict | None = None,
                   env_first: dict | None = None) -> tuple[int, int]:
    """Run ``cmd`` until exit 0 or restart budget exhausted.

    ``env_first`` applies only to the first attempt (e.g. an induced-crash
    trigger that models a one-off node failure).
    Returns (final_returncode, restarts_used).
    """
    restarts = 0
    while True:
        extra = env_first if restarts == 0 else None
        proc = subprocess.run(
            cmd, env={**os.environ, **(env or {}), **(extra or {})})
        if proc.returncode == 0:
            return 0, restarts
        restarts += 1
        print(f"[supervisor] child exited rc={proc.returncode}; "
              f"restart {restarts}/{max_restarts}", flush=True)
        if restarts >= max_restarts:
            return proc.returncode, restarts


def main(argv=None):
    argv = argv or sys.argv[1:]
    if not argv:
        print("usage: python -m repro.train.supervisor -- <cmd...>")
        return 2
    if argv[0] == "--":
        argv = argv[1:]
    rc, n = run_supervised(argv)
    print(f"[supervisor] done rc={rc} after {n} restarts")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
