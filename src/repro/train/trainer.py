"""Training loop with Hercule HProt checkpointing and fault tolerance.

Fault-tolerance surface (DESIGN.md §6):
  * periodic async checkpoints (contexts) + atomic finalize;
  * restore-latest on startup -> crash/restart continues bit-exactly
    (data pipeline is a pure function of step; RNG state is in the state);
  * SIGTERM/SIGINT -> synchronous final checkpoint (preemption grace);
  * optional induced crash (env TRAIN_CRASH_AT) for the supervisor demo;
  * straggler monitor: EWMA step-time watchdog, events surfaced in logs
    and metrics (on a real cluster this feeds the scheduler; here it is
    observable behavior under test).
"""
from __future__ import annotations

import os
import signal
import time

import jax
import numpy as np

from ..data.pipeline import DataConfig, TokenPipeline
from ..hercule.checkpoint import CheckpointManager
from ..models.transformer import LM
from . import optim, step as step_lib


class StragglerMonitor:
    """Flags steps slower than ``factor`` x the EWMA of recent steps."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2, warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma = None
        self.count = 0
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = self.count > self.warmup and dt > self.factor * self.ewma
        if slow:
            self.events.append((step, dt, self.ewma))
        # stragglers don't poison the baseline
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class Trainer:
    def __init__(self, lm: LM, *, opt_cfg: optim.OptConfig | None = None,
                 data_cfg: DataConfig | None = None,
                 ckpt_dir: str = "/tmp/hx_ckpt", ckpt_every: int = 50,
                 ckpt_mode: str = "raw", ncf: int = 8,
                 ckpt_async: bool = False, ckpt_delta_every: int = 0,
                 ckpt_lane_backend: str = "thread",
                 seed: int = 0, log_every: int = 10,
                 hdep_dir: str | None = None, hdep_every: int = 0,
                 insitu_dir: str | None = None, insitu_every: int = 0,
                 insitu_reducers=None, insitu_policy: str = "drop-oldest",
                 insitu_domains: int = 1, insitu_backend: str = "thread",
                 insitu_device_reduce: bool = False,
                 insitu_device_mesh: int = 0,
                 insitu_trace_out: str | None = None,
                 ledger: bool = False, ledger_interval: float = 2.0,
                 metrics_port: int | None = None):
        self.lm = lm
        self.cfg = lm.cfg
        self.opt_cfg = opt_cfg or optim.OptConfig()
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=lm.cfg.vocab_size, seq_len=256, global_batch=8, seed=seed)
        self.pipeline = TokenPipeline(self.data_cfg)
        if ckpt_async:
            # HProt flow: device-side snapshot is the only train-thread
            # cost; encode/write/fsync run behind staged writer lanes,
            # with optional delta checkpoints every K saves (DESIGN.md §16)
            from ..ckpt import AsyncCheckpointManager
            self.ckpt = AsyncCheckpointManager(
                ckpt_dir, ncf=ncf, delta_every=ckpt_delta_every,
                lane_backend=ckpt_lane_backend)
        else:
            self.ckpt = CheckpointManager(ckpt_dir, ncf=ncf, mode=ckpt_mode)
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.hdep_every = hdep_every
        self.hdep = None
        if hdep_dir and hdep_every:
            from ..hercule.database import HerculeDB
            self.hdep = HerculeDB.create(hdep_dir, kind="hdep", ncf=ncf)
        self.insitu = None
        if insitu_dir and insitu_every:
            from ..insitu import (InTransitEngine, SpectraReducer,
                                  TensorNormReducer)
            reducers = insitu_reducers if insitu_reducers is not None else \
                [TensorNormReducer(), SpectraReducer(k=8)]
            # backend="process" moves each contributor lane to its own
            # OS process over shared-memory staging: reductions and
            # domain writes stop competing with the train step's Python
            # device_reduce stages the train-state leaves on the
            # accelerator (zero-copy: they are already jax arrays) and
            # only the reduced tensor summaries cross to the host
            self.insitu = InTransitEngine(
                insitu_dir, reducers, output_every=insitu_every,
                policy=insitu_policy, ncf=ncf, domains=insitu_domains,
                backend=insitu_backend,
                device_reduce="mesh" if insitu_device_mesh
                else insitu_device_reduce,
                mesh_devices=insitu_device_mesh or None)
        self.insitu_trace_out = insitu_trace_out
        if insitu_trace_out and self.insitu is not None:
            from ..obs import TRACER
            TRACER.enable()
        self.ledger = None
        if ledger:
            # the run ledger lives with the run's analysis output when
            # there is one, else beside the checkpoints
            from ..obs import RunLedger, TRACER
            TRACER.enable()
            self.ledger = RunLedger(
                insitu_dir if self.insitu is not None else ckpt_dir,
                "trainer", interval=ledger_interval)
            if self.insitu is not None:
                self.insitu.bind_ledger(self.ledger)
            if hasattr(self.ckpt, "bind_ledger"):
                self.ckpt.bind_ledger(self.ledger)
        self.metrics_server = None
        if metrics_port is not None:
            from ..obs import serve_metrics
            self.metrics_server = serve_metrics(metrics_port)
            print(f"metrics endpoint: {self.metrics_server.url}",
                  flush=True)
        self.monitor = StragglerMonitor()
        self.seed = seed
        self._stop = False
        self.metrics_log: list[dict] = []

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not main thread (tests)

    def init_or_restore(self):
        latest = self.ckpt.latest_step()
        if latest is not None:
            template = step_lib.abstract_state(self.lm)
            dev = jax.devices()[0]
            template = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, s.dtype,
                    sharding=jax.sharding.SingleDeviceSharding(dev)),
                template)
            state, attrs = self.ckpt.restore(template)
            return state, int(latest)
        state = step_lib.init_state(self.lm, jax.random.PRNGKey(self.seed))
        return state, 0

    def run(self, num_steps: int, *, crash_at: int | None = None):
        self._install_signals()
        crash_at = crash_at if crash_at is not None else \
            int(os.environ.get("TRAIN_CRASH_AT", "0")) or None
        state, start = self.init_or_restore()
        train_step = jax.jit(step_lib.make_train_step(self.lm, self.opt_cfg),
                             donate_argnums=0)
        for s in range(start, num_steps):
            t0 = time.perf_counter()
            batch = self.pipeline.batch(s)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = train_step(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            slow = self.monitor.observe(s, dt)
            metrics.update(step=s + 1, dt=dt, straggler=bool(slow))
            self.metrics_log.append(metrics)
            if self.log_every and (s + 1) % self.log_every == 0:
                print(f"step {s+1:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.2f} "
                      f"{dt*1e3:.0f} ms{' [straggler]' if slow else ''}",
                      flush=True)
            if crash_at and (s + 1) == crash_at:
                print(f"induced crash at step {s+1}", flush=True)
                os._exit(17)
            if (s + 1) % self.ckpt_every == 0 or (s + 1) == num_steps or self._stop:
                self.ckpt.save(s + 1, state,
                               attrs={"loss": metrics["loss"]})
            if self.hdep is not None and (s + 1) % self.hdep_every == 0:
                self._dump_analysis(s + 1, state)
            if self.insitu is not None:
                # in-transit flow: engine decides cadence + backpressure;
                # compute never stalls under a non-blocking policy
                self.insitu.submit_state(s + 1, state)
            if self._stop:
                print(f"signal received: checkpointed at step {s+1}, exiting",
                      flush=True)
                break
        self.ckpt.wait()
        self.ckpt.close()
        if self.insitu is not None:
            self.insitu.close()
            if self.insitu_trace_out:
                from ..obs import TRACER
                n = TRACER.write_chrome_trace(self.insitu_trace_out)
                print(f"in-transit trace: {n} spans -> "
                      f"{self.insitu_trace_out}", flush=True)
        if self.ledger is not None:
            verdict = self.ledger.verdict()
            self.ledger.close()
            print(f"run ledger: {self.ledger.flushes} flushes, "
                  f"verdict={verdict} -> {self.ledger.dir}", flush=True)
        if self.metrics_server is not None:
            self.metrics_server.close()
        return state

    def _dump_analysis(self, step: int, state):
        """HDep flow at its own frequency (paper fig. 1)."""
        from ..hercule import api as hercule_api
        from ..hercule.checkpoint import leaf_name
        ctx = self.hdep.begin_context(step)
        flat, _ = jax.tree_util.tree_flatten_with_path(state["params"])
        stats = {}
        for path, leaf in flat:
            name = leaf_name(path)
            arr = np.asarray(leaf)
            if arr.ndim >= 2:
                stats[name] = arr
        hercule_api.write_object(ctx, "analysis", 0, stats)
        ctx.finalize(attrs={"step": step})
