"""AdamW with the WSD (warmup–stable–decay) schedule (minicpm,
arXiv:2404.06395) and global-norm clipping. Optimizer state shards
exactly like the parameters (same logical axes -> ZeRO-compatible)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    stable_steps: int = 10_000
    decay_steps: int = 2_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def wsd_schedule(step, cfg: OptConfig):
    """Warmup -> Stable -> (sqrt-like exponential) Decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_t = (step - cfg.warmup_steps - cfg.stable_steps) / jnp.maximum(
        cfg.decay_steps, 1)
    decay_t = jnp.clip(decay_t, 0.0, 1.0)
    decay = cfg.min_lr_ratio ** decay_t  # exponential anneal to min ratio
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_step(params, grads, opt_state, cfg: OptConfig):
    """One AdamW update; returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = wsd_schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    params = jax.tree.unflatten(treedef, [o[0] for o in out])
    mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params, {"mu": mu, "nu": nu, "step": step}, metrics
