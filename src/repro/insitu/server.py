"""Server-mode catalog: one shared reduction cache for many viewers.

The paper's post-processing scenario (and the in-situ services in
VisIVO/IHPV-style pipelines) has analysis consumers as *remote
processes* querying a catalog service. This module puts the
:class:`~repro.insitu.catalog.Catalog` behind a small stdlib HTTP server
so any number of viewer processes share one LRU reduction cache and one
merge-at-read pass — instead of each process re-reading and re-merging
the same domains.

Wire format (``hx-frame/1``): array payloads travel as raw codec bytes
with a JSON descriptor header, reusing the registered Hercule codecs —
no pickle on the wire, any language can parse it:

    b"HXF1" | u32 header_len | header JSON | payload bytes...

    header = {"schema": "hx-frame/1",
              "arrays": [{"name", "dtype", "shape", "codec", "meta",
                          "nbytes"}, ...]}

Payloads are codec-encoded per array (``raw`` by default; the server may
opt into ``fpdelta-pyramid`` for large float arrays) and concatenated in
header order; the client decodes through the same codec registry
(:func:`repro.hercule.database.get_codec`).

Endpoints (JSON unless framed):

    GET /v1/manifest                         server + database summary
    GET /v1/steps                            context steps
    GET /v1/reducers?step=S                  reducer names in one context
    GET /v1/attrs?step=S                     context attrs
    GET /v1/domains?step=S&reducer=R         contributing domains
    GET /v1/query?step=S&reducer=R[&domain=D][&region=a:b,c:d]   framed
        [&progressive=1]  -> chunked coarse-first hx-frame stream
    GET /v1/series?reducer=R&name=N[&steps=s1,s2]                framed
    GET /v1/stats                            cache + request telemetry
    GET /metrics                             Prometheus text exposition

:class:`RemoteCatalog` mirrors ``Catalog.query`` / ``series`` /
``domains`` (and the discovery surface) over these endpoints; a missing
object raises :class:`KeyError` exactly like the local catalog.
"""
from __future__ import annotations

import collections
import hashlib
import hmac
import json
import os
import queue
import struct
import threading
import time
import urllib.error
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from ..hercule.database import Record, get_codec
from ..obs import metrics as obs_metrics
from .catalog import Catalog, _hist_digest, _normalize_region
from .serve import (ProgressiveAssembler, ServeEngine, ServeOverloaded,
                    plan_progressive)

FRAME_MAGIC = b"HXF1"
FRAME_SCHEMA = "hx-frame/1"


# ------------------------------------------------------------ wire format

def pack_frame(arrays: dict[str, np.ndarray], *,
               compress: bool = False) -> bytes:
    """Encode named arrays as one hx-frame/1 message."""
    descs, payloads = [], []
    for name, arr in arrays.items():
        arr = np.ascontiguousarray(arr)
        codec, meta, payload = "raw", {}, None
        if compress and arr.dtype.kind == "f" and arr.size >= 64:
            enc, m = get_codec("fpdelta-pyramid").encode(arr)
            if len(enc) < arr.nbytes:
                payload, codec, meta = enc, "fpdelta-pyramid", m
        if payload is None:   # raw only materialized when it wins
            payload, _ = get_codec("raw").encode(arr)
        descs.append({"name": name, "dtype": str(arr.dtype),
                      "shape": list(arr.shape), "codec": codec,
                      "meta": meta, "nbytes": len(payload)})
        payloads.append(payload)
    header = json.dumps({"schema": FRAME_SCHEMA, "arrays": descs}).encode()
    return b"".join([FRAME_MAGIC, struct.pack("<I", len(header)), header,
                     *payloads])


def unpack_frame(data: bytes) -> dict[str, np.ndarray]:
    """Decode one hx-frame/1 message through the codec registry."""
    if data[:4] != FRAME_MAGIC:
        raise ValueError("not an hx-frame/1 message")
    (hlen,) = struct.unpack_from("<I", data, 4)
    head = json.loads(data[8:8 + hlen].decode())
    if head.get("schema") != FRAME_SCHEMA:
        raise ValueError(f"unsupported frame schema {head.get('schema')!r}")
    out, off = {}, 8 + hlen
    for d in head["arrays"]:
        payload = data[off:off + d["nbytes"]]
        off += d["nbytes"]
        rec = Record(name=d["name"], domain=0, file="", offset=0,
                     nbytes=d["nbytes"], dtype=d["dtype"],
                     shape=tuple(d["shape"]), codec=d["codec"],
                     meta=d.get("meta", {}))
        # frame codecs are self-contained (no cross-context predictors),
        # so decode needs no database handle
        out[d["name"]] = get_codec(d["codec"]).decode(None, rec, payload)
    return out


def _read_exact(fp, n: int) -> bytes:
    """Read exactly ``n`` bytes from a file-like (chunk-decoded) stream."""
    parts, got = [], 0
    while got < n:
        chunk = fp.read(n - got)
        if not chunk:
            raise ValueError(
                f"progressive stream truncated: wanted {n}, got {got}")
        parts.append(chunk)
        got += len(chunk)
    return b"".join(parts)


def _read_wire_frame(fp) -> bytes:
    """Read one complete hx-frame/1 message off a streaming response."""
    head = _read_exact(fp, 8)
    if head[:4] != FRAME_MAGIC:
        raise ValueError("not an hx-frame/1 stream")
    (hlen,) = struct.unpack_from("<I", head, 4)
    header = _read_exact(fp, hlen)
    nbytes = sum(d["nbytes"]
                 for d in json.loads(header.decode())["arrays"])
    return head + header + _read_exact(fp, nbytes)


def _parse_region(spec: str):
    """``"8:24,0:16"`` -> ((8, 24), (0, 16))."""
    return tuple(tuple(int(x) for x in part.split(":"))
                 for part in spec.split(","))


def _format_region(region) -> str:
    return ",".join(f"{int(lo)}:{int(hi)}" for lo, hi in region)


# ----------------------------------------------------------------- server

class _PooledHTTPServer(ThreadingHTTPServer):
    """HTTP server with a *bounded* connection-worker pool.

    ``ThreadingHTTPServer`` spawns one OS thread per connection — under
    a viewer storm the OS scheduler, not the serving engine, becomes
    the backstop. Here accepted connections land on a queue drained by
    ``max_connections`` long-lived daemon workers: concurrency is capped
    by configuration, excess connections simply wait their turn (the
    engine's admission control 429s *work* overload long before the
    connection cap matters), and saturation is observable
    (``server_conn_active`` gauge, ``server_conn_saturation_total``
    counter) instead of showing up as thread-count growth.
    """

    def __init__(self, addr, handler, *, max_connections: int = 32,
                 obs: obs_metrics.MetricsRegistry | None = None):
        self.max_connections = max(1, int(max_connections))
        # socketserver's default listen backlog is 5: a viewer-storm
        # connection burst overflows it, dropped SYNs retransmit after
        # 1s, and tail latency jumps by whole seconds. Queue the burst
        # here instead — the workers drain it in arrival order.
        self.request_queue_size = max(128, 4 * self.max_connections)
        super().__init__(addr, handler)
        self._conn_q: queue.SimpleQueue = queue.SimpleQueue()
        self._active_lock = threading.Lock()
        self._active = 0
        self._m_saturated = None
        if obs is not None:
            self._m_saturated = obs.counter(
                "server_conn_saturation_total",
                "connections queued because every worker was busy")
            obs.gauge("server_conn_active",
                      "connection workers currently handling a request"
                      ).set_function(lambda: self._active)
            obs.gauge("server_conn_pool_size",
                      "configured connection-worker cap"
                      ).set(self.max_connections)
        self._conn_threads = [
            threading.Thread(target=self._conn_worker, daemon=True,
                             name=f"hx-conn-{i}")
            for i in range(self.max_connections)]
        for t in self._conn_threads:
            t.start()

    def process_request(self, request, client_address):
        if self._m_saturated is not None and obs_metrics.ENABLED:
            with self._active_lock:
                saturated = self._active >= self.max_connections
            if saturated:
                self._m_saturated.inc()
        self._conn_q.put((request, client_address))

    def _conn_worker(self) -> None:
        while True:
            item = self._conn_q.get()
            if item is None:
                return
            request, client_address = item
            with self._active_lock:
                self._active += 1
            try:
                self.finish_request(request, client_address)
            except Exception:       # noqa: BLE001 — mirror ThreadingMixIn
                self.handle_error(request, client_address)
            finally:
                self.shutdown_request(request)
                with self._active_lock:
                    self._active -= 1

    def server_close(self) -> None:
        super().server_close()
        for _ in self._conn_threads:
            self._conn_q.put(None)


class CatalogServer:
    """HTTP front-end over one shared :class:`Catalog`.

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    The handler threads all hit the same catalog, whose lock-guarded
    LRU makes concurrent viewer queries share reductions.

    ``token`` switches on bearer authentication: every request must
    carry ``Authorization: Bearer <token>`` (compared constant-time) or
    is refused with 401 — the minimum for a deployment beyond
    localhost. ``/v1/query`` responses carry an ``ETag`` derived from
    the immutable context manifest, and ``If-None-Match`` revalidation
    answers 304 with no body — a hot viewer re-polling the same object
    skips the transfer entirely (see :class:`RemoteCatalog`).

    ``engine=True`` (the default) routes ``/v1/query`` through a
    :class:`~repro.insitu.serve.ServeEngine`: concurrent identical
    queries coalesce onto one backend read, region crops batch, and
    admission control answers overload with 429 + ``Retry-After``
    (optionally coupled to a staging ring via ``pressure_fn``, see
    :func:`~repro.insitu.serve.staging_pressure`). Connection handling
    runs on a bounded pool of ``max_connections`` workers rather than a
    thread per connection.
    """

    def __init__(self, root, *, host: str = "127.0.0.1", port: int = 0,
                 cache_entries: int = 64, compress: bool = False,
                 token: str | None = None, engine: bool = True,
                 serve_workers: int = 4, max_pending: int = 256,
                 max_connections: int = 32, pressure_fn=None):
        if isinstance(root, Catalog) or hasattr(root, "query"):
            self.catalog, self._own_catalog = root, False
        else:
            self.catalog = Catalog(root, cache_entries=cache_entries)
            self._own_catalog = True
        self.compress = compress
        self.obs = obs_metrics.MetricsRegistry()
        self._sync_obs()
        self.engine = ServeEngine(
            self.catalog, workers=serve_workers, max_pending=max_pending,
            pressure_fn=pressure_fn, obs=self.obs) if engine else None
        handler = _make_handler(self.catalog, compress, token, self.obs,
                                self.engine)
        self.httpd = _PooledHTTPServer(
            (host, port), handler, max_connections=max_connections,
            obs=self.obs)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    def bind_ledger(self, ledger) -> None:
        """Register this server with a run ledger (its own, when run as
        a standalone process — ``launch/catalog_serve.py --ledger`` —
        or the trainer's in embedded use): metrics become a flush
        source; ``serve_p99_ms`` (worst per-endpoint request p99 in ms)
        feeds the health rules."""
        ledger.add_source("server", self.obs.snapshot)
        hist = self.obs.histogram(
            "catalog_request_seconds", "request handling latency",
            labels=("endpoint",))

        def p99_ms():
            worst = None
            for _, child in hist.children():
                if child.count:
                    q = child.quantile(0.99) * 1e3
                    worst = q if worst is None else max(worst, q)
            return worst

        ledger.add_signal("serve_p99_ms", p99_ms)

    def _sync_obs(self) -> None:
        """Mirror the shared catalog's cache counters into gauges."""
        cat = self.catalog
        for name, fn in (("entries", lambda: len(cat._cache)),
                         ("hits", lambda: cat.cache_hits),
                         ("misses", lambda: cat.cache_misses),
                         ("io_reads", lambda: cat.io_reads)):
            self.obs.gauge(f"catalog_cache_{name}",
                           f"shared reduction cache: {name}"
                           ).set_function(fn)

    def telemetry(self) -> dict:
        """JSON-able merged snapshot: cache counters + request metrics."""
        out = {"cache": self.catalog.cache_info(),
               "metrics": self.obs.snapshot()}
        if self.engine is not None:
            out["serve"] = self.engine.stats()
        return out

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "CatalogServer":
        """Serve on a background thread (tests, embedded viewers)."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self.httpd.serve_forever, name="catalog-server",
                daemon=True)
            self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.httpd.serve_forever()

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if self.engine is not None:
            self.engine.close()
        if self._own_catalog:
            self.catalog.close()


#: routes whose paths become metric label values; anything else is
#: folded into "other" so probing clients can't explode the cardinality
_KNOWN_ENDPOINTS = frozenset({
    "/v1/manifest", "/v1/steps", "/v1/reducers", "/v1/attrs",
    "/v1/domains", "/v1/query", "/v1/series", "/v1/stats", "/metrics"})

PROMETHEUS_CTYPE = "text/plain; version=0.0.4; charset=utf-8"


def _make_handler(catalog: Catalog, compress: bool,
                  token: str | None = None,
                  obs: obs_metrics.MetricsRegistry | None = None,
                  engine: ServeEngine | None = None):
    #: step -> last seen manifest identity; a change means the context
    #: was rewritten (engine resubmission) and cached bytes are stale
    idents: dict[int, tuple[int, int]] = {}
    ident_lock = threading.Lock()

    obs = obs if obs is not None else obs_metrics.MetricsRegistry()
    m_requests = obs.counter(
        "catalog_requests_total", "HTTP requests by endpoint and status",
        labels=("endpoint", "status"))
    m_seconds = obs.histogram(
        "catalog_request_seconds", "request handling latency",
        labels=("endpoint",))
    m_bytes = obs.counter(
        "catalog_bytes_sent_total", "response body bytes by endpoint",
        labels=("endpoint",))
    m_304 = obs.counter(
        "catalog_etag_304_total",
        "ETag revalidations answered 304 (headers only, no payload)")

    def _stats_payload() -> dict:
        """/v1/stats body: cache counters + per-endpoint request stats."""
        info = catalog.cache_info()
        requests: dict[str, dict[str, int]] = {}
        for (endpoint, status), child in m_requests.children():
            requests.setdefault(endpoint, {})[status] = int(child.value)
        info["server"] = {
            "requests": requests,
            "etag_304": int(m_304.value),
            "bytes_sent": {ep: int(c.value)
                           for (ep,), c in m_bytes.children()},
            "request_seconds": {ep: _hist_digest(c)
                                for (ep,), c in m_seconds.children()},
        }
        if engine is not None:
            info["serve"] = engine.stats()
        return info

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"

        def log_message(self, fmt, *args):   # quiet by default
            pass

        # ------------------------------------------------------ responses
        def _send(self, code: int, body: bytes, ctype: str,
                  headers: dict | None = None) -> None:
            self._obs_status = code
            self._obs_bytes += len(body)
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            for k, v in (headers or {}).items():
                self.send_header(k, v)
            self.end_headers()
            self.wfile.write(body)

        def _json(self, obj, code: int = 200,
                  headers: dict | None = None) -> None:
            self._send(code, json.dumps(obj).encode(), "application/json",
                       headers)

        def _frame(self, arrays: dict, headers: dict | None = None) -> None:
            t0 = time.perf_counter()
            body = pack_frame(arrays, compress=compress)
            if engine is not None:
                engine.observe_stage("encode", time.perf_counter() - t0)
            t1 = time.perf_counter()
            self._send(200, body, "application/x-hx-frame", headers)
            if engine is not None:
                engine.observe_stage("write", time.perf_counter() - t1)

        def _stream_progressive(self, arrays: dict, tag: str) -> None:
            """Chunked coarse-first response: one hx-frame per chunk
            group, frame 0 = coarsest pyramid level + non-pyramidal
            arrays, later frames = refinement blocks (bit-exact once
            complete; see ``repro.insitu.serve.plan_progressive``)."""
            t0 = time.perf_counter()
            frames = plan_progressive(arrays)
            if engine is not None:
                engine.observe_stage("encode", time.perf_counter() - t0)
            self._obs_status = 200
            self.send_response(200)
            self.send_header("Content-Type", "application/x-hx-frame-stream")
            self.send_header("Transfer-Encoding", "chunked")
            self.send_header("ETag", tag)
            self.send_header("X-Progressive-Frames", str(len(frames)))
            self.end_headers()
            t1 = time.perf_counter()
            for fr in frames:
                data = pack_frame(fr, compress=False)
                self.wfile.write(b"%X\r\n" % len(data) + data + b"\r\n")
                self.wfile.flush()
                self._obs_bytes += len(data)
            self.wfile.write(b"0\r\n\r\n")
            if engine is not None:
                engine.observe_stage("write", time.perf_counter() - t1)

        def _client_token(self) -> str:
            """Fairness token: explicit client id, else the peer host."""
            return self.headers.get("X-Client-Id") \
                or self.client_address[0]

        # ----------------------------------------------------------- auth
        def _authorized(self) -> bool:
            if token is None:
                return True
            got = self.headers.get("Authorization", "")
            # constant-time compare: an attacker probing byte by byte
            # learns nothing from response timing
            return hmac.compare_digest(got.encode(),
                                       f"Bearer {token}".encode())

        # ----------------------------------------------------------- etag
        def _query_etag(self, step: int, reducer: str,
                        domain: int | None, region) -> str:
            """Validator for one reduced object.

            Contexts are immutable once finalized, so the manifest's
            identity (mtime + size) pins the object's bytes; the query
            key makes the tag vary per object/crop. A rewritten context
            (engine resubmission, rebuilt database) changes the
            manifest stat: the tag rotates *and* the server's cached
            bytes for that step are dropped first, so a fresh validator
            is never stamped onto stale LRU content.
            """
            st = os.stat(os.path.join(catalog.db._ctx_dir(step),
                                      "MANIFEST.json"))
            ident = (st.st_mtime_ns, st.st_size)
            with ident_lock:
                stale = idents.get(step, ident) != ident
                idents[step] = ident
            if stale:
                catalog.invalidate_step(step)
            key = (f"{st.st_mtime_ns}/{st.st_size}/{step}/{reducer}/"
                   f"{domain}/{region}")
            return '"' + hashlib.sha1(key.encode()).hexdigest() + '"'

        # --------------------------------------------------------- routes
        def do_GET(self):   # noqa: N802  (http.server API)
            url = urllib.parse.urlsplit(self.path)
            endpoint = url.path if url.path in _KNOWN_ENDPOINTS else "other"
            self._obs_status = 0      # 0 = aborted before any response
            self._obs_bytes = 0
            t0 = time.perf_counter()
            q = {k: v[-1] for k, v in
                 urllib.parse.parse_qs(url.query).items()}
            try:
                if not self._authorized():
                    self._json({"error": "unauthorized",
                                "message": "missing or bad bearer token"},
                               code=401,
                               headers={"WWW-Authenticate": "Bearer"})
                    return
                self._route(url.path, q)
            except ServeOverloaded as e:
                # 4xx, not 5xx: the server is healthy, the client must
                # back off (admission control, not failure)
                self._json({"error": "overloaded",
                            "message": str(e),
                            "retry_after": e.retry_after},
                           code=429,
                           headers={"Retry-After":
                                    f"{e.retry_after:.3f}"})
            except (KeyError, FileNotFoundError) as e:
                # a step with no manifest is as absent as an unknown
                # reducer: both surface as KeyError on the client
                self._json({"error": "not_found", "message": str(e)},
                           code=404)
            except (ValueError, TypeError) as e:
                self._json({"error": "bad_request", "message": str(e)},
                           code=400)
            except BrokenPipeError:      # viewer went away mid-response
                pass
            except Exception as e:      # noqa: BLE001
                self._json({"error": "internal", "message": repr(e)},
                           code=500)
            finally:
                if obs_metrics.ENABLED:
                    m_requests.labels(endpoint, self._obs_status or
                                      "aborted").inc()
                    m_seconds.labels(endpoint).observe(
                        time.perf_counter() - t0)
                    if self._obs_bytes:
                        m_bytes.labels(endpoint).inc(self._obs_bytes)

        @staticmethod
        def _param(q: dict, name: str) -> str:
            try:
                return q[name]
            except KeyError:
                # a client mistake, not an absent object: 400, not 404
                raise ValueError(
                    f"missing query parameter {name!r}") from None

        def _route(self, path: str, q: dict) -> None:
            if path == "/v1/manifest":
                steps = catalog.steps()
                self._json({"schema": "hx-catalog/1",
                            "kind": catalog.db.kind,
                            "steps": steps,
                            "latest": steps[-1] if steps else None})
            elif path == "/v1/steps":
                self._json(catalog.steps())
            elif path == "/v1/reducers":
                self._json(catalog.reducers(int(self._param(q, "step"))))
            elif path == "/v1/attrs":
                self._json(catalog.attrs(int(self._param(q, "step"))))
            elif path == "/v1/domains":
                self._json(catalog.domains(int(self._param(q, "step")),
                                           self._param(q, "reducer")))
            elif path == "/v1/stats":
                self._json(_stats_payload())
            elif path == "/metrics":
                # both registries: request-level (this handler's) and
                # the shared catalog's query/series latency families
                text = (obs.render_prometheus()
                        + catalog.obs.render_prometheus())
                self._send(200, text.encode(), PROMETHEUS_CTYPE)
            elif path == "/v1/query":
                domain = int(q["domain"]) if "domain" in q else None
                region = _parse_region(q["region"]) if "region" in q \
                    else None
                step = int(self._param(q, "step"))
                reducer = self._param(q, "reducer")
                tag = self._query_etag(step, reducer, domain,
                                       q.get("region"))
                inm = self.headers.get("If-None-Match")
                if inm is not None and tag in (
                        t.strip() for t in inm.split(",")):
                    # client already holds these exact bytes: headers
                    # only, no body (RFC 9110 §15.4.5) — revalidation
                    # never touches the serving queue
                    self._obs_status = 304
                    if obs_metrics.ENABLED:
                        m_304.inc()
                    self.send_response(304)
                    self.send_header("ETag", tag)
                    self.send_header("Content-Length", "0")
                    self.end_headers()
                    return
                if engine is not None:
                    arrays = engine.fetch(step, reducer, region=region,
                                          domain=domain,
                                          client=self._client_token())
                else:
                    arrays = catalog.query(step, reducer, region=region,
                                           domain=domain)
                if q.get("progressive") in ("1", "true", "yes"):
                    self._stream_progressive(arrays, tag)
                else:
                    self._frame(arrays, headers={"ETag": tag})
            elif path == "/v1/series":
                steps = [int(s) for s in q["steps"].split(",")] \
                    if "steps" in q else None
                out_steps, vals = catalog.series(self._param(q, "reducer"),
                                                 self._param(q, "name"),
                                                 steps=steps)
                frame = {"steps": np.asarray(out_steps, np.int64)}
                for i, v in enumerate(vals):
                    frame[f"value/{i}"] = v
                self._frame(frame)
            else:
                raise KeyError(f"no route {path!r}")

    return Handler


# ----------------------------------------------------------------- client

class CatalogBusy(RuntimeError):
    """The server's admission control answered 429 (back off and retry).

    ``retry_after`` carries the server's backoff hint in seconds.
    """

    def __init__(self, message: str, retry_after: float = 0.05):
        super().__init__(message)
        self.retry_after = float(retry_after)


class RemoteCatalog:
    """Viewer-side twin of :class:`Catalog` over a catalog server.

    ``query``/``series``/``domains`` (and the discovery surface) mirror
    the local catalog's signatures; merge-at-read happens server-side,
    so every viewer process shares the server's reduction cache.

    Queries keep a client-side ETag cache keyed on ``(step, reducer,
    region, domain)``: a revalidation that answers 304 costs one
    header-only round trip and **zero payload bytes** — the hot-viewer
    polling loop stops re-downloading unchanged reductions
    (``etag_hits``/``etag_misses``, :meth:`client_cache_info`).
    ``token`` adds ``Authorization: Bearer`` to every request; a 401
    surfaces as :class:`PermissionError`. A 429 from the server's
    admission control surfaces as :class:`CatalogBusy` — set
    ``busy_retries`` to have the client honor ``Retry-After`` and retry
    transparently. ``client_id`` names this viewer for the server's
    per-client fair queueing (defaults to one token per process).
    """

    def __init__(self, base_url: str, *, timeout: float = 30.0,
                 token: str | None = None, cache_entries: int = 32,
                 client_id: str | None = None, busy_retries: int = 0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.token = token
        self.cache_entries = cache_entries
        self.client_id = client_id if client_id is not None \
            else f"pid-{os.getpid()}"
        self.busy_retries = max(0, int(busy_retries))
        #: (step, reducer, domain, region) -> (etag, frozen arrays)
        self._etag_cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_lock = threading.Lock()
        self.etag_hits = 0
        self.etag_misses = 0

    # ------------------------------------------------------------- plumbing
    def _open(self, path: str, headers: dict | None = None, **params):
        """urlopen with auth + client-id headers; caller owns the body."""
        qs = urllib.parse.urlencode(
            {k: v for k, v in params.items() if v is not None})
        url = f"{self.base_url}{path}" + (f"?{qs}" if qs else "")
        req = urllib.request.Request(url, headers=dict(headers or {}))
        if self.token is not None:
            req.add_header("Authorization", f"Bearer {self.token}")
        req.add_header("X-Client-Id", self.client_id)
        return urllib.request.urlopen(req, timeout=self.timeout)

    @staticmethod
    def _raise_http(e: urllib.error.HTTPError):
        """Map an HTTP error to the local-catalog exception surface."""
        body = e.read()
        try:
            msg = json.loads(body.decode()).get("message", "")
        except Exception:
            msg = body.decode(errors="replace")
        if e.code == 404:
            raise KeyError(msg) from None
        if e.code == 401:
            raise PermissionError(
                f"catalog server refused the request: {msg}") from None
        if e.code == 429:
            try:
                after = float(e.headers.get("Retry-After", "0.05"))
            except ValueError:
                after = 0.05
            raise CatalogBusy(
                f"catalog server overloaded: {msg}",
                retry_after=after) from None
        raise RuntimeError(
            f"catalog server error {e.code}: {msg}") from None

    def _request(self, path: str, headers: dict | None = None,
                 **params) -> tuple[int, bytes, dict]:
        """One GET; returns (status, body, response headers).

        304 is a *result* here (ETag revalidation), not an error; 404
        maps to KeyError (local-catalog parity), 401 to PermissionError
        and 429 to :class:`CatalogBusy` — retried ``busy_retries``
        times, sleeping the server's ``Retry-After`` hint between
        attempts.
        """
        for attempt in range(self.busy_retries + 1):
            try:
                with self._open(path, headers, **params) as r:
                    return r.status, r.read(), dict(r.headers)
            except urllib.error.HTTPError as e:
                if e.code == 304:
                    e.read()
                    return 304, b"", dict(e.headers)
                try:
                    self._raise_http(e)
                except CatalogBusy as busy:
                    if attempt >= self.busy_retries:
                        raise
                    time.sleep(min(1.0, busy.retry_after))

    def _get(self, path: str, **params) -> bytes:
        return self._request(path, **params)[1]

    def _get_json(self, path: str, **params):
        return json.loads(self._get(path, **params).decode())

    def _get_frame(self, path: str, **params) -> dict[str, np.ndarray]:
        return unpack_frame(self._get(path, **params))

    # ------------------------------------------------------------ discovery
    def manifest(self) -> dict:
        return self._get_json("/v1/manifest")

    def steps(self) -> list[int]:
        return self._get_json("/v1/steps")

    def latest_step(self) -> int | None:
        return self.manifest()["latest"]

    def reducers(self, step: int) -> list[str]:
        return self._get_json("/v1/reducers", step=step)

    def attrs(self, step: int) -> dict:
        return self._get_json("/v1/attrs", step=step)

    def domains(self, step: int, reducer: str) -> list[int]:
        """Contributor domains holding parts of one reduced object."""
        return self._get_json("/v1/domains", step=step, reducer=reducer)

    def cache_info(self) -> dict:
        """The *server's* shared-cache counters (+ request telemetry)."""
        return self._get_json("/v1/stats")

    def metrics(self) -> str:
        """The server's Prometheus ``/metrics`` exposition text."""
        return self._get("/metrics").decode()

    def client_cache_info(self) -> dict:
        """This viewer's ETag-cache counters."""
        with self._cache_lock:
            return {"entries": len(self._etag_cache),
                    "etag_hits": self.etag_hits,
                    "etag_misses": self.etag_misses}

    # ---------------------------------------------------------------- query
    def query(self, step: int, reducer: str, *,
              region=None, domain: int | None = None
              ) -> dict[str, np.ndarray]:
        """Fetch one reduced object; ``domain=None`` merges server-side.

        Revalidates through the ETag cache: a 304 answer serves the
        cached arrays without transferring the payload again. Cached
        arrays are frozen (mutating callers take a ``.copy()``), like
        the local catalog's.
        """
        region = _normalize_region(region)
        key = (step, reducer, domain, region)
        with self._cache_lock:
            ent = self._etag_cache.get(key)
            if ent is not None:
                self._etag_cache.move_to_end(key)
        status, body, rh = self._request(
            "/v1/query",
            headers={"If-None-Match": ent[0]} if ent else None,
            step=step, reducer=reducer, domain=domain,
            region=_format_region(region) if region is not None else None)
        if status == 304:
            with self._cache_lock:
                self.etag_hits += 1
            return dict(ent[1])
        arrays = unpack_frame(body)
        for arr in arrays.values():
            arr.flags.writeable = False
        etag = {k.lower(): v for k, v in rh.items()}.get("etag")
        with self._cache_lock:
            self.etag_misses += 1
            if etag:
                self._etag_cache[key] = (etag, arrays)
                self._etag_cache.move_to_end(key)
                while len(self._etag_cache) > self.cache_entries:
                    self._etag_cache.popitem(last=False)
        return dict(arrays)

    def query_progressive(self, step: int, reducer: str, *,
                          region=None, domain: int | None = None):
        """Iterate coarse-to-fine reconstructions of one reduced object.

        Yields a ``{name: array}`` dict after every received frame: the
        first arrives after one coarse chunk (the ``fpdelta-pyramid``
        root level upsampled to full shape), later ones refine, and the
        final yield is **bit-exact** with :meth:`query` — the pyramid
        codec is lossless. Bypasses the ETag cache (the stream is the
        transfer-avoidance mechanism here).
        """
        region = _normalize_region(region)
        try:
            resp = self._open(
                "/v1/query", step=step, reducer=reducer, domain=domain,
                region=_format_region(region) if region is not None
                else None, progressive=1)
        except urllib.error.HTTPError as e:
            self._raise_http(e)
        asm = ProgressiveAssembler()
        with resp:
            while not asm.done:
                yield asm.feed(unpack_frame(_read_wire_frame(resp)))

    def series(self, reducer: str, name: str, *,
               steps: list[int] | None = None) -> tuple[np.ndarray, list]:
        """(steps, values) time series of one array across contexts."""
        frame = self._get_frame(
            "/v1/series", reducer=reducer, name=name,
            steps=",".join(str(s) for s in steps) if steps else None)
        out_steps = frame.pop("steps")
        vals = [frame[f"value/{i}"] for i in range(len(frame))]
        return out_steps, vals


def open_catalog(target: str, **kw):
    """``http(s)://...`` -> :class:`RemoteCatalog`, else a local Catalog."""
    if str(target).startswith(("http://", "https://")):
        return RemoteCatalog(str(target), **kw)
    return Catalog(target, **kw)


__all__ = ["CatalogServer", "RemoteCatalog", "CatalogBusy",
           "open_catalog", "pack_frame", "unpack_frame", "FRAME_SCHEMA"]
