"""Sharded multi-device reduction: shard_map'd rasters + on-device merge.

The PR 5 device path (``insitu.device``) funnels the whole reduction
DAG through one device — the paper's single-funnel bottleneck one layer
down. This module partitions each snapshot's *leaf table* over a JAX
device mesh with the same Hilbert split the multi-domain writer uses
(``partition.leaf_shards``), runs the Pallas raster kernels under
``shard_map`` so every device rasterizes only its own leaf shard into a
partial image, and merges the partials **on device** with the exact
semantics of the read-side merge strategies (``hercule.api``):

  ===========  ======================  ==================================
  reducer      read-side strategy      on-device merge
  ===========  ======================  ==================================
  slice        ``tile`` (paint)        depth-resolve: deepest leaf wins,
                                       lowest shard on ties — a ppermute
                                       XOR-butterfly tree over pow2
                                       meshes, all_gather + argmax else
  projection   ``sum`` (ascending)     all_gather + static ascending
                                       fold — the same float adds in the
                                       same order as ``_merge_sum``
  level-hist   ``hist`` (int sum)      ``psum`` (integer counts are
                                       order-free, so the psum tree is
                                       exact)
  ===========  ======================  ==================================

No full snapshot or full leaf table ever materializes on one device:
each device holds its own ~1/S of the leaf rows (padded to the common
bucket) plus one partial image; :class:`MeshRunStats` accounts for both
(``peak_leaf_frac``, ``peak_device_table_bytes``,
``peak_device_partial_bytes``) next to the inherited device→host byte
counters.

Bit-parity contract (``tests/test_mesh_reduce.py``): per-shard rows are
the global BFS-ordered leaves of one Hilbert segment — exactly the
leaves the multi-domain writer assigns to domain ``g`` — so shard
partials are bitwise the per-domain host outputs, and the merged images
are bit-identical to the host reducers for the default float64 tables
(slice requires ``resolution >= 2**max_level``, where leaf footprints
are disjoint and painting is collision-free; the read-side tile merge
has the same contract). ``dtype="float32"`` halves the table uploads
and trades bit-parity for tolerance parity (DESIGN.md §18: slice rtol
1e-6, projection rtol 1e-4, histograms exact *for the cast values*).

Leaf tables larger than the per-shard padded budget (``tile_n``) switch
to the tiled-gather formulation (``ops`` ``tile_n=``): the shard's rows
stream through carry-seeded kernels in BFS-order chunks, bounding the
gathered working set without changing a single output bit.

Develop/CI-test on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must
be set before jax initializes a backend — the tests and the bench spawn
subprocess children). Select with ``InTransitEngine(device_reduce="mesh")``
/ ``launch/insitu.py --device-mesh N`` / the trainer's
``insitu_device_mesh``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .device import DeviceDAGRunner, DeviceRunStats, _padded, _pow2
from .partition import leaf_shards
from .reducers import (LevelHistogramReducer, LODCutReducer,
                       ProjectionReducer, ReducerDAG, SliceReducer)
from .staging import Snapshot

__all__ = ["MeshDAGRunner", "MeshRunStats", "MeshTable",
           "register_mesh_impl", "mesh_impl_for", "MESH_AXIS", "MESH_TILE"]

#: mesh axis name the shard_map bodies reduce over
MESH_AXIS = "shard"

#: per-shard padded row budget before the tiled-gather formulation kicks
#: in (multiple of the kernels' lane block)
MESH_TILE = 16384


# ----------------------------------------------------------- leaf tables

class MeshTable:
    """Per-snapshot sharded leaf table (the mesh twin of ``DeviceTree``).

    Built host-side from the staged (host-resident) BFS tree arrays:
    owned leaves are split into Hilbert-contiguous shards
    (:func:`partition.leaf_shards`), each shard's rows keep ascending
    BFS order, every shard is padded to the common bucket multiple, and
    the stacked ``(S, P, ...)`` arrays are uploaded once under a
    ``NamedSharding`` so device ``g`` receives only shard ``g``'s rows.
    Fields upload lazily per reducer; ``dtype`` casts them at table
    build (the f32 variant halves the upload).
    """

    def __init__(self, arrays: dict, n_domains: int, mesh: Mesh, *,
                 backend: str | None = None, dtype=None,
                 tile_n: int = MESH_TILE, on_upload=None):
        self.arrays = arrays
        self.mesh = mesh
        self.backend = backend
        self.dtype = None if dtype is None else np.dtype(dtype)
        self.tile_n = tile_n
        self.on_upload = on_upload or (lambda nbytes: None)
        self.n_shards = int(mesh.devices.size)
        self._offsets = np.asarray(arrays["level_offsets"])
        self.n_levels = int(self._offsets.shape[0]) - 1
        leaves = np.flatnonzero(~np.asarray(arrays["refine"]))
        shard = leaf_shards(arrays, self.n_shards)
        if n_domains > 1:            # partitioned: owned leaves count once
            owned = np.asarray(arrays["owner"])[leaves]
            leaves, shard = leaves[owned], shard[owned]
        self._rows = [leaves[shard == g] for g in range(self.n_shards)]
        counts = [int(r.shape[0]) for r in self._rows]
        self.total_rows = int(leaves.shape[0])
        self.peak_rows = max(counts) if counts else 0
        self.rows_padded = _padded(max(self.peak_rows, 1))
        self._geom = None
        self._fields: dict = {}

    @property
    def leaf_frac(self) -> float:
        """Largest per-device share of the (unpadded) leaf rows."""
        return self.peak_rows / max(self.total_rows, 1)

    def _stack(self, per_row, dtype, fill, trailing=()):
        out = np.full((self.n_shards, self.rows_padded, *trailing), fill,
                      dtype)
        for g, rows in enumerate(self._rows):
            out[g, :rows.shape[0]] = per_row(rows)
        return out

    def _shard(self, host: np.ndarray):
        spec = PartitionSpec(MESH_AXIS, *([None] * (host.ndim - 1)))
        arr = jax.device_put(host, NamedSharding(self.mesh, spec))
        arr.block_until_ready()
        self.on_upload(arr.nbytes)
        return arr

    def _prep(self):
        if self._geom is None:
            coords = np.asarray(self.arrays["coords"]).astype(np.int32)
            self._geom = (
                self._shard(self._stack(lambda rows: coords[rows],
                                        np.int32, 0, trailing=(3,))),
                self._shard(self._stack(
                    lambda rows: np.searchsorted(
                        self._offsets, rows, side="right").astype(np.int32)
                    - 1, np.int32, 0)),
                self._shard(self._stack(lambda rows: True, bool, False)))
        return self._geom

    @property
    def coords(self):
        return self._prep()[0]

    @property
    def levels(self):
        return self._prep()[1]

    @property
    def ok(self):
        """Valid-row mask: padding rows carry ``ok=False``."""
        return self._prep()[2]

    def field(self, name: str):
        if name not in self._fields:
            v = np.asarray(self.arrays[f"field:{name}"])
            if self.dtype is not None:
                v = v.astype(self.dtype)
            self._fields[name] = self._shard(
                self._stack(lambda rows: v[rows], v.dtype, 0))
        return self._fields[name]

    def field_bounds(self, name: str) -> tuple[float, float]:
        """Host-side min/max over the owned leaf values.

        min/max are order-free, so this is bitwise the host reducer's
        auto bounds — and it costs no device pull at all (the staged
        arrays are host-resident on the mesh path, vs. the single-device
        path's fused-reduction 16-byte sync). f32 tables bound the
        *cast* values so the edges match what the kernel bins.
        """
        v = np.asarray(self.arrays[f"field:{name}"])
        if self.dtype is not None:
            v = v.astype(self.dtype)
        vals = [v[rows] for rows in self._rows if rows.size]
        if not vals:
            return 0.0, 1.0
        allv = np.concatenate(vals)
        return float(allv.min()), float(allv.max())


# ------------------------------------------------------ on-device merges

def _depth_resolve(img, depth, n_shards: int):
    """Slice merge: deepest leaf wins; equal depth → lowest shard.

    For power-of-two meshes this is a ppermute XOR-butterfly — after
    ``log2(S)`` exchange stages every device holds the global winner,
    because the (depth, -shard) lexicographic max is associative and
    commutative. Other mesh sizes take one all_gather + ``argmax``
    (which returns the *first* maximum, i.e. the lowest shard). Ties can
    only occur below the collision-free resolution bound; at or above it
    the two forms are identical pixel for pixel.
    """
    if n_shards == 1:
        return img, depth
    if _pow2(n_shards):
        src = jnp.full(img.shape, jax.lax.axis_index(MESH_AXIS), jnp.int32)
        m = 1
        while m < n_shards:
            perm = [(i, i ^ m) for i in range(n_shards)]
            img_p = jax.lax.ppermute(img, MESH_AXIS, perm)
            depth_p = jax.lax.ppermute(depth, MESH_AXIS, perm)
            src_p = jax.lax.ppermute(src, MESH_AXIS, perm)
            take = (depth_p > depth) | ((depth_p == depth) & (src_p < src))
            img = jnp.where(take, img_p, img)
            depth = jnp.where(take, depth_p, depth)
            src = jnp.where(take, src_p, src)
            m <<= 1
        return img, depth
    d_all = jax.lax.all_gather(depth, MESH_AXIS)        # (S, R, R)
    i_all = jax.lax.all_gather(img, MESH_AXIS)
    win = jnp.argmax(d_all, axis=0)[None]
    return (jnp.take_along_axis(i_all, win, 0)[0],
            jnp.take_along_axis(d_all, win, 0)[0])


def _ordered_sum(img, n_shards: int):
    """Projection merge: the read-side ``_merge_sum`` ascending fold.

    A float ``psum`` sums in whatever order the lowering picks, not the
    merge registry's — so gather the S partials and fold them in static
    ascending shard order instead: every float add happens in the same
    sequence as the host merge (bit-identical), and the gather, not the
    unrolled fold, is the O(S·R²) cost.
    """
    if n_shards == 1:
        return img
    parts = jax.lax.all_gather(img, MESH_AXIS)          # (S, R, R)
    acc = parts[0]
    for i in range(1, n_shards):
        acc = acc + parts[i]
    return acc


# ------------------------------------------------- shard_map'd reductions

_TBL = (PartitionSpec(MESH_AXIS),) * 4


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis", "position", "resolution", "n_levels", "backend",
    "tile_n"))
def _mesh_slice(coords, levels, ok, values, *, mesh: Mesh, axis: int,
                position: float, resolution: int, n_levels: int,
                backend: str | None, tile_n: int):
    from ..kernels import ops

    def body(c, lv, okk, val):
        img, depth = ops.raster_slice_partial(
            c[0], lv[0], val[0], okk[0], axis=axis, position=position,
            resolution=resolution, n_levels=n_levels, backend=backend,
            tile_n=tile_n)
        img, _ = _depth_resolve(img, depth, int(mesh.devices.size))
        return img

    # check_rep=False: the butterfly's ppermute is not *provably*
    # replicated to the rep checker, though every device holds the same
    # winner after the last stage
    f = shard_map(body, mesh=mesh, in_specs=_TBL,
                  out_specs=PartitionSpec(), check_rep=False)
    return f(coords, levels, ok, values)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "axis", "resolution", "n_levels", "backend", "tile_n"))
def _mesh_projection(coords, levels, ok, values, *, mesh: Mesh, axis: int,
                     resolution: int, n_levels: int, backend: str | None,
                     tile_n: int):
    from ..kernels import ops

    def body(c, lv, okk, val):
        img = ops.raster_projection_partial(
            c[0], lv[0], val[0], okk[0], axis=axis, resolution=resolution,
            n_levels=n_levels, backend=backend, tile_n=tile_n)
        return _ordered_sum(img, int(mesh.devices.size))

    f = shard_map(body, mesh=mesh, in_specs=_TBL,
                  out_specs=PartitionSpec(), check_rep=False)
    return f(coords, levels, ok, values)


@functools.partial(jax.jit, static_argnames=("mesh", "n_levels", "backend"))
def _mesh_hist(values, levels, ok, edges, *, mesh: Mesh, n_levels: int,
               backend: str | None):
    from ..kernels import ops

    def body(val, lv, okk, e):
        hist = ops.raster_level_hist_partial(
            val[0], lv[0], okk[0], e, n_levels=n_levels, backend=backend)
        return jax.lax.psum(hist, MESH_AXIS)

    f = shard_map(body, mesh=mesh,
                  in_specs=(*_TBL[:3], PartitionSpec()),
                  out_specs=PartitionSpec(), check_rep=False)
    return f(values, levels, ok, edges).astype(jnp.int64)


# ----------------------------------------------------- impl registry

#: reducer class -> factory(reducer) -> impl(MeshTable) -> dict | None
MESH_IMPLS: dict[type, object] = {}


def register_mesh_impl(reducer_cls: type):
    """Register (or replace) the mesh factory for one reducer class.

    Mirrors :func:`device.register_device_impl`: the factory receives
    the reducer *instance* and returns ``impl(mesh_table) -> dict`` or
    ``None`` when this configuration must fall back to the host path.
    """
    def deco(factory):
        MESH_IMPLS[reducer_cls] = factory
        return factory
    return deco


def mesh_impl_for(reducer):
    """Resolve one reducer instance to its mesh impl (or None)."""
    factory = MESH_IMPLS.get(type(reducer))
    return factory(reducer) if factory is not None else None


@register_mesh_impl(SliceReducer)
def _slice_mesh(r: SliceReducer):
    if r.source is not None or not _pow2(r.resolution):
        return None

    def run(mt: MeshTable):
        img = _mesh_slice(mt.coords, mt.levels, mt.ok, mt.field(r.field),
                          mesh=mt.mesh, axis=r.axis, position=r.position,
                          resolution=r.resolution, n_levels=mt.n_levels,
                          backend=mt.backend, tile_n=mt.tile_n)
        return {"image": img}
    return run


@register_mesh_impl(ProjectionReducer)
def _projection_mesh(r: ProjectionReducer):
    if r.source is not None or not _pow2(r.resolution):
        return None

    def run(mt: MeshTable):
        img = _mesh_projection(mt.coords, mt.levels, mt.ok,
                               mt.field(r.field), mesh=mt.mesh, axis=r.axis,
                               resolution=r.resolution,
                               n_levels=mt.n_levels, backend=mt.backend,
                               tile_n=mt.tile_n)
        return {"image": img}
    return run


@register_mesh_impl(LODCutReducer)
def _lod_mesh(r: LODCutReducer):
    """LOD cut on the mesh path: a pure-numpy BFS prefix slice.

    Mesh snapshots stage on host, so the cut never needs a device at
    all — it is the same prefix-slice + deepest-level demotion identity
    the device impl uses (``device._lod_impl``), on the host arrays.
    Registered so the default CLI DAG reports zero fallbacks on the
    mesh path too.
    """
    def run(mt: MeshTable):
        offs = np.asarray(mt.arrays["level_offsets"]).astype(np.int64)
        if len(offs) - 1 <= r.max_level + 1:
            return {k: np.asarray(v) for k, v in mt.arrays.items()}
        n_keep = int(offs[r.max_level + 1])
        new_offs = offs[:r.max_level + 2].copy()
        # trim now-empty deepest levels, exactly like subset_tree
        n_lv = len(new_offs) - 1
        while n_lv > 1 and new_offs[n_lv] == new_offs[n_lv - 1]:
            n_lv -= 1
        refine = np.array(np.asarray(mt.arrays["refine"])[:n_keep])
        refine[int(offs[r.max_level]):n_keep] = False
        out = {"refine": refine, "level_offsets": new_offs[:n_lv + 1]}
        for k, v in mt.arrays.items():
            if k not in out and k != "level_offsets":
                out[k] = np.asarray(v)[:n_keep]
        return out
    return run


@register_mesh_impl(LevelHistogramReducer)
def _hist_mesh(r: LevelHistogramReducer):
    def run(mt: MeshTable):
        if r.lo is None or r.hi is None:
            lo, hi = mt.field_bounds(r.field)
            lo = lo if r.lo is None else r.lo
            hi = hi if r.hi is None else r.hi
        else:
            lo, hi = r.lo, r.hi
        if hi <= lo:
            hi = lo + 1.0
        edges = np.linspace(lo, hi, r.bins + 1)
        hist = _mesh_hist(mt.field(r.field), mt.levels, mt.ok,
                          jnp.asarray(edges), mesh=mt.mesh,
                          n_levels=min(mt.n_levels, r.max_levels),
                          backend=mt.backend)
        return {"hist": hist, "edges": edges}
    return run


# ------------------------------------------------------------ runner

class MeshRunStats(DeviceRunStats):
    """Transfer + residency accounting for the mesh path.

    Extends the device counters with the proof obligations of the
    sharded layout: the largest per-device share of the leaf rows
    (``peak_leaf_frac``, ≈ 1/S for a balanced Hilbert split), the
    per-device table upload and the per-device partial-image footprint.
    """

    def __init__(self):
        super().__init__()
        self.mesh_devices = 0
        self.leaf_rows = 0                    # cumulative sharded rows
        self.peak_leaf_frac = 0.0             # max per-device row share
        self.bytes_tables_to_device = 0       # total sharded uploads
        self.peak_device_table_bytes = 0      # one shard's padded rows
        self.peak_device_partial_bytes = 0    # one partial image / hist

    def as_dict(self) -> dict:
        d = super().as_dict()
        d.update(mesh_devices=self.mesh_devices,
                 leaf_rows=self.leaf_rows,
                 peak_leaf_frac=self.peak_leaf_frac,
                 bytes_tables_to_device=self.bytes_tables_to_device,
                 peak_device_table_bytes=self.peak_device_table_bytes,
                 peak_device_partial_bytes=self.peak_device_partial_bytes)
        return d


class MeshDAGRunner(DeviceDAGRunner):
    """DeviceDAGRunner whose impls shard every snapshot over a mesh.

    Drop-in third path for the engine (``device_reduce="mesh"``): same
    DAG order, per-reducer fallback and output contract as the
    single-device runner — but snapshots stage on *host*, the leaf
    table is Hilbert-sharded over the first ``devices`` jax devices and
    reduced under ``shard_map``, and host fallbacks cost no device
    traffic (the staged arrays never left the host).
    ``dtype="float32"`` selects the tolerance-parity table variant.
    """

    def __init__(self, dag: ReducerDAG, *, devices: int | None = None,
                 backend: str | None = None, dtype: str | None = None,
                 tile_n: int = MESH_TILE):
        avail = jax.devices()
        n = len(avail) if devices in (None, 0) else int(devices)
        if not 1 <= n <= len(avail):
            raise ValueError(
                f"device mesh of {n} requested but only {len(avail)} jax "
                f"device(s) available (forcing host devices needs "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N set "
                f"before jax initializes)")
        self.mesh = Mesh(np.asarray(avail[:n]), (MESH_AXIS,))
        self.dtype = dtype
        self.tile_n = tile_n
        super().__init__(dag, backend=backend)
        self.impls = {r.name: mesh_impl_for(r) for r in dag}
        self.stats = MeshRunStats()
        self.stats.mesh_devices = n

    def _note_upload(self, nbytes: int) -> None:
        with self._lock:
            self.stats.bytes_tables_to_device += nbytes
            per_dev = nbytes // max(self.stats.mesh_devices, 1)
            self.stats.peak_device_table_bytes = max(
                self.stats.peak_device_table_bytes, per_dev)

    def _make_view(self, snap: Snapshot):
        mt = MeshTable(snap.arrays, snap.n_domains, self.mesh,
                       backend=self.backend, dtype=self.dtype,
                       tile_n=self.tile_n, on_upload=self._note_upload)
        with self._lock:
            self.stats.leaf_rows += mt.total_rows
            self.stats.peak_leaf_frac = max(self.stats.peak_leaf_frac,
                                            mt.leaf_frac)
        return mt

    def run(self, snap: Snapshot):
        outputs = super().run(snap)
        # every device holds one replicated copy of each reduced object
        # while its merge runs; the largest single output bounds the
        # per-device partial footprint
        peak = 0
        for out in outputs.values():
            peak = max(peak, sum(np.asarray(v).nbytes
                                 for v in out.values()))
        with self._lock:
            self.stats.peak_device_partial_bytes = max(
                self.stats.peak_device_partial_bytes, peak)
        return outputs
