"""Contributor-group partitioning of staged snapshots.

The paper's scalability comes from every MPI process writing *its own*
domains and post-processing reassembling them lazily. One in-process
engine has no MPI ranks, so this module manufactures the same shape:
a staged snapshot is split into ``n_groups`` contributor parts, each
reduced by its own worker lane and written as its own Hercule domain
(merged back at read — see ``hercule.api.ReducedKind``).

Two snapshot kinds partition differently:

  * ``amr``      — leaves are assigned to groups contiguously along the
    Hilbert curve (the same :func:`repro.core.decompose.assign_domains`
    split the writer uses for real domains), then each group gets the
    closed subtree of its owned leaves: ancestors, full sibling octets,
    and demoted ``force_leaf`` nodes where a branch leaves the group.
    ``owner`` flags mark which leaves the group actually owns, so
    owner-aware reducers contribute each global leaf exactly once and
    per-group outputs tile/sum back to the global answer.
  * ``tensors``  — named arrays are striped over groups in sorted-name
    order (each tensor is reduced by exactly one group; merged objects
    concatenate and re-sort by name).
"""
from __future__ import annotations

import numpy as np

from ..core import decompose
from ..core.amr import AMRTree, subset_tree

__all__ = ["partition_snapshot", "partition_tree", "partition_named",
           "leaf_shards"]


def leaf_shards(arrays: dict[str, np.ndarray], n_shards: int) -> np.ndarray:
    """Per-leaf shard id, Hilbert-contiguous — the mesh path's split.

    Returns an ``(n_leaves,)`` int array aligned with
    ``np.flatnonzero(~refine)`` (BFS leaf order). Shard ``g``'s leaves
    are the same set the multi-domain writer would assign to domain
    ``g`` (:func:`repro.core.decompose.assign_domains`), so per-shard
    partial reductions are bitwise the per-domain host outputs and the
    on-device merge can mirror the read-side merge strategies exactly.
    """
    tree = AMRTree.from_arrays(arrays)
    if n_shards <= 1:
        return np.zeros(int((~tree.refine).sum()), np.int64)
    return np.asarray(decompose.assign_domains(tree, n_shards),
                      np.int64)


def _group_tree(tree: AMRTree, leaf_domain: np.ndarray, group: int,
                parent: np.ndarray, cs: np.ndarray) -> AMRTree:
    """Closed subtree of one group's owned leaves (no ghosts, no coarse view).

    Same closure rules as :func:`repro.core.decompose.local_tree` minus the
    ghost halo and the degraded global coarse view: ancestors of owned
    leaves are kept, kept refined nodes keep all eight sons, and kept
    refined nodes whose sons all fall outside the group are demoted to
    leaves (they already carry the intensive restriction of their sons).
    """
    owner = decompose.subtree_ownership(tree, leaf_domain, group)
    keep = np.zeros(tree.n_nodes, bool)
    leaves = np.flatnonzero(~tree.refine)
    keep[leaves[leaf_domain == group]] = True

    # ancestor closure, bottom-up
    for l in range(tree.n_levels - 1, 0, -1):
        sl = tree.level_slice(l)
        kept = np.flatnonzero(keep[sl]) + sl.start
        keep[parent[kept]] = True

    # sibling closure + demote refined nodes whose branch leaves the group
    force_leaf = []
    for l in range(tree.n_levels - 1):
        sl = tree.level_slice(l)
        idx = np.flatnonzero(tree.refine[sl] & keep[sl]) + sl.start
        if idx.size == 0:
            continue
        kids = cs[idx][:, None] + np.arange(8)[None, :]
        any_kid = keep[kids].any(axis=1)
        keep[kids[any_kid].ravel()] = True
        force_leaf.append(idx[~any_kid])
    force = np.concatenate(force_leaf) if force_leaf \
        else np.zeros(0, np.int64)

    base = AMRTree(refine=tree.refine, owner=owner,
                   level_offsets=tree.level_offsets, coords=tree.coords,
                   fields=tree.fields)
    return subset_tree(base, keep, force_leaf=force)


def partition_tree(arrays: dict[str, np.ndarray], n_groups: int
                   ) -> list[dict[str, np.ndarray]]:
    """Split tree arrays into ``n_groups`` closed contributor subtrees."""
    tree = AMRTree.from_arrays(arrays)
    leaf_domain = decompose.assign_domains(tree, n_groups)
    parent, cs = tree.parent(), tree.child_start()
    return [_group_tree(tree, leaf_domain, g, parent, cs).to_arrays()
            for g in range(n_groups)]


def partition_named(arrays: dict[str, np.ndarray], n_groups: int
                    ) -> list[dict[str, np.ndarray]]:
    """Stripe named arrays over groups in sorted-name order."""
    names = sorted(arrays)
    return [{n: arrays[n] for n in names[g::n_groups]}
            for g in range(n_groups)]


def partition_snapshot(arrays: dict[str, np.ndarray], kind: str,
                       n_groups: int) -> list[dict[str, np.ndarray]]:
    """Split one staged payload into per-contributor-group payloads.

    ``n_groups == 1`` is the degenerate identity (no copies, no closure
    work) so a single-group engine behaves bit-for-bit like the
    single-writer one.
    """
    if n_groups <= 1:
        return [arrays]
    if kind == "amr":
        try:
            return partition_tree(arrays, n_groups)
        except KeyError as e:
            raise ValueError(
                "multi-domain in-transit reduction needs complete AMR tree "
                f"arrays (AMRTree.to_arrays schema); missing {e}") from None
    if kind == "tensors":
        return partition_named(arrays, n_groups)
    raise ValueError(
        f"cannot partition snapshot kind {kind!r} over contributor groups; "
        "supported kinds: 'amr', 'tensors'")
