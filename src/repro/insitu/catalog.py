"""Query API over reduced HDep objects, with an LRU reduction cache.

The paper's many-concurrent-viewers scenario: dashboards and viewers ask
for the same few reductions over and over. The catalog keys an LRU cache
on ``(step, reducer, region)`` so repeated queries are served from memory
— the database files are only touched on a miss (observable via
:attr:`io_reads` / :attr:`cache_hits`).

A *region* is an optional tuple of ``(lo, hi)`` pairs cropping the
leading axes of every array in the reduced object (e.g. a zoomed window
of a slice image). Cropping happens on the cached full object, so a
window query after a full query is also a cache hit.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

from ..hercule import api
from ..hercule.database import HerculeDB
from ..obs import metrics as obs_metrics

Region = tuple[tuple[int, int], ...]


def _normalize_region(region) -> Region | None:
    if region is None:
        return None
    return tuple((int(lo), int(hi)) for lo, hi in region)


def _crop(arrays: dict[str, np.ndarray], region: Region
          ) -> dict[str, np.ndarray]:
    out = {}
    for name, arr in arrays.items():
        if arr.ndim >= len(region):
            sl = tuple(slice(lo, hi) for lo, hi in region)
            out[name] = arr[sl]
        else:
            out[name] = arr
    return out


def _hist_digest(h) -> dict:
    """Compact JSON-able digest of one histogram (no NaN quantiles)."""
    _, total, n = h.merged()
    out = {"count": n, "sum": total}
    if n:
        out.update(h.quantiles())
    return out


class Catalog:
    """Read-side view of an in-transit HDep database."""

    def __init__(self, root: str | HerculeDB, *, cache_entries: int = 64):
        self.db = root if isinstance(root, HerculeDB) else \
            HerculeDB.open(root)
        self.cache_entries = cache_entries
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.io_reads = 0      # records decoded from the database files
        self.cache_hits = 0
        self.cache_misses = 0
        #: private registry: two catalogs in one process never collide
        self.obs = obs_metrics.MetricsRegistry()
        self._h_query = self.obs.histogram(
            "catalog_query_seconds",
            "query() latency split by cache outcome", labels=("result",))
        self._h_series = self.obs.histogram(
            "catalog_series_seconds", "series() end-to-end latency")

    # ------------------------------------------------------------ discovery
    def steps(self) -> list[int]:
        return self.db.contexts()

    def latest_step(self) -> int | None:
        return self.db.latest_context()

    def reducers(self, step: int) -> list[str]:
        return api.REDUCED.reducers_in(self.db.view(step))

    def attrs(self, step: int) -> dict:
        return self.db.view(step).attrs

    def scan(self, selector: api.Selector | None = None, **kw):
        """Iterate matching reduced records (see :func:`hercule.api.scan`).

        Defaults to the ``reduced`` kind; pass an explicit selector to
        widen. Yields :class:`~repro.hercule.api.RecordRef`.
        """
        if selector is None and "kinds" not in kw:
            kw["kinds"] = "reduced"
        return api.scan(self.db, selector, **kw)

    def domains(self, step: int, reducer: str) -> list[int]:
        """Contributor domains holding parts of one reduced object."""
        return api.REDUCED.domains_in(self.db.view(step), reducer)

    # ---------------------------------------------------------------- query
    def query(self, step: int, reducer: str, *,
              region=None, domain: int | None = None
              ) -> dict[str, np.ndarray]:
        """Fetch one reduced object, optionally cropped to ``region``.

        ``domain=None`` (the default) transparently merges the object
        across every contributing domain using the reducer's registered
        merge strategy — on a single-domain database this is bit-for-bit
        the plain read. Pass a concrete domain for one group's part.

        Contexts are immutable once finalized, so cached entries never go
        stale. The full (merged) object is what gets cached; region crops
        are views of the cached arrays.
        """
        t0 = time.perf_counter() if obs_metrics.ENABLED else 0.0
        region = _normalize_region(region)
        key = (step, reducer, domain)
        with self._lock:
            full = self._cache.get(key)
            if full is not None:
                self._cache.move_to_end(key)
                self.cache_hits += 1
        hit = full is not None
        if full is None:
            full = api.read_object(self.db, step, "reduced", domain,
                                   reducer=reducer)
            for arr in full.values():
                # cached arrays are shared across viewers: freeze them so
                # an in-place edit can't poison later queries (mutating
                # callers take an explicit .copy())
                arr.flags.writeable = False
            with self._lock:
                self.cache_misses += 1
                self.io_reads += len(full)
                self._cache[key] = full
                self._cache.move_to_end(key)
                while len(self._cache) > self.cache_entries:
                    self._cache.popitem(last=False)
        if obs_metrics.ENABLED:
            self._h_query.labels("hit" if hit else "miss").observe(
                time.perf_counter() - t0)
        if region is None:
            return dict(full)
        return _crop(full, region)

    def peek(self, step: int, reducer: str, domain: int | None = None
             ) -> bool:
        """True when the full object is already in the LRU cache.

        A cache probe, not a fetch: the serving engine
        (:mod:`repro.insitu.serve`) uses it to let cached objects bypass
        admission control — a hot viewer polling an object the server
        already holds must not be 429'd just because the *backend read*
        queue is saturated. Does not touch hit/miss counters or LRU
        order.
        """
        with self._lock:
            return (step, reducer, domain) in self._cache

    def series(self, reducer: str, name: str, *,
               steps: list[int] | None = None) -> tuple[np.ndarray, list]:
        """(steps, values) time series of one array across contexts.

        A Selector scan finds the contexts actually holding the record
        (index lookups, no decoding); values are then served through the
        cached (domain-merged) :meth:`query` path — a context whose
        record lives in several contributor domains appears once.
        ``reducer``/``name`` are compared as exact strings — glob
        characters in them are literal.
        """
        t0 = time.perf_counter() if obs_metrics.ENABLED else 0.0
        target = f"reduced/{reducer}/{name}"
        sel = api.Selector(steps=steps, kinds="reduced")
        out_steps, vals = [], []
        for ref in api.scan(self.db, sel):
            if ref.record.name != target or ref.step in out_steps[-1:]:
                continue
            out_steps.append(ref.step)
            vals.append(self.query(ref.step, reducer)[name])
        if obs_metrics.ENABLED:
            self._h_series.observe(time.perf_counter() - t0)
        return np.asarray(out_steps, np.int64), vals

    # ----------------------------------------------------------------- admin
    def invalidate_step(self, step: int) -> None:
        """Drop every cached object of one step (rewritten context).

        Contexts are normally immutable, which is the cache's premise —
        but the engine's resubmission path *can* rewrite a step's
        manifest. The catalog server calls this when the manifest
        identity changes, so a fresh ETag never gets stamped onto stale
        cached bytes.
        """
        with self._lock:
            for key in [k for k in self._cache if k[0] == step]:
                del self._cache[key]
        self.db._invalidate_view(step)

    def cache_info(self) -> dict:
        """Cache counters plus a compact query/series latency summary.

        The four counter keys are stable API; ``timing`` carries
        histogram digests (count/sum + interpolated quantiles, NaN-free
        so the dict JSON-serializes strictly).
        """
        with self._lock:
            info = {"entries": len(self._cache), "hits": self.cache_hits,
                    "misses": self.cache_misses, "io_reads": self.io_reads}
        info["timing"] = {
            "query_hit": _hist_digest(self._h_query.labels("hit")),
            "query_miss": _hist_digest(self._h_query.labels("miss")),
            "series": _hist_digest(self._h_series),
        }
        return info

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()

    def close(self) -> None:
        """Release the cache and the underlying database handles."""
        self.clear_cache()
        self.db.close()
