"""Continuous-batching serving core between the catalog server and catalog.

The paper's HDep format exists so that *many concurrent analysis
consumers* can be fed cheaply — but a thread-per-request HTTP front end
over :class:`~repro.insitu.catalog.Catalog` pays one full decode+merge
per request on a cache miss: 100 identical viewers cost 100x one viewer.
This module is the JetStream-style engine shape (ROADMAP direction 2)
that fixes the serving story; :class:`~repro.insitu.server.CatalogServer`
routes ``/v1/query`` through it, and it is equally usable embedded
(benchmarks, tests, custom front ends).

:class:`ServeEngine` provides four mechanisms:

  * **Single-flight coalescing** — concurrent requests for the same
    coalescing key ``(step, reducer, name, domain)`` attach to one
    in-flight backend read; N identical viewers cost one decode+merge
    and N response writes (``serve_coalesced_total``).
  * **Crop batching** — region crops of the same object are *compatible*
    requests: the flight performs one merged full-object read and every
    requester slices its own crop from the shared frozen arrays
    (``serve_batched_reads_total`` counts flights that served more than
    one distinct region from a single read).
  * **Admission control + per-client fairness** — a bounded pending
    queue (capacity scaled down by the staging ring's backpressure
    signal, see :func:`staging_pressure`) refuses overload with
    :class:`ServeOverloaded` → HTTP 429 + ``Retry-After``; queued work
    drains round-robin across client tokens so one flooding dashboard
    cannot starve the others. Objects already in the catalog's LRU
    bypass admission entirely (they cost no backend read).
  * **Progressive responses** — :func:`plan_progressive` splits a
    reduced object into a coarse-first frame sequence built on the
    ``fpdelta-pyramid`` levels (the codec's mean pyramid *is* a LOD
    ladder): frame 0 carries the coarsest level (plus every
    non-pyramidal array), later frames stream refinement blocks, and
    :class:`ProgressiveAssembler` reconstructs — approximately after
    every frame, **bit-exactly** after the last (the codec is lossless).

Metric families (registered on the engine's — usually the server's —
registry): ``serve_coalesced_total``, ``serve_batched_reads_total``,
``serve_admission_rejections_total``, ``serve_backend_reads_total``,
``serve_cache_serves_total``, the ``serve_queue_depth`` gauge, and the
``serve_stage_seconds{stage}`` latency histograms
(admit/queue/read/follow/crop/encode/write).
"""
from __future__ import annotations

import collections
import io
import json
import threading
import time

import numpy as np

from ..core import fpdelta, pyramid
from ..hercule.codecs import _block_to_bytes, _blocks_from_bytes
from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from .catalog import _crop, _normalize_region

#: stage labels of the serve_stage_seconds histogram family
STAGES = ("admit", "queue", "read", "follow", "crop", "encode", "write")


class ServeOverloaded(RuntimeError):
    """Admission control refused the request (HTTP 429 upstream).

    ``retry_after`` (seconds) is the server's backoff hint; it grows
    with the observed backpressure.
    """

    def __init__(self, retry_after: float):
        super().__init__(f"serving queue full; retry after "
                         f"{retry_after:.3f}s")
        self.retry_after = float(retry_after)


def staging_pressure(area) -> "collections.abc.Callable[[], float]":
    """Backpressure signal (0..1) from a staging ring's queue depth.

    Pass the result as ``pressure_fn`` to couple admission control to a
    live :class:`~repro.insitu.staging.StagingArea`: when the ring backs
    up (the compute flow is outrunning the analysis flow), the serving
    engine sheds viewer load first instead of competing for the same
    cores.
    """
    return lambda: len(area) / max(1, area.capacity)


class _Flight:
    """One in-flight backend read plus everyone waiting on it."""

    __slots__ = ("event", "result", "error", "followers", "regions")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error: BaseException | None = None
        self.followers = 0
        self.regions: set = set()


class ServeEngine:
    """Single-flight, batching, fair-queueing front end over a catalog.

    ``catalog`` needs ``query(step, reducer, domain=...)`` (full-object
    read) and ``peek(step, reducer, domain)`` (cache probe) — a
    :class:`~repro.insitu.catalog.Catalog` or any duck-typed wrapper.
    ``workers`` backend-read threads execute queued flights;
    ``max_pending`` bounds flights admitted but not yet finished, scaled
    down to 10% as ``pressure_fn()`` approaches 1.0.
    """

    def __init__(self, catalog, *, workers: int = 4,
                 max_pending: int = 256, retry_after: float = 0.05,
                 pressure_fn=None,
                 obs: obs_metrics.MetricsRegistry | None = None):
        self.catalog = catalog
        self.workers = max(1, int(workers))
        self.max_pending = max(1, int(max_pending))
        self.base_retry_after = float(retry_after)
        self.pressure_fn = pressure_fn
        self.obs = obs if obs is not None else obs_metrics.MetricsRegistry()

        self._cv = threading.Condition()
        self._inflight: dict[tuple, _Flight] = {}
        self._queues: dict[str, collections.deque] = {}
        self._rr: collections.deque = collections.deque()
        self._pending = 0
        self._closed = False

        self._m_coalesced = self.obs.counter(
            "serve_coalesced_total",
            "requests attached to an in-flight identical backend read")
        self._m_batched = self.obs.counter(
            "serve_batched_reads_total",
            "flights that served >1 distinct region crop from one read")
        self._m_rejected = self.obs.counter(
            "serve_admission_rejections_total",
            "requests refused by admission control (429)")
        self._m_backend = self.obs.counter(
            "serve_backend_reads_total",
            "full decode+merge reads executed against the catalog")
        self._m_inline = self.obs.counter(
            "serve_cache_serves_total",
            "requests served inline from the catalog LRU (no queue slot)")
        self.obs.gauge(
            "serve_queue_depth",
            "flights admitted but not yet finished"
        ).set_function(lambda: self._pending)
        self._h_stage = self.obs.histogram(
            "serve_stage_seconds", "per-stage serving latency",
            labels=("stage",))

        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"hx-serve-{i}")
            for i in range(self.workers)]
        for t in self._threads:
            t.start()

    # --------------------------------------------------------- admission
    def _pressure(self) -> float:
        if self.pressure_fn is None:
            return 0.0
        try:
            return min(1.0, max(0.0, float(self.pressure_fn())))
        except Exception:       # noqa: BLE001 — a dead producer's signal
            return 0.0          # must not take serving down with it

    def capacity(self) -> int:
        """Effective admission capacity under the current backpressure."""
        return max(1, int(self.max_pending * (1.0 - 0.9 * self._pressure())))

    def retry_after(self) -> float:
        """Backoff hint for a rejected client; grows with backpressure."""
        return self.base_retry_after * (1.0 + 9.0 * self._pressure())

    def observe_stage(self, stage: str, seconds: float) -> None:
        """Record one stage latency (servers report encode/write here)."""
        if obs_metrics.ENABLED:
            self._h_stage.labels(stage).observe(seconds)

    # ------------------------------------------------------------- fetch
    def fetch(self, step: int, reducer: str, *, name: str | None = None,
              region=None, domain: int | None = None,
              client: str = "anon", timeout: float = 120.0
              ) -> dict[str, np.ndarray]:
        """One viewer request; returns the (cropped) reduced object.

        Coalesces with concurrent identical requests, batches region
        crops onto one read, and raises :class:`ServeOverloaded` when
        admission control refuses. ``KeyError`` propagates exactly like
        ``Catalog.query`` (absent object).
        """
        t0 = time.perf_counter()
        region = _normalize_region(region)
        key = (step, reducer, name, domain)
        with self._cv:
            if self._closed:
                raise RuntimeError("ServeEngine is closed")
            fl = self._inflight.get(key)
            if fl is not None:                    # single-flight attach
                fl.followers += 1
                fl.regions.add(region)
                # stats() counters are functional (the selftest and the
                # load test assert on them): never gated on the obs
                # kill switch, unlike the stage histograms
                self._m_coalesced.inc()
            elif self.catalog.peek(step, reducer, domain):
                fl = None                         # LRU hit: serve inline
            else:
                if self._pending >= self.capacity():
                    self._m_rejected.inc()
                    obs_events.EVENTS.emit(
                        obs_events.SERVE_429, step=step, reducer=reducer,
                        pending=self._pending,
                        retry_after=self.retry_after())
                    raise ServeOverloaded(self.retry_after())
                fl = self._inflight[key] = _Flight()
                fl.regions.add(region)
                self._pending += 1
                self._enqueue_locked(client, key, fl)
                self._cv.notify()
        self.observe_stage("admit", time.perf_counter() - t0)

        if fl is None:                            # inline cache serve
            self._m_inline.inc()
            full = self.catalog.query(step, reducer, domain=domain)
        else:
            t1 = time.perf_counter()
            if not fl.event.wait(timeout):
                raise TimeoutError(
                    f"backend read for {key} did not finish in {timeout}s")
            self.observe_stage("follow", time.perf_counter() - t1)
            if fl.error is not None:
                raise fl.error
            full = fl.result
        t2 = time.perf_counter()
        out = dict(full) if region is None else _crop(full, region)
        self.observe_stage("crop", time.perf_counter() - t2)
        return out

    # --------------------------------------------------- fair scheduling
    def _enqueue_locked(self, client: str, key: tuple, fl: _Flight
                        ) -> None:
        q = self._queues.get(client)
        if q is None:
            q = self._queues[client] = collections.deque()
            if client not in self._rr:
                self._rr.append(client)
        q.append((key, fl, time.perf_counter()))

    def _next_job_locked(self):
        """Round-robin across client tokens; None when nothing queued."""
        for _ in range(len(self._rr)):
            c = self._rr[0]
            self._rr.rotate(-1)
            q = self._queues.get(c)
            if not q:
                # lazily retire clients with no queued work (c is now
                # at the tail after the rotate)
                self._queues.pop(c, None)
                if self._rr and self._rr[-1] == c:
                    self._rr.pop()
                continue
            return q.popleft()
        return None

    def _worker(self) -> None:
        while True:
            with self._cv:
                job = self._next_job_locked()
                while job is None and not self._closed:
                    self._cv.wait(0.5)
                    job = self._next_job_locked()
                if job is None:
                    return
            key, fl, t_enq = job
            self.observe_stage("queue", time.perf_counter() - t_enq)
            step, reducer, _name, domain = key
            t0 = time.perf_counter()
            try:
                fl.result = self.catalog.query(step, reducer,
                                               domain=domain)
                self._m_backend.inc()
            except BaseException as e:      # noqa: BLE001 — propagated
                fl.error = e                # to every waiter
            self.observe_stage("read", time.perf_counter() - t0)
            with self._cv:
                self._inflight.pop(key, None)
                self._pending -= 1
                n_regions = len(fl.regions)
            if n_regions > 1:
                self._m_batched.inc()
            fl.event.set()

    # --------------------------------------------------------------- admin
    def stats(self) -> dict:
        """JSON-able counter snapshot (the /v1/stats ``serve`` section)."""
        with self._cv:
            depth, inflight = self._pending, len(self._inflight)
        return {"coalesced": int(self._m_coalesced.value),
                "batched_reads": int(self._m_batched.value),
                "rejections": int(self._m_rejected.value),
                "backend_reads": int(self._m_backend.value),
                "cache_serves": int(self._m_inline.value),
                "queue_depth": depth,
                "inflight": inflight,
                "capacity": self.capacity(),
                "workers": self.workers,
                "max_pending": self.max_pending}

    def close(self) -> None:
        with self._cv:
            self._closed = True
            # fail any still-queued flights: their waiters must not hang
            for q in self._queues.values():
                for _key, fl, _t in q:
                    fl.error = RuntimeError("ServeEngine closed")
                    fl.event.set()
            self._queues.clear()
            self._rr.clear()
            self._inflight.clear()
            self._pending = 0
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)


# ---------------------------------------------------------- progressive

PROG_SCHEMA = "hx-progressive/1"
#: floats below this element count ship whole in frame 0 (a pyramid of
#: a tiny array refines nothing worth a round trip)
PROG_MIN_SIZE = 4096


def _upsample(vals: np.ndarray, shape: tuple, dtype) -> np.ndarray:
    """Nearest-neighbour preview of a coarse pyramid level at full shape."""
    n = int(np.prod(shape)) if shape else 1
    reps = -(-n // max(1, vals.size))
    return np.repeat(np.asarray(vals), reps)[:n].reshape(shape) \
        .astype(dtype, copy=False)


def plan_progressive(arrays: dict[str, np.ndarray], *,
                     min_size: int = PROG_MIN_SIZE, zbits: int = 4
                     ) -> list[dict[str, np.ndarray]]:
    """Split a reduced object into coarse-first ``hx-frame/1`` payloads.

    Frame 0 carries a JSON plan (``__prog__``), every non-pyramidal
    array whole, and the coarsest pyramid level (``<name>@root``) of
    each eligible float array. Frame ``i`` (i>=1) carries refinement
    block ``k-i`` of each array with ``k`` levels (coarse → fine), as
    raw section bytes (``<name>@L<j>``). Feeding all frames to
    :class:`ProgressiveAssembler` reproduces the arrays bit-exactly.
    """
    plan: dict = {"schema": PROG_SCHEMA, "arrays": {}}
    frame0: dict[str, np.ndarray] = {}
    blocks_of: dict[str, list[bytes]] = {}
    n_refine = 0
    for name, arr in arrays.items():
        a = np.ascontiguousarray(arr)
        if a.dtype in (np.dtype(np.float32), np.dtype(np.float64)) \
                and a.size >= min_size:
            pc = pyramid.encode_pyramid(a, zbits=zbits)
            if pc.levels:
                k = len(pc.levels)
                plan["arrays"][name] = {
                    "mode": "pyramid", "dtype": str(a.dtype),
                    "shape": list(a.shape), "pad": pc.pad, "n_levels": k}
                frame0[f"{name}@root"] = pc.root
                secs = []
                for blk in pc.levels:           # fine -> coarse storage
                    buf = io.BytesIO()
                    _block_to_bytes(buf, blk)
                    secs.append(buf.getvalue())
                blocks_of[name] = secs
                n_refine = max(n_refine, k)
                continue
        plan["arrays"][name] = {"mode": "full"}
        frame0[name] = a
    plan["frames"] = 1 + n_refine
    frames = [{"__prog__": np.frombuffer(json.dumps(plan).encode(),
                                         np.uint8), **frame0}]
    for i in range(1, n_refine + 1):
        fr: dict[str, np.ndarray] = {}
        for name, secs in blocks_of.items():
            j = len(secs) - i                   # coarsest block first
            if j >= 0:
                fr[f"{name}@L{j}"] = np.frombuffer(secs[j], np.uint8)
        frames.append(fr)
    return frames


class ProgressiveAssembler:
    """Viewer-side reassembly of a :func:`plan_progressive` stream.

    ``feed`` one decoded frame at a time; each call returns the current
    best reconstruction (coarse levels upsampled nearest-neighbour).
    After the final frame (``done``) the result is bit-exact — the
    pyramid codec is lossless, so refinement is *correction*, not
    approximation.
    """

    def __init__(self):
        self.plan: dict | None = None
        self._root: dict[str, np.ndarray] = {}
        self._blocks: dict[str, dict[int, fpdelta.Compressed]] = {}
        self._full: dict[str, np.ndarray] = {}
        self._frames_seen = 0

    @property
    def done(self) -> bool:
        return self.plan is not None and \
            self._frames_seen >= int(self.plan["frames"])

    def feed(self, frame: dict[str, np.ndarray]
             ) -> dict[str, np.ndarray]:
        if self.plan is None:
            meta = frame.get("__prog__")
            if meta is None:
                raise ValueError("first frame carries no __prog__ plan")
            self.plan = json.loads(bytes(bytearray(meta)).decode())
            if self.plan.get("schema") != PROG_SCHEMA:
                raise ValueError(
                    f"unsupported progressive schema "
                    f"{self.plan.get('schema')!r}")
            for name, spec in self.plan["arrays"].items():
                if spec["mode"] == "full":
                    self._full[name] = frame[name]
                else:
                    self._root[name] = frame[f"{name}@root"]
                    self._blocks[name] = {}
        else:
            for tkey, payload in frame.items():
                name, sep, j = tkey.rpartition("@L")
                if not sep or name not in self._blocks:
                    raise ValueError(
                        f"unexpected refinement key {tkey!r}")
                self._blocks[name][int(j)] = \
                    _blocks_from_bytes(bytes(bytearray(payload)))[0]
        self._frames_seen += 1
        return self.current()

    def current(self) -> dict[str, np.ndarray]:
        """Best reconstruction from the frames received so far."""
        if self.plan is None:
            raise ValueError("no frames fed yet")
        out = dict(self._full)
        for name, spec in self.plan["arrays"].items():
            if spec["mode"] != "pyramid":
                continue
            k = int(spec["n_levels"])
            shape = tuple(spec["shape"])
            dtype = np.dtype(spec["dtype"])
            cur = np.asarray(self._root[name])
            have = self._blocks[name]
            exact = True
            for j in range(k - 1, -1, -1):      # decode coarse -> fine
                blk = have.get(j)
                if blk is None:
                    exact = False
                    break
                cur = fpdelta.decode(blk, cur[:blk.n_groups]).reshape(-1)
            if exact:
                n = int(np.prod(shape)) if shape else 1
                out[name] = cur[:n].reshape(shape)
            else:
                out[name] = _upsample(cur, shape, dtype)
        return out

    def result(self) -> dict[str, np.ndarray]:
        """The bit-exact arrays; raises unless every frame was fed."""
        if not self.done:
            raise ValueError(
                f"progressive stream incomplete: "
                f"{self._frames_seen}/{self.plan and self.plan['frames']} "
                f"frames")
        return self.current()


__all__ = ["ServeEngine", "ServeOverloaded", "staging_pressure",
           "plan_progressive", "ProgressiveAssembler", "PROG_SCHEMA",
           "STAGES"]
