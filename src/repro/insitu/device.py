"""On-accelerator reduction: device staging + device reducers (§14).

The host engine's staging copies every snapshot to host memory *before*
any reduction — a full-resolution device→host transfer per staged step,
exactly the bottleneck the paper's in-transit architecture exists to
remove. This module keeps the snapshot on the accelerator end to end:

  * :class:`DeviceStagingArea` — the bounded-ring/backpressure staging
    area with **device-resident** buffer sets: a pushed jax array is
    restaged by a device→device copy (donation-safe, never touches the
    host), a host array is uploaded once; nothing crosses back to the
    host until a reducer has shrunk it.
  * a **device-reducer registry** (:func:`register_device_impl`) mapping
    the existing reducer classes to on-device implementations built on
    the Pallas rasterization kernels (``kernels/raster_kernel.py``,
    selected through ``kernels.ops``): axis-aligned slice, projection
    with owner masking, per-level histogram. Implementations are exact:
    the reduced objects are bit-identical to the host reducers
    (``tests/test_device_reduce.py``).
  * :class:`DeviceDAGRunner` — executes the engine's ReducerDAG with
    device implementations where registered and a **per-reducer host
    fallback** everywhere else (the full snapshot is materialized on
    host at most once per step, and only if some reducer needs it),
    while accounting every device→host byte (``stats``).

Wired in through ``InTransitEngine(device_reduce=True)``: the thread
backend stages into :class:`DeviceStagingArea` and lanes run the DAG
through the runner, so the only steady-state device→host traffic is the
reduced objects themselves (``bench_insitu.run_device`` records the
ratio). The whole path runs under ``jax.experimental.enable_x64`` so
the CPU/interpret kernels see the simulation's float64 exactly; on a
real TPU the registry would be populated with float32 variants (no f64
hardware) — documented, not implemented, since CI has no TPU.

Device impl factories return ``None`` for configs the kernels do not
cover — non-power-of-two resolutions (the kernels' pixel geometry is
exact integer arithmetic). Reducers chained on an upstream ``source``
run on host but read only that upstream's already-transferred output,
so they never force a snapshot materialization; with the device-side
LOD cut the default CLI DAG has **zero** full-snapshot fallbacks.

``insitu.mesh_reduce`` builds the third path on these pieces: the same
DAG sharded over a JAX device mesh (``shard_map`` partial rasters +
on-device merge), selected with ``InTransitEngine(device_reduce="mesh")``.
"""
from __future__ import annotations

import threading

import numpy as np

from ..obs.trace import TRACER
from .reducers import (LevelHistogramReducer, LODCutReducer,
                       ProjectionReducer, ReducerDAG, SliceReducer)
from .staging import Snapshot, StagingArea

#: leaf-table padding bucket: bounds jit retraces as trees grow/shrink
#: (multiple of the raster kernels' lane block)
PAD_BUCKET = 4096


def _pow2(n: int) -> bool:
    return n > 0 and n & (n - 1) == 0


def _padded(n: int) -> int:
    return -(-n // PAD_BUCKET) * PAD_BUCKET


# ------------------------------------------------------- device staging

class _DeviceBufferSet:
    """Device-resident twin of the host ``_BufferSet``.

    A jax-array push is staged through a **device→device copy** — it
    never crosses to the host, but it must not be a bare reference:
    the producer's buffer may be *donated* by its next jitted step
    (the trainer's train step donates the state), which deletes the
    original while the snapshot is still queued. Device restages count
    as buffer reuses (no host crossing), host uploads as allocs.
    ``block_until_ready`` keeps the ``push`` contract that compute may
    mutate (or donate) its arrays the moment push returns.
    """

    def __init__(self):
        self.buffers: dict = {}

    def fill(self, arrays: dict):
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        out = {}
        reuses = allocs = nbytes = 0
        with enable_x64():
            for name, src in arrays.items():
                # jnp.array (not asarray): a guaranteed copy — device
                # sources may be donated away by the producer's next
                # step, host sources may alias on the CPU backend
                out[name] = jnp.array(src)
                if isinstance(src, jax.Array):
                    reuses += 1          # device-resident: no host crossing
                else:
                    allocs += 1          # host upload
                nbytes += out[name].nbytes
            jax.block_until_ready(out)
        # deliberately NOT retained on self: jax arrays cannot be
        # refilled in place, so holding them while the buffer set sits
        # in the free pool would only pin dead device memory — the
        # Snapshot owns the only reference, release() really frees
        return out, reuses, allocs, nbytes


class DeviceStagingArea(StagingArea):
    """StagingArea whose staged snapshots live on the accelerator.

    Same bounded queue, policies, stats and ``on_evict`` contract as the
    host area (it *is* the host area — only the buffer residency
    changes); ``Snapshot.arrays`` values are jax device arrays.
    """

    BUFFER_SET = _DeviceBufferSet


# ------------------------------------------------------------- prep

class DeviceTree:
    """Per-snapshot device view shared by all device reducer impls.

    Lazily derives the flat rasterization inputs from the staged BFS
    tree arrays — per-node levels (from ``level_offsets``, which never
    leaves the device), the owned-leaf validity mask, int32 coords —
    padded to :data:`PAD_BUCKET` so jit retraces stay bounded while the
    AMR tree changes size every step. Padding rows carry ``ok=False``.
    """

    def __init__(self, arrays: dict, n_domains: int, count_to_host=None,
                 backend: str | None = None):
        self.arrays = arrays
        self.n_domains = n_domains
        self.backend = backend
        self.count_to_host = count_to_host or (lambda nbytes: None)
        self.n_levels = int(arrays["level_offsets"].shape[0]) - 1
        self._geom = None
        self._fields: dict = {}

    def _pad(self, x, fill):
        import jax.numpy as jnp
        n = x.shape[0]
        pad = _padded(n) - n
        if pad == 0:
            return x
        width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, width, constant_values=fill)

    def _prep(self):
        if self._geom is None:
            import jax.numpy as jnp
            refine = jnp.asarray(self.arrays["refine"])
            n = int(refine.shape[0])
            offsets = jnp.asarray(self.arrays["level_offsets"])
            levels = (jnp.searchsorted(offsets, jnp.arange(n), side="right")
                      .astype(jnp.int32) - 1)
            ok = ~refine
            if self.n_domains > 1:   # partitioned: owned leaves count once
                ok = ok & jnp.asarray(self.arrays["owner"])
            coords = jnp.asarray(self.arrays["coords"]).astype(jnp.int32)
            self._geom = (self._pad(coords, 0), self._pad(levels, 0),
                          self._pad(ok, False))
        return self._geom

    @property
    def coords(self):
        return self._prep()[0]

    @property
    def levels(self):
        return self._prep()[1]

    @property
    def ok(self):
        """Valid-leaf mask: leaf ∧ (owner when partitioned) ∧ ¬padding."""
        return self._prep()[2]

    def field(self, name: str):
        if name not in self._fields:
            import jax.numpy as jnp
            self._fields[name] = self._pad(
                jnp.asarray(self.arrays[f"field:{name}"]), 0)
        return self._fields[name]


# ----------------------------------------------------- impl registry

#: reducer class -> factory(reducer) -> impl(DeviceTree) -> dict | None
DEVICE_IMPLS: dict[type, object] = {}


def register_device_impl(reducer_cls: type):
    """Register (or replace) the device factory for one reducer class.

    The factory receives the reducer *instance* and returns either a
    callable ``impl(device_tree) -> dict of arrays`` or ``None`` when
    this configuration must fall back to the host implementation.
    """
    def deco(factory):
        DEVICE_IMPLS[reducer_cls] = factory
        return factory
    return deco


def device_impl_for(reducer):
    """Resolve one reducer instance to its device impl (or None)."""
    factory = DEVICE_IMPLS.get(type(reducer))
    return factory(reducer) if factory is not None else None


@register_device_impl(SliceReducer)
def _slice_impl(r: SliceReducer):
    if r.source is not None or not _pow2(r.resolution):
        return None

    def run(dt: DeviceTree):
        from ..kernels import ops
        img = ops.raster_slice(dt.coords, dt.levels, dt.field(r.field),
                               dt.ok, axis=r.axis, position=r.position,
                               resolution=r.resolution,
                               n_levels=dt.n_levels, backend=dt.backend)
        return {"image": img}
    return run


@register_device_impl(ProjectionReducer)
def _projection_impl(r: ProjectionReducer):
    if r.source is not None or not _pow2(r.resolution):
        return None

    def run(dt: DeviceTree):
        from ..kernels import ops
        img = ops.raster_projection(dt.coords, dt.levels, dt.field(r.field),
                                    dt.ok, axis=r.axis,
                                    resolution=r.resolution,
                                    n_levels=dt.n_levels,
                                    backend=dt.backend)
        return {"image": img}
    return run


@register_device_impl(LODCutReducer)
def _lod_impl(r: LODCutReducer):
    """Device-side LOD cut: slice the BFS prefix, demote the new floor.

    ``keep = levels <= max_level`` is a *prefix* of the level-major BFS
    arrays, so the host path's ``subset_tree`` selection is an identity
    re-index over the first ``offsets[max_level+1]`` rows: the cut is a
    device-side slice plus a ``refine=False`` stamp on the new deepest
    level (the host's ``force_leaf`` demotion). Only ``level_offsets``
    (a few dozen bytes, counted as meta) crosses to the host to size
    the slices; the cut tree itself crosses only as the reducer output.
    Kills the last full-snapshot fallback in the default CLI DAG.
    """
    def run(dt: DeviceTree):
        import jax.numpy as jnp
        offs = np.asarray(dt.arrays["level_offsets"]).astype(np.int64)
        dt.count_to_host(offs.nbytes)
        if len(offs) - 1 <= r.max_level + 1:
            return dict(dt.arrays)          # already at/below the cut
        n_keep = int(offs[r.max_level + 1])
        new_offs = offs[:r.max_level + 2].copy()
        # trim now-empty deepest levels, exactly like subset_tree
        n_lv = len(new_offs) - 1
        while n_lv > 1 and new_offs[n_lv] == new_offs[n_lv - 1]:
            n_lv -= 1
        refine = jnp.asarray(dt.arrays["refine"])[:n_keep]
        refine = refine.at[int(offs[r.max_level]):n_keep].set(False)
        out = {"refine": refine, "level_offsets": new_offs[:n_lv + 1]}
        for k, v in dt.arrays.items():
            if k not in out and k != "level_offsets":
                out[k] = jnp.asarray(v)[:n_keep]
        return out
    return run


@register_device_impl(LevelHistogramReducer)
def _hist_impl(r: LevelHistogramReducer):
    def run(dt: DeviceTree):
        import jax.numpy as jnp

        from ..kernels import ops
        v = dt.field(r.field)
        if r.lo is None or r.hi is None:
            # auto bounds: one fused device min/max reduction, a single
            # 16-byte sync instead of the whole field (or two pulls)
            mm = np.asarray(jnp.stack(
                [jnp.min(jnp.where(dt.ok, v, jnp.inf)),
                 jnp.max(jnp.where(dt.ok, v, -jnp.inf))]))
            lo = float(mm[0]) if r.lo is None else r.lo
            hi = float(mm[1]) if r.hi is None else r.hi
            dt.count_to_host(16)
        else:
            lo, hi = r.lo, r.hi
        if hi <= lo:
            hi = lo + 1.0
        edges = np.linspace(lo, hi, r.bins + 1)
        hist = ops.raster_level_hist(
            v, dt.levels, dt.ok, jnp.asarray(edges),
            n_levels=min(dt.n_levels, r.max_levels), backend=dt.backend)
        return {"hist": hist, "edges": edges}
    return run


# ------------------------------------------------------------ runner

class DeviceRunStats:
    """Device→host transfer accounting for the device-reduce path."""

    def __init__(self):
        self.snapshots = 0                 # snapshots run through the DAG
        self.device_objects = 0            # reduced objects computed on device
        self.bytes_reduced_to_host = 0     # transferred reduced outputs
        self.bytes_meta_to_host = 0        # scalar pulls (auto hist bounds)
        self.fallback_snapshots = 0        # snapshots materialized on host
        self.bytes_fallback_to_host = 0    # full-snapshot fallback transfers
        self.fallback_runs: dict[str, int] = {}   # per-reducer host runs

    def as_dict(self) -> dict:
        return {"snapshots": self.snapshots,
                "device_objects": self.device_objects,
                "bytes_reduced_to_host": self.bytes_reduced_to_host,
                "bytes_meta_to_host": self.bytes_meta_to_host,
                "fallback_snapshots": self.fallback_snapshots,
                "bytes_fallback_to_host": self.bytes_fallback_to_host,
                "fallback_runs": dict(self.fallback_runs),
                "bytes_to_host": (self.bytes_reduced_to_host
                                  + self.bytes_meta_to_host
                                  + self.bytes_fallback_to_host)}


class DeviceDAGRunner:
    """Execute a ReducerDAG with device impls + per-reducer host fallback.

    Drop-in for ``ReducerDAG.run`` on the engine's lane side: same kind
    filtering, dependency skipping and output shape. Reducers with a
    registered device impl reduce on the accelerator and transfer only
    their outputs; the rest see a host snapshot materialized at most
    once per step (and tensor reducers, which are jax-jitted anyway,
    consume the device arrays directly). Thread-safe — engine lanes may
    share one runner.
    """

    def __init__(self, dag: ReducerDAG, *, backend: str | None = None):
        self.dag = dag
        self.backend = backend          # kernel backend override (tests)
        self.impls = {r.name: device_impl_for(r) for r in dag}
        self.stats = DeviceRunStats()
        self._lock = threading.Lock()

    def device_reducers(self) -> list[str]:
        """Names of DAG reducers that will run on device."""
        return [n for n, impl in self.impls.items() if impl is not None]

    def _count_meta(self, nbytes: int) -> None:
        with self._lock:
            self.stats.bytes_meta_to_host += nbytes

    def _make_view(self, snap: Snapshot):
        """Per-snapshot view handed to the registered impls (overridable:
        the mesh runner builds sharded leaf tables here instead)."""
        return DeviceTree(snap.arrays, snap.n_domains, self._count_meta,
                          backend=self.backend)

    def run(self, snap: Snapshot) -> dict[str, dict[str, np.ndarray]]:
        import jax
        from jax.experimental import enable_x64
        with enable_x64():
            outputs: dict[str, dict[str, np.ndarray]] = {}
            dt = host_snap = None
            for r in self.dag.order:
                if snap.kind not in r.kinds:
                    continue
                if any(d not in outputs for d in r.deps):
                    continue
                impl = self.impls.get(r.name)
                if impl is not None:
                    if dt is None:
                        dt = self._make_view(snap)
                    moved = 0
                    out = {}
                    # spans nest under the lane's open "reduce" span;
                    # np.asarray is where the async device work lands
                    with TRACER.span("device.transfer",
                                     args={"reducer": r.name}) as sp:
                        for k, v in impl(dt).items():
                            if isinstance(v, jax.Array):
                                moved += v.nbytes
                                v = np.asarray(v)
                            out[k] = v
                        sp.set(nbytes=moved)
                    with self._lock:
                        self.stats.device_objects += 1
                        self.stats.bytes_reduced_to_host += moved
                elif getattr(r, "device_ready", False):
                    # jax-jitted reducers (tensor norms/spectra) consume
                    # device arrays directly; their outputs are already
                    # reduced host arrays
                    out = r.reduce(snap, outputs)
                    with self._lock:
                        self.stats.device_objects += 1
                        self.stats.bytes_reduced_to_host += sum(
                            np.asarray(v).nbytes for v in out.values())
                elif getattr(r, "source", None):
                    # source-chained reducers only read their upstream's
                    # (already transferred) output — run them on host
                    # without materializing the snapshot
                    out = r.reduce(snap, outputs)
                    with self._lock:
                        self.stats.fallback_runs[r.name] = \
                            self.stats.fallback_runs.get(r.name, 0) + 1
                else:
                    if host_snap is None:
                        host_arrays, moved = {}, 0
                        with TRACER.span("device.transfer",
                                         args={"reducer": r.name,
                                               "fallback": True}) as sp:
                            for k, v in snap.arrays.items():
                                if isinstance(v, jax.Array):
                                    moved += v.nbytes
                                host_arrays[k] = np.asarray(v)
                            sp.set(nbytes=moved)
                        host_snap = Snapshot(
                            step=snap.step, kind=snap.kind,
                            arrays=host_arrays, meta=snap.meta,
                            domain=snap.domain, n_domains=snap.n_domains)
                        with self._lock:
                            self.stats.fallback_snapshots += 1
                            self.stats.bytes_fallback_to_host += moved
                    out = r.reduce(host_snap, outputs)
                    with self._lock:
                        self.stats.fallback_runs[r.name] = \
                            self.stats.fallback_runs.get(r.name, 0) + 1
                if out:
                    outputs[r.name] = out
            with self._lock:
                self.stats.snapshots += 1
            return outputs
