"""In-transit analysis engine (the paper's staging-node role).

``InTransitEngine`` sits between the compute flow and an HDep database:
compute calls :meth:`submit` (or :meth:`submit_state` for train states)
and returns immediately; lanes drain the staging areas, run the reducer
DAG and write each snapshot's reduced objects as one HDep context. The
engine has its *own* output frequency (``output_every``), independent of
HProt checkpoint cadence — the paper's "different output frequencies"
between the protection and post-processing flows.

With ``domains > 1`` the engine runs the paper's per-producer shape:
each submitted step is partitioned over contributor groups
(``insitu.partition``), every group owns its own staging area and lane,
and each group writes its part of the reduction as its *own Hercule
domain* within the shared per-step context — no single-writer funnel.
The context finalizes when the last group's part lands (or is dropped by
backpressure); reads merge the domains back
(``hercule.api.ReducedKind``), so a context with some parts dropped
still serves its surviving domains.

*How* lanes execute is pluggable (``insitu.lanes``): ``backend="thread"``
keeps every lane an in-process worker thread (PR-3 semantics, bit for
bit); ``backend="process"`` makes each group's lane an OS process fed
through shared-memory staging, so reduction and domain writes run
outside the producer's GIL — the live pipeline scales the way
``bench_insitu.run_multidomain`` demonstrates with separate processes.

``step_ttl`` bounds the life of a partial step: when per-producer
submission (:meth:`submit_part`) loses a producer (crash, skipped
cadence), the step's context finalizes with the surviving domains after
``step_ttl`` seconds of inactivity — the same path drop-oldest eviction
takes — instead of leaking the pending context forever.

Contexts written here carry ``attrs["insitu"]`` with the reducer names,
the per-reducer merge strategies, the contributing domains and staging
statistics, so a catalog (or a human) can see what was reduced and what
back-pressure did to the cadence.
"""
from __future__ import annotations

import dataclasses
import threading
import time

from ..core.amr import AMRTree
from ..hercule import api
from ..hercule.database import HerculeDB
from ..obs import events as obs_events
from ..obs import metrics as obs_metrics
from ..obs.trace import TRACER
from .lanes import make_backend
from .partition import partition_snapshot
from .reducers import Reducer, ReducerDAG
from .staging import Snapshot


@dataclasses.dataclass
class _PendingStep:
    """Countdown of contributor parts still in flight for one step."""
    remaining: int
    ctx: object = None                # ContextWriter, begun lazily
    kind: str = ""
    meta: dict = dataclasses.field(default_factory=dict)
    wrote: set = dataclasses.field(default_factory=set)      # domains
    reducers: set = dataclasses.field(default_factory=set)
    finalizing: bool = False          # countdown done, manifest pending
    touched: float = 0.0              # monotonic time of last activity
    writers: int = 0                  # lanes mid-write into ctx (TTL gate)
    trace: dict | None = None         # submit-span wire context (tracing)


class InTransitEngine:
    """Contributor-group lanes turning staged snapshots into reduced HDep."""

    def __init__(self, root: str | HerculeDB, reducers: list[Reducer], *,
                 output_every: int = 1, workers: int = 1,
                 queue_capacity: int = 4, policy: str = "drop-oldest",
                 ncf: int = 4, compress: bool = False, domains: int = 1,
                 durable_parts: bool = False, backend: str = "thread",
                 step_ttl: float | None = None,
                 device_reduce: bool | str = False,
                 mesh_devices: int | None = None,
                 lane_pool: bool = False, ledger=None):
        from .lanes import BACKENDS
        if backend not in BACKENDS:   # before creating anything on disk
            raise ValueError(f"unknown lane backend {backend!r}; "
                             f"registered: {sorted(BACKENDS)}")
        self.n_domains = max(1, domains)
        if isinstance(device_reduce, str) and device_reduce != "mesh":
            raise ValueError(
                f"unknown device_reduce mode {device_reduce!r}; use "
                f"True (single device) or 'mesh' (sharded shard_map "
                f"reduction over a device mesh)")
        self.device_reduce = device_reduce if device_reduce == "mesh" \
            else bool(device_reduce)
        if mesh_devices is not None and self.device_reduce != "mesh":
            raise ValueError(
                "mesh_devices only applies with device_reduce='mesh'")
        if self.device_reduce and backend != "thread":
            # device arrays cannot cross to spawned lane processes; the
            # device path exists precisely to avoid such copies
            raise ValueError(
                f"device_reduce={self.device_reduce!r} requires "
                f"backend='thread' (device arrays and the device mesh "
                f"stay in the engine process)")
        if lane_pool and backend != "process":
            raise ValueError(
                "lane_pool=True only applies to backend='process' "
                "(thread lanes have no spawn cost to amortize)")
        if backend == "process" and self.n_domains > 1:
            ncf = 1   # each lane process must own its group files
        self.db = root if isinstance(root, HerculeDB) else \
            HerculeDB.create(root, kind="hdep", ncf=ncf)
        self.dag = ReducerDAG(reducers)
        #: device-reduce runner (None = host DAG execution); staging
        #: residency follows it — see lanes.ThreadLaneBackend
        self._device = None
        if self.device_reduce == "mesh":
            # sharded path: snapshots stage on *host* (the leaf table is
            # Hilbert-sharded over the mesh at reduce time), so the
            # staging area stays the plain host one — see lanes
            from .mesh_reduce import MeshDAGRunner
            self._device = MeshDAGRunner(self.dag, devices=mesh_devices)
        elif self.device_reduce:
            from .device import DeviceDAGRunner
            self._device = DeviceDAGRunner(self.dag)
        self.compress = compress
        self.output_every = max(1, output_every)
        #: fsync each group file from its own lane right after the part
        #: lands (parallel durability on storage with scalable sync);
        #: off = PR-1 semantics, durability at context finalize only
        self.durable_parts = durable_parts
        self.step_ttl = step_ttl
        self._merge_map = {r.name: r.merge for r in self.dag
                           if getattr(r, "merge", None)}
        self._errors: list[BaseException] = []
        self._pending: dict[int, _PendingStep] = {}
        #: completed steps whose finalize was deferred off the compute
        #: thread (eviction can complete a countdown inside submit();
        #: the manifest fsync must not run there)
        self._deferred: list[tuple[int, _PendingStep]] = []
        self._written: list[int] = []
        self._committed: set[int] = set()   # fast membership for _written
        self._failed = 0
        self._skipped = 0          # snapshot parts no reducer applied to
        self._ttl_expired = 0      # steps force-finalized by step_ttl
        self._wlock = threading.Lock()
        self._started = False
        #: the lane runtime: staging transport + execution context per
        #: contributor group (see insitu.lanes)
        self._backend = make_backend(backend, self, workers=workers,
                                     queue_capacity=queue_capacity,
                                     policy=policy, lane_pool=lane_pool)
        #: one staging area per contributor group; ``staging`` aliases
        #: group 0 for the single-group API the compute side always had
        self.stages = self._backend.stages
        self.staging = self.stages[0]
        #: per-engine metrics registry (engine instances never collide);
        #: hot-path observes are gated on obs.metrics.ENABLED, callback
        #: gauges sync the passive counters at collect time
        self.obs = obs_metrics.MetricsRegistry()
        self._h_submit = self.obs.histogram(
            "insitu_submit_seconds", "producer-side submit latency")
        self._h_reduce = self.obs.histogram(
            "insitu_reduce_seconds", "lane reducer-DAG latency",
            labels=("group",))
        self._h_write = self.obs.histogram(
            "insitu_write_seconds", "domain write latency",
            labels=("group",))
        self._h_commit = self.obs.histogram(
            "insitu_commit_seconds", "manifest commit latency")
        self.obs.register_callback(self._sync_obs)
        #: flight-recorder state: backpressure edge detection, one-shot
        #: crash dump, device-fallback event deltas
        self._bp_block_seen = 0.0
        self._bp_active = False
        self._fallback_seen = 0
        self._dumped = False
        self.ledger = None
        if ledger is not None:
            self.bind_ledger(ledger)

    @property
    def backend(self) -> str:
        return self._backend.name

    # ------------------------------------------------------------ run ledger
    def bind_ledger(self, ledger) -> None:
        """Attach a :class:`~repro.obs.ledger.RunLedger`: the engine
        registers its metrics registry as a flush source and its health
        signals, and lane telemetry relayed over the results queue is
        forwarded into each lane's own ledger domain."""
        self.ledger = ledger
        ledger.add_source("engine", self.obs.snapshot)
        ledger.add_signal("staging_pressure", self._sig_staging_pressure)
        ledger.add_signal("backpressure", self._sig_backpressure)
        ledger.add_signal(
            "engine_failed",
            lambda: float(self._failed + len(self._errors)))
        if self._device is not None:
            ledger.add_signal(
                "device_fallbacks",
                lambda: float(self._device.stats.fallback_snapshots))

    def _sig_staging_pressure(self) -> float | None:
        """Worst queue-fill fraction across the contributor groups."""
        worst = None
        for area in self.stages:
            try:
                frac = len(area) / max(1, area.capacity)
            except Exception:           # noqa: BLE001 — unlinked shm area
                continue
            worst = frac if worst is None else max(worst, frac)
        return worst

    def _sig_backpressure(self) -> float:
        """Fraction of wall time producers spent blocked since the last
        sample (block policy; drop policies surface as evict events)."""
        now = time.monotonic()
        total = sum(a.stats.as_dict().get("block_seconds", 0.0)
                    for a in self.stages)
        last_t, last_b = getattr(self, "_bp_sample", (None, 0.0))
        self._bp_sample = (now, total)
        if last_t is None or now <= last_t:
            return 0.0
        return min(1.0, max(0.0, (total - last_b) / (now - last_t)))

    def _note_backpressure(self) -> None:
        """Edge-triggered backpressure events off the block-time stat:
        enter when a submit paid block time, exit on the first submit
        that didn't (runs on the producer thread, two counter reads)."""
        total = 0.0
        for area in self.stages:
            try:
                total += area.stats.block_seconds
            except Exception:           # noqa: BLE001 — unlinked shm area
                return
        if total > self._bp_block_seen:
            self._bp_block_seen = total
            if not self._bp_active:
                self._bp_active = True
                obs_events.EVENTS.emit(
                    obs_events.STAGING_BACKPRESSURE, state="enter",
                    block_seconds=round(total, 6))
        elif self._bp_active:
            self._bp_active = False
            obs_events.EVENTS.emit(
                obs_events.STAGING_BACKPRESSURE, state="exit",
                block_seconds=round(total, 6))

    # ----------------------------------------------------------- compute side
    def start(self) -> "InTransitEngine":
        if not self._started:
            self._started = True
            self._backend.start()
        return self

    def submit(self, step: int, payload, *, kind: str = "amr",
               meta: dict | None = None) -> bool:
        """Offer one step's state to the analysis flow.

        ``payload`` is an :class:`AMRTree`, or a dict of arrays (device or
        host). Steps off the engine's output cadence are ignored without
        staging cost; otherwise the payload is partitioned over the
        contributor groups and each part staged under the configured
        backpressure policy. Returns True iff any part was staged.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        self._sweep_ttl()
        t0 = time.perf_counter() if obs_metrics.ENABLED else 0.0
        with TRACER.span("submit", args={"step": step}) as sp:
            if isinstance(payload, AMRTree):
                payload = payload.to_arrays()
                kind = "amr"
            parts = partition_snapshot(payload, kind, self.n_domains)
            staged = self._stage_parts(step, parts, kind, meta,
                                       trace=sp.context())
        if obs_metrics.ENABLED:
            self._h_submit.observe(time.perf_counter() - t0)
            self._note_backpressure()
        return staged

    def submit_parts(self, step: int, parts, *, kind: str = "amr",
                     meta: dict | None = None) -> bool:
        """Per-producer hand-off: stage pre-partitioned contributor parts.

        ``parts`` holds one payload (array dict or :class:`AMRTree`) per
        contributor group — the shape real multi-producer runs have,
        where each producer already owns its domain and no runtime
        partition is needed. ``len(parts)`` must equal the engine's
        ``domains``. Cadence and backpressure behave exactly as in
        :meth:`submit`; returns True iff any part was staged.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if len(parts) != self.n_domains:
            raise ValueError(
                f"got {len(parts)} parts for {self.n_domains} contributor "
                f"group(s)")
        self._sweep_ttl()
        t0 = time.perf_counter() if obs_metrics.ENABLED else 0.0
        with TRACER.span("submit", args={"step": step}) as sp:
            parts = [p.to_arrays() if isinstance(p, AMRTree) else p
                     for p in parts]
            staged = self._stage_parts(step, parts, kind, meta,
                                       trace=sp.context())
        if obs_metrics.ENABLED:
            self._h_submit.observe(time.perf_counter() - t0)
            self._note_backpressure()
        return staged

    def submit_part(self, step: int, domain: int, payload, *,
                    kind: str = "amr", meta: dict | None = None) -> bool:
        """One producer's hand-off of its own contributor part.

        The fully per-producer shape: each of the ``domains`` producers
        (e.g. one thread per simulated MPI rank) stages its own part
        into its own group's staging area, concurrently with the others
        — no shared hand-off thread. The step's context finalizes once
        all ``domains`` parts have settled; backpressure drops count as
        settled, and a producer that skips an on-cadence step is covered
        by ``step_ttl`` (the partial context finalizes with the
        surviving domains after the timeout; without a TTL it would
        wait forever). A part arriving *after* its step's context
        committed is rejected (returns False) — a lone straggler must
        not restart the countdown and overwrite the survivors' manifest.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if not 0 <= domain < self.n_domains:
            raise ValueError(f"domain {domain} outside the engine's "
                             f"{self.n_domains} contributor group(s)")
        self._sweep_ttl()
        t0 = time.perf_counter() if obs_metrics.ENABLED else 0.0
        with TRACER.span("submit",
                         args={"step": step, "domain": domain}) as sp:
            tctx = sp.context()
            if isinstance(payload, AMRTree):
                payload = payload.to_arrays()
            with self._wlock:
                pend = self._pending.get(step)
                if (pend is not None and pend.finalizing) or \
                        (pend is None and step in self._committed):
                    # the step's context already committed (or is
                    # committing) — e.g. a TTL-finalized partial. A lone
                    # late part must not start a fresh countdown: it
                    # could only ever hold its own domain, and committing
                    # that would *overwrite* the manifest that carries
                    # the other survivors.
                    return False
                if pend is None:
                    self._pending[step] = _PendingStep(
                        remaining=self.n_domains, touched=time.monotonic(),
                        trace=tctx)
                else:
                    pend.touched = time.monotonic()
            if pend is None:
                obs_events.EVENTS.emit(obs_events.STEP_BEGIN, step=step,
                                       parts=self.n_domains, kind=kind)
            if tctx is not None:
                meta = {**(meta or {}), "_trace": tctx}
            with TRACER.span("stage.push", args={"step": step,
                                                 "group": domain}):
                ok = self.stages[domain].push(
                    step, payload, kind=kind, meta=meta, domain=domain,
                    n_domains=self.n_domains)
        if obs_metrics.ENABLED:
            self._h_submit.observe(time.perf_counter() - t0)
            self._note_backpressure()
        if not ok:
            self._part_done(step, None, None, defer_finalize=True)
        return ok

    def _stage_parts(self, step: int, parts, kind: str,
                     meta: dict | None, trace: dict | None = None) -> bool:
        # register before the first push: a fast worker lane may finish
        # its part while later parts are still being staged
        with self._wlock:
            pend = self._pending.get(step)
            fresh = pend is None or pend.finalizing
            if fresh:
                # a finalizing pend is already off the countdown: the
                # resubmission gets its own entry (and so its own
                # ContextWriter — never append to a mid-serialization
                # manifest); the stale entry pops itself by identity
                self._pending[step] = _PendingStep(
                    remaining=len(parts), touched=time.monotonic(),
                    trace=trace)
            else:                      # resubmitted step: extend the countdown
                pend.remaining += len(parts)
                pend.touched = time.monotonic()
        if fresh:
            obs_events.EVENTS.emit(obs_events.STEP_BEGIN, step=step,
                                   parts=len(parts), kind=kind)
        if trace is not None:
            # the submit span rides the snapshot meta across the lane
            # boundary (shm JSON header), so lane-side spans link to it
            meta = {**(meta or {}), "_trace": trace}
        staged_any = False
        for g, part in enumerate(parts):
            with TRACER.span("stage.push", args={"step": step,
                                                 "group": g}):
                ok = self.stages[g].push(step, part, kind=kind, meta=meta,
                                         domain=g,
                                         n_domains=self.n_domains)
            if ok:
                staged_any = True
            else:
                self._part_done(step, None, None, defer_finalize=True)
        return staged_any

    def submit_state(self, step: int, state, *, prefix: str = "params"
                     ) -> bool:
        """Stage the matrix-shaped leaves of a train-state pytree."""
        if step % self.output_every != 0:
            return False   # skip the pytree flatten on off-cadence steps
        import jax

        from ..hercule.checkpoint import leaf_name
        sub = state[prefix] if isinstance(state, dict) and prefix in state \
            else state
        flat, _ = jax.tree_util.tree_flatten_with_path(sub)
        arrays = {}
        for path, leaf in flat:
            if leaf is None or getattr(leaf, "ndim", 0) < 2:
                continue
            arrays[leaf_name(path)] = leaf
        return self.submit(step, arrays, kind="tensors")

    # ---------------------------------------------------------- analysis side
    def _on_evict(self, snap: Snapshot) -> None:
        """A queued part was displaced by drop-oldest backpressure.

        Runs on the pushing (compute) thread, so a completed countdown
        is deferred — lanes (or :meth:`drain`) commit it.
        """
        obs_events.EVENTS.emit(obs_events.STAGING_EVICT, step=snap.step,
                               group=snap.domain)
        self._part_done(snap.step, None, None, defer_finalize=True)

    def _reduce_and_write(self, snap: Snapshot):
        """Thread-backend execution of one part (in the engine process)."""
        obs_on = obs_metrics.ENABLED
        tctx = snap.meta.get("_trace")
        t0 = time.perf_counter() if obs_on else 0.0
        with TRACER.span("reduce", parent=tctx,
                         args={"step": snap.step, "group": snap.domain}):
            outputs = self._device.run(snap) if self._device is not None \
                else self.dag.run(snap)
        if obs_on:
            self._h_reduce.labels(snap.domain).observe(
                time.perf_counter() - t0)
        if not outputs:
            # no reducer accepted this snapshot kind — don't litter the
            # database with empty contexts; surface it via stats instead
            with self._wlock:
                self._skipped += 1
            self._part_done(snap.step, None, None)
            return
        with self._wlock:
            pend = self._pending.get(snap.step)
            ctx = None
            if pend is not None and not pend.finalizing:
                if pend.ctx is None:
                    pend.ctx = self.db.begin_context(snap.step)
                    pend.kind = snap.kind
                    pend.meta = snap.meta
                ctx = pend.ctx
                # holding a writer claim keeps the TTL sweep from
                # finalizing (and serializing) this manifest while the
                # records below are still being appended
                pend.writers += 1
        if ctx is None:   # lone part of a settled (or TTL-expired) step:
            return        # never write into a mid-serialization manifest
        try:
            t1 = time.perf_counter() if obs_on else 0.0
            with TRACER.span("write", parent=tctx,
                             args={"step": snap.step,
                                   "group": snap.domain}):
                for rname, arrays in outputs.items():
                    api.write_object(ctx, "reduced", snap.domain, arrays,
                                     reducer=rname, compress=self.compress)
                if self.durable_parts:
                    # each lane makes its own group durable: group fsyncs
                    # overlap across lanes instead of queueing serially
                    # behind finalize
                    self.db.flush_domain(snap.domain)
            if obs_on:
                self._h_write.labels(snap.domain).observe(
                    time.perf_counter() - t1)
        except BaseException:
            with self._wlock:
                pend.writers -= 1
            raise          # the lane settles the part via its error path
        # release the writer claim atomically with the settle, so the
        # countdown can never finalize between the two
        self._part_done(snap.step, snap.domain, set(outputs),
                        release_writer=True)

    def _part_records(self, step: int, domain: int, records, reducers: set,
                      kind: str, meta: dict | None) -> None:
        """Process-backend intake: a lane landed its part, records arrive.

        The lane already appended the payload bytes to its own group
        files; the engine only collects the record index into the shared
        per-step context for the manifest commit.
        """
        with self._wlock:
            pend = self._pending.get(step)
            live = pend is not None and not pend.finalizing
            if live:
                if pend.ctx is None:
                    pend.ctx = self.db.begin_context(step)
                    pend.kind = kind
                    pend.meta = dict(meta or {})
                pend.ctx.records.extend(records)
                # claim a writer until the settle below: a TTL sweep
                # between the two lock holds must not commit a manifest
                # carrying these records but not their domain/reducers
                pend.writers += 1
        if not live:      # late part of a TTL-expired step: its bytes
            return        # stay orphaned (no manifest references them)
        self._part_done(step, domain, reducers, release_writer=True)

    def _part_done(self, step: int, domain: int | None,
                   reducers: set | None, *,
                   defer_finalize: bool = False,
                   release_writer: bool = False) -> None:
        """One contributor part settled (written, dropped, or failed).

        The pending entry survives until the manifest is committed, so
        :meth:`drain` cannot return while a context is mid-finalize.
        """
        with self._wlock:
            pend = self._pending.get(step)
            if pend is None or pend.finalizing:
                return
            if release_writer:
                pend.writers -= 1
            pend.remaining -= 1
            pend.touched = time.monotonic()
            if domain is not None:
                pend.wrote.add(domain)
                pend.reducers |= reducers
            if pend.remaining > 0:
                return
            if pend.writers > 0:
                # a lane is still appending records into this context
                # (possible when a TTL sweep consumed the countdown):
                # that writer's own settle re-enters here with
                # writers == 0 and commits — its records included
                return
            pend.finalizing = True
            if pend.ctx is None:        # every part dropped/skipped: no
                del self._pending[step]  # context, nothing to commit
                return
            if defer_finalize:
                self._deferred.append((step, pend))
                return
        self._finalize_step(step, pend)

    def _sweep_ttl(self) -> None:
        """Force-settle steps inactive past ``step_ttl`` (partial commit).

        A producer that skipped an on-cadence step (or died) leaves the
        step's countdown short forever; after ``step_ttl`` seconds with
        no part activity the missing parts are settled through the same
        path as drop-oldest eviction, so the context commits with the
        surviving domains only. A step with a lane mid-write into its
        context (``writers > 0``) is never swept — the TTL targets
        missing producers, not slow reductions; a part the sweep beat
        to the *start* of its write finds the context finalizing and
        skips cleanly.
        """
        if self.step_ttl is None:
            return
        now = time.monotonic()
        with self._wlock:
            expired = [(step, pend.remaining)
                       for step, pend in self._pending.items()
                       if not pend.finalizing and pend.remaining > 0
                       and pend.writers == 0
                       and now - pend.touched > self.step_ttl]
            self._ttl_expired += len(expired)
        for step, missing in expired:
            for _ in range(missing):
                self._part_done(step, None, None, defer_finalize=True)

    def _finalize_step(self, step: int, pend: _PendingStep) -> None:
        """Commit one completed context; errors surface via check_errors."""
        staging = self.stages[0].stats.as_dict() if self.n_domains == 1 \
            else [a.stats.as_dict() for a in self.stages]
        # the trace context is transport metadata, not context attrs
        meta = {k: v for k, v in pend.meta.items() if k != "_trace"}
        obs_on = obs_metrics.ENABLED
        t0 = time.perf_counter() if obs_on else 0.0
        try:
            with TRACER.span("manifest.commit", parent=pend.trace,
                             args={"step": step,
                                   "domains": sorted(pend.wrote)}):
                self._backend.pre_finalize(pend)
                pend.ctx.finalize(attrs={"insitu": {
                    "kind": pend.kind,
                    "reducers": sorted(pend.reducers),
                    "merge": {r: self._merge_map[r]
                              for r in sorted(pend.reducers)
                              if r in self._merge_map},
                    "n_domains": self.n_domains,
                    "domains": sorted(pend.wrote),
                    "staging": staging,
                    **meta,
                }})
            if obs_on:
                self._h_commit.observe(time.perf_counter() - t0)
        except BaseException as e:
            self._errors.append(e)
            with self._wlock:
                self._failed += 1
                if self._pending.get(step) is pend:   # a resubmission
                    del self._pending[step]           # may own the slot
            obs_events.EVENTS.dump("engine.commit_failed", step=step,
                                   error=repr(e))
            return
        with self._wlock:
            self._written.append(step)
            self._committed.add(step)
            if self._pending.get(step) is pend:
                del self._pending[step]
        obs_events.EVENTS.emit(obs_events.STEP_COMMIT, step=step,
                               domains=sorted(pend.wrote),
                               partial=len(pend.wrote) < self.n_domains)

    def _run_deferred(self) -> None:
        """Commit contexts whose countdown completed on a compute thread."""
        while True:
            with self._wlock:
                if not self._deferred:
                    return
                step, pend = self._deferred.pop()
            self._finalize_step(step, pend)

    # ----------------------------------------------------------------- admin
    @property
    def written_steps(self) -> list[int]:
        with self._wlock:
            return sorted(self._written)

    @property
    def skipped_snapshots(self) -> int:
        """Snapshot parts whose kind no reducer in the DAG accepted."""
        with self._wlock:
            return self._skipped

    @property
    def ttl_expired_steps(self) -> int:
        """Steps force-finalized (partial) by the step TTL."""
        with self._wlock:
            return self._ttl_expired

    @property
    def device_stats(self) -> dict | None:
        """Device→host transfer accounting (None unless device_reduce)."""
        return None if self._device is None else \
            self._device.stats.as_dict()

    def _staging_per_group(self) -> list[dict]:
        # shm areas share their counter words with the lane process, so
        # the producer-side view already carries consumer increments
        # (popped/released); after unlink the frozen copy answers
        return [a.stats.as_dict() for a in self.stages]

    def telemetry(self) -> dict:
        """One merged observability snapshot across every pipeline layer.

        Aggregates what used to be scattered over ``stages[i].stats``,
        ``device_stats`` and backend internals (all kept as thin views):
        staging per group + totals, lane/backend state, device-reduce
        accounting, write/commit progress, and the engine's metric
        registry. Identical shape for thread and process backends; for
        shm staging the producer and consumer sides are merged through
        the shared control words.
        """
        with self._wlock:
            lanes = {"written_steps": len(self._written),
                     "failed": self._failed,
                     "skipped_parts": self._skipped,
                     "ttl_expired_steps": self._ttl_expired,
                     "pending_steps": len(self._pending)}
            last = max(self._written, default=None)
        per_group = self._staging_per_group()
        totals = {k: sum(d[k] for d in per_group) for k in per_group[0]}
        queued = [len(a) if getattr(a, "_words", True) is not None
                  else None for a in self.stages]   # None once unlinked
        lanes.update(self._backend.telemetry())
        return {
            "backend": self._backend.name,
            "staging": {"per_group": per_group, "totals": totals,
                        "queued": queued},
            "lanes": lanes,
            "device": self.device_stats,
            "writes": {"contexts_committed": lanes["written_steps"],
                       "last_step": last},
            "trace": {"spans_dropped": TRACER.spans_dropped,
                      "max_spans": TRACER.max_spans,
                      "events_dropped": obs_events.EVENTS.dropped},
            "ledger": None if self.ledger is None
            else self.ledger.telemetry(),
            "metrics": self.obs.snapshot(),
        }

    def _sync_obs(self) -> None:
        """Collect-time gauge sync (MetricsRegistry callback): mirrors
        the passive counters into the registry without touching any hot
        path."""
        with self._wlock:
            state = {"steps_written": len(self._written),
                     "steps_failed": self._failed,
                     "parts_skipped": self._skipped,
                     "steps_ttl_expired": self._ttl_expired,
                     "steps_pending": len(self._pending)}
        for k, v in state.items():
            self.obs.gauge(f"insitu_{k}", "engine progress counter").set(v)
        per_group = self._staging_per_group()
        for k in per_group[0]:
            self.obs.gauge(f"insitu_staging_{k}",
                           "staging counter, summed over groups").set(
                sum(d[k] for d in per_group))
        for k, v in self._backend.telemetry().items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.obs.gauge(f"insitu_lane_{k}",
                               "lane backend counter").set(v)
        if self._device is not None:
            for k, v in self._device.stats.as_dict().items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    self.obs.gauge(f"insitu_device_{k}",
                                   "device reduce counter").set(v)
            n_fallback = self._device.stats.fallback_snapshots
            if n_fallback > self._fallback_seen:
                obs_events.EVENTS.emit(
                    obs_events.DEVICE_FALLBACK,
                    snapshots=n_fallback - self._fallback_seen,
                    total=n_fallback)
                self._fallback_seen = n_fallback

    def check_errors(self) -> None:
        if self._errors:
            if not self._dumped:
                # first surfacing of an engine failure: flush the flight
                # recorder so the postmortem has the final window on disk
                self._dumped = True
                obs_events.EVENTS.dump(
                    "engine.failed", error=repr(self._errors[0]))
            raise RuntimeError("in-transit reduction failed") \
                from self._errors[0]

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every accepted part was reduced (or dropped)."""
        deadline = time.perf_counter() + timeout
        while True:
            self.check_errors()
            self._run_deferred()
            self._sweep_ttl()
            with self._wlock:
                if not self._pending:
                    return
            if time.perf_counter() > deadline:
                raise TimeoutError("in-transit engine did not drain")
            time.sleep(0.005)

    def close(self, *, drain: bool = True) -> None:
        err: BaseException | None = None
        if drain and self._started:
            try:
                self.drain()
            except BaseException as e:
                err = e
        if self._started:
            self._backend.stop(timeout=30.0)
        else:
            self._backend.stop(timeout=0.0)
        self._run_deferred()   # evict-completed contexts with no lane left
        self.db.close()
        if err is not None:
            raise err
        self.check_errors()
