"""In-transit analysis engine (the paper's staging-node role).

``InTransitEngine`` sits between the compute flow and an HDep database:
compute calls :meth:`submit` (or :meth:`submit_state` for train states)
and returns immediately; worker lanes drain the staging areas, run the
reducer DAG and write each snapshot's reduced objects as one HDep
context. The engine has its *own* output frequency (``output_every``),
independent of HProt checkpoint cadence — the paper's "different output
frequencies" between the protection and post-processing flows.

With ``domains > 1`` the engine runs the paper's per-producer shape
inside one process: each submitted step is partitioned over contributor
groups (``insitu.partition``), every group owns its own
:class:`StagingArea` and worker lane, and each group writes its part of
the reduction as its *own Hercule domain* within the shared per-step
context — no single-writer funnel. The context finalizes when the last
group's part lands (or is dropped by backpressure); reads merge the
domains back (``hercule.api.ReducedKind``), so a context with some parts
dropped still serves its surviving domains.

Contexts written here carry ``attrs["insitu"]`` with the reducer names,
the per-reducer merge strategies, the contributing domains and staging
statistics, so a catalog (or a human) can see what was reduced and what
back-pressure did to the cadence.
"""
from __future__ import annotations

import dataclasses
import threading

from ..core.amr import AMRTree
from ..hercule import api
from ..hercule.database import HerculeDB
from .partition import partition_snapshot
from .reducers import Reducer, ReducerDAG
from .staging import Snapshot, StagingArea


@dataclasses.dataclass
class _PendingStep:
    """Countdown of contributor parts still in flight for one step."""
    remaining: int
    ctx: object = None                # ContextWriter, begun lazily
    kind: str = ""
    meta: dict = dataclasses.field(default_factory=dict)
    wrote: set = dataclasses.field(default_factory=set)      # domains
    reducers: set = dataclasses.field(default_factory=set)
    finalizing: bool = False          # countdown done, manifest pending


class InTransitEngine:
    """Worker lanes turning staged snapshots into reduced HDep objects."""

    def __init__(self, root: str | HerculeDB, reducers: list[Reducer], *,
                 output_every: int = 1, workers: int = 1,
                 queue_capacity: int = 4, policy: str = "drop-oldest",
                 ncf: int = 4, compress: bool = False, domains: int = 1,
                 durable_parts: bool = False):
        self.db = root if isinstance(root, HerculeDB) else \
            HerculeDB.create(root, kind="hdep", ncf=ncf)
        self.dag = ReducerDAG(reducers)
        self.compress = compress
        self.output_every = max(1, output_every)
        self.n_domains = max(1, domains)
        #: fsync each group file from its own lane right after the part
        #: lands (parallel durability on storage with scalable sync);
        #: off = PR-1 semantics, durability at context finalize only
        self.durable_parts = durable_parts
        self._merge_map = {r.name: r.merge for r in self.dag
                           if getattr(r, "merge", None)}
        #: one staging area per contributor group; ``staging`` aliases
        #: group 0 for the single-group API the compute side always had
        self.stages = [
            StagingArea(capacity=queue_capacity, policy=policy,
                        n_buffers=queue_capacity + max(1, workers) + 1,
                        on_evict=self._on_evict)
            for _ in range(self.n_domains)]
        self.staging = self.stages[0]
        self._threads = [
            threading.Thread(target=self._worker, args=(area,),
                             name=f"insitu-g{g}-{i}", daemon=True)
            for g, area in enumerate(self.stages)
            for i in range(max(1, workers))]
        self._errors: list[BaseException] = []
        self._pending: dict[int, _PendingStep] = {}
        #: completed steps whose finalize was deferred off the compute
        #: thread (eviction can complete a countdown inside submit();
        #: the manifest fsync must not run there)
        self._deferred: list[tuple[int, _PendingStep]] = []
        self._written: list[int] = []
        self._failed = 0
        self._skipped = 0          # snapshot parts no reducer applied to
        self._wlock = threading.Lock()
        self._started = False

    # ----------------------------------------------------------- compute side
    def start(self) -> "InTransitEngine":
        if not self._started:
            self._started = True
            for t in self._threads:
                t.start()
        return self

    def submit(self, step: int, payload, *, kind: str = "amr",
               meta: dict | None = None) -> bool:
        """Offer one step's state to the analysis flow.

        ``payload`` is an :class:`AMRTree`, or a dict of arrays (device or
        host). Steps off the engine's output cadence are ignored without
        staging cost; otherwise the payload is partitioned over the
        contributor groups and each part staged under the configured
        backpressure policy. Returns True iff any part was staged.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if isinstance(payload, AMRTree):
            payload = payload.to_arrays()
            kind = "amr"
        parts = partition_snapshot(payload, kind, self.n_domains)
        return self._stage_parts(step, parts, kind, meta)

    def submit_parts(self, step: int, parts, *, kind: str = "amr",
                     meta: dict | None = None) -> bool:
        """Per-producer hand-off: stage pre-partitioned contributor parts.

        ``parts`` holds one payload (array dict or :class:`AMRTree`) per
        contributor group — the shape real multi-producer runs have,
        where each producer already owns its domain and no runtime
        partition is needed. ``len(parts)`` must equal the engine's
        ``domains``. Cadence and backpressure behave exactly as in
        :meth:`submit`; returns True iff any part was staged.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if len(parts) != self.n_domains:
            raise ValueError(
                f"got {len(parts)} parts for {self.n_domains} contributor "
                f"group(s)")
        parts = [p.to_arrays() if isinstance(p, AMRTree) else p
                 for p in parts]
        return self._stage_parts(step, parts, kind, meta)

    def submit_part(self, step: int, domain: int, payload, *,
                    kind: str = "amr", meta: dict | None = None) -> bool:
        """One producer's hand-off of its own contributor part.

        The fully per-producer shape: each of the ``domains`` producers
        (e.g. one thread per simulated MPI rank) stages its own part
        into its own group's staging area, concurrently with the others
        — no shared hand-off thread. The step's context finalizes once
        all ``domains`` parts have settled, so *every* producer must
        call this for every on-cadence step (backpressure drops count
        as settled; a producer that skips a step leaks the context).
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if not 0 <= domain < self.n_domains:
            raise ValueError(f"domain {domain} outside the engine's "
                             f"{self.n_domains} contributor group(s)")
        if isinstance(payload, AMRTree):
            payload = payload.to_arrays()
        with self._wlock:
            pend = self._pending.get(step)
            if pend is None or pend.finalizing:
                # absent, or a previous submission's context is already
                # mid-finalize: this part belongs to a fresh countdown
                self._pending[step] = _PendingStep(remaining=self.n_domains)
        ok = self.stages[domain].push(step, payload, kind=kind, meta=meta,
                                      domain=domain,
                                      n_domains=self.n_domains)
        if not ok:
            self._part_done(step, None, None, defer_finalize=True)
        return ok

    def _stage_parts(self, step: int, parts, kind: str,
                     meta: dict | None) -> bool:
        # register before the first push: a fast worker lane may finish
        # its part while later parts are still being staged
        with self._wlock:
            pend = self._pending.get(step)
            if pend is None or pend.finalizing:
                # a finalizing pend is already off the countdown: the
                # resubmission gets its own entry (and so its own
                # ContextWriter — never append to a mid-serialization
                # manifest); the stale entry pops itself by identity
                self._pending[step] = _PendingStep(remaining=len(parts))
            else:                      # resubmitted step: extend the countdown
                pend.remaining += len(parts)
        staged_any = False
        for g, part in enumerate(parts):
            ok = self.stages[g].push(step, part, kind=kind, meta=meta,
                                     domain=g, n_domains=self.n_domains)
            if ok:
                staged_any = True
            else:
                self._part_done(step, None, None, defer_finalize=True)
        return staged_any

    def submit_state(self, step: int, state, *, prefix: str = "params"
                     ) -> bool:
        """Stage the matrix-shaped leaves of a train-state pytree."""
        if step % self.output_every != 0:
            return False   # skip the pytree flatten on off-cadence steps
        import jax

        from ..hercule.checkpoint import leaf_name
        sub = state[prefix] if isinstance(state, dict) and prefix in state \
            else state
        flat, _ = jax.tree_util.tree_flatten_with_path(sub)
        arrays = {}
        for path, leaf in flat:
            if leaf is None or getattr(leaf, "ndim", 0) < 2:
                continue
            arrays[leaf_name(path)] = leaf
        return self.submit(step, arrays, kind="tensors")

    # ---------------------------------------------------------- analysis side
    def _on_evict(self, snap: Snapshot) -> None:
        """A queued part was displaced by drop-oldest backpressure.

        Runs on the pushing (compute) thread, so a completed countdown
        is deferred — worker lanes and :meth:`drain` commit it.
        """
        self._part_done(snap.step, None, None, defer_finalize=True)

    def _worker(self, area: StagingArea):
        while True:
            snap = area.pop(timeout=0.25)
            if snap is None:
                self._run_deferred()
                if area.closed and len(area) == 0:
                    return
                continue
            try:
                self._reduce_and_write(snap)
            except BaseException as e:   # surfaced on next submit/drain
                self._errors.append(e)
                with self._wlock:
                    self._failed += 1
                self._part_done(snap.step, None, None)
            finally:
                area.release(snap)
            self._run_deferred()

    def _reduce_and_write(self, snap: Snapshot):
        outputs = self.dag.run(snap)
        if not outputs:
            # no reducer accepted this snapshot kind — don't litter the
            # database with empty contexts; surface it via stats instead
            with self._wlock:
                self._skipped += 1
            self._part_done(snap.step, None, None)
            return
        with self._wlock:
            pend = self._pending.get(snap.step)
            if pend is not None and pend.ctx is None:
                pend.ctx = self.db.begin_context(snap.step)
                pend.kind = snap.kind
                pend.meta = snap.meta
            ctx = pend.ctx if pend is not None else None
        if ctx is None:   # lone part of an already-settled step (shouldn't
            return        # happen; guards against double accounting)
        for rname, arrays in outputs.items():
            api.write_object(ctx, "reduced", snap.domain, arrays,
                             reducer=rname, compress=self.compress)
        if self.durable_parts:
            # each lane makes its own group durable: group fsyncs overlap
            # across lanes instead of queueing serially behind finalize
            self.db.flush_domain(snap.domain)
        self._part_done(snap.step, snap.domain, set(outputs))

    def _part_done(self, step: int, domain: int | None,
                   reducers: set | None, *,
                   defer_finalize: bool = False) -> None:
        """One contributor part settled (written, dropped, or failed).

        The pending entry survives until the manifest is committed, so
        :meth:`drain` cannot return while a context is mid-finalize.
        """
        with self._wlock:
            pend = self._pending.get(step)
            if pend is None or pend.finalizing:
                return
            pend.remaining -= 1
            if domain is not None:
                pend.wrote.add(domain)
                pend.reducers |= reducers
            if pend.remaining > 0:
                return
            pend.finalizing = True
            if pend.ctx is None:        # every part dropped/skipped: no
                del self._pending[step]  # context, nothing to commit
                return
            if defer_finalize:
                self._deferred.append((step, pend))
                return
        self._finalize_step(step, pend)

    def _finalize_step(self, step: int, pend: _PendingStep) -> None:
        """Commit one completed context; errors surface via check_errors."""
        staging = self.stages[0].stats.as_dict() if self.n_domains == 1 \
            else [a.stats.as_dict() for a in self.stages]
        try:
            pend.ctx.finalize(attrs={"insitu": {
                "kind": pend.kind,
                "reducers": sorted(pend.reducers),
                "merge": {r: self._merge_map[r]
                          for r in sorted(pend.reducers)
                          if r in self._merge_map},
                "n_domains": self.n_domains,
                "domains": sorted(pend.wrote),
                "staging": staging,
                **pend.meta,
            }})
        except BaseException as e:
            self._errors.append(e)
            with self._wlock:
                self._failed += 1
                if self._pending.get(step) is pend:   # a resubmission
                    del self._pending[step]           # may own the slot
            return
        with self._wlock:
            self._written.append(step)
            if self._pending.get(step) is pend:
                del self._pending[step]

    def _run_deferred(self) -> None:
        """Commit contexts whose countdown completed on a compute thread."""
        while True:
            with self._wlock:
                if not self._deferred:
                    return
                step, pend = self._deferred.pop()
            self._finalize_step(step, pend)

    # ----------------------------------------------------------------- admin
    @property
    def written_steps(self) -> list[int]:
        with self._wlock:
            return sorted(self._written)

    @property
    def skipped_snapshots(self) -> int:
        """Snapshot parts whose kind no reducer in the DAG accepted."""
        with self._wlock:
            return self._skipped

    def check_errors(self) -> None:
        if self._errors:
            raise RuntimeError("in-transit reduction failed") \
                from self._errors[0]

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every accepted part was reduced (or dropped)."""
        import time
        deadline = time.perf_counter() + timeout
        while True:
            self.check_errors()
            self._run_deferred()
            with self._wlock:
                if not self._pending:
                    return
            if time.perf_counter() > deadline:
                raise TimeoutError("in-transit engine did not drain")
            time.sleep(0.005)

    def close(self, *, drain: bool = True) -> None:
        err: BaseException | None = None
        if drain and self._started:
            try:
                self.drain()
            except BaseException as e:
                err = e
        for area in self.stages:
            area.close()
        if self._started:
            for t in self._threads:
                t.join(timeout=30.0)
            if any(t.is_alive() for t in self._threads):
                # never close the db under a still-writing worker — a
                # leaked daemon thread beats a corrupted context
                raise TimeoutError(
                    "in-transit workers did not stop; database left open")
        self._run_deferred()   # evict-completed contexts with no lane left
        self.db.close()
        if err is not None:
            raise err
        self.check_errors()
