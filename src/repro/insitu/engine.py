"""In-transit analysis engine (the paper's staging-node role).

``InTransitEngine`` sits between the compute flow and an HDep database:
compute calls :meth:`submit` (or :meth:`submit_state` for train states)
and returns immediately; a worker pool drains the staging area, runs the
reducer DAG and writes each snapshot's reduced objects as one HDep
context. The engine has its *own* output frequency (``output_every``),
independent of HProt checkpoint cadence — the paper's "different output
frequencies" between the protection and post-processing flows.

Contexts written here carry ``attrs["insitu"]`` with the reducer names
and staging statistics, so a catalog (or a human) can see what was
reduced and what back-pressure did to the cadence.
"""
from __future__ import annotations

import threading

from ..core.amr import AMRTree
from ..hercule import api
from ..hercule.database import HerculeDB
from .reducers import Reducer, ReducerDAG
from .staging import StagingArea


class InTransitEngine:
    """Worker pool turning staged snapshots into reduced HDep objects."""

    def __init__(self, root: str | HerculeDB, reducers: list[Reducer], *,
                 output_every: int = 1, workers: int = 1,
                 queue_capacity: int = 4, policy: str = "drop-oldest",
                 ncf: int = 4, compress: bool = False):
        self.db = root if isinstance(root, HerculeDB) else \
            HerculeDB.create(root, kind="hdep", ncf=ncf)
        self.dag = ReducerDAG(reducers)
        self.compress = compress
        self.output_every = max(1, output_every)
        self.staging = StagingArea(
            capacity=queue_capacity, policy=policy,
            n_buffers=queue_capacity + workers + 1)
        self._threads = [
            threading.Thread(target=self._worker, name=f"insitu-{i}",
                             daemon=True)
            for i in range(max(1, workers))]
        self._errors: list[BaseException] = []
        self._written: list[int] = []
        self._failed = 0
        self._skipped = 0          # snapshots no reducer applied to
        self._wlock = threading.Lock()
        self._started = False

    # ----------------------------------------------------------- compute side
    def start(self) -> "InTransitEngine":
        if not self._started:
            self._started = True
            for t in self._threads:
                t.start()
        return self

    def submit(self, step: int, payload, *, kind: str = "amr",
               meta: dict | None = None) -> bool:
        """Offer one step's state to the analysis flow.

        ``payload`` is an :class:`AMRTree`, or a dict of arrays (device or
        host). Steps off the engine's output cadence are ignored without
        staging cost; otherwise the configured backpressure policy
        decides. Returns True iff the snapshot was staged.
        """
        self.check_errors()
        if not self._started:
            self.start()
        if step % self.output_every != 0:
            return False
        if isinstance(payload, AMRTree):
            payload = payload.to_arrays()
            kind = "amr"
        return self.staging.push(step, payload, kind=kind, meta=meta)

    def submit_state(self, step: int, state, *, prefix: str = "params"
                     ) -> bool:
        """Stage the matrix-shaped leaves of a train-state pytree."""
        if step % self.output_every != 0:
            return False   # skip the pytree flatten on off-cadence steps
        import jax

        from ..hercule.checkpoint import leaf_name
        sub = state[prefix] if isinstance(state, dict) and prefix in state \
            else state
        flat, _ = jax.tree_util.tree_flatten_with_path(sub)
        arrays = {}
        for path, leaf in flat:
            if leaf is None or getattr(leaf, "ndim", 0) < 2:
                continue
            arrays[leaf_name(path)] = leaf
        return self.submit(step, arrays, kind="tensors")

    # ---------------------------------------------------------- analysis side
    def _worker(self):
        while True:
            snap = self.staging.pop(timeout=0.25)
            if snap is None:
                if self.staging.closed and len(self.staging) == 0:
                    return
                continue
            try:
                self._reduce_and_write(snap)
            except BaseException as e:   # surfaced on next submit/drain
                self._errors.append(e)
                with self._wlock:
                    self._failed += 1
            finally:
                self.staging.release(snap)

    def _reduce_and_write(self, snap):
        outputs = self.dag.run(snap)
        if not outputs:
            # no reducer accepted this snapshot kind — don't litter the
            # database with empty contexts; surface it via stats instead
            with self._wlock:
                self._skipped += 1
            return
        ctx = self.db.begin_context(snap.step)
        for rname, arrays in outputs.items():
            api.write_object(ctx, "reduced", 0, arrays, reducer=rname,
                             compress=self.compress)
        ctx.finalize(attrs={"insitu": {
            "kind": snap.kind,
            "reducers": sorted(outputs),
            "staging": self.staging.stats.as_dict(),
            **snap.meta,
        }})
        with self._wlock:
            self._written.append(snap.step)

    # ----------------------------------------------------------------- admin
    @property
    def written_steps(self) -> list[int]:
        with self._wlock:
            return sorted(self._written)

    @property
    def skipped_snapshots(self) -> int:
        """Snapshots whose kind no reducer in the DAG accepted."""
        with self._wlock:
            return self._skipped

    def check_errors(self) -> None:
        if self._errors:
            raise RuntimeError("in-transit reduction failed") \
                from self._errors[0]

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every accepted snapshot was reduced (or failed)."""
        import time
        deadline = time.perf_counter() + timeout
        while True:
            self.check_errors()
            with self._wlock:
                done = len(self._written) + self._failed + self._skipped
            stats = self.staging.stats
            # accepted snapshots are either still queued/in-flight,
            # were evicted by drop-oldest, or have been processed
            if done + stats.evicted >= stats.accepted:
                return
            if time.perf_counter() > deadline:
                raise TimeoutError("in-transit engine did not drain")
            time.sleep(0.005)

    def close(self, *, drain: bool = True) -> None:
        err: BaseException | None = None
        if drain and self._started:
            try:
                self.drain()
            except BaseException as e:
                err = e
        self.staging.close()
        if self._started:
            for t in self._threads:
                t.join(timeout=30.0)
            if any(t.is_alive() for t in self._threads):
                # never close the db under a still-writing worker — a
                # leaked daemon thread beats a corrupted context
                raise TimeoutError(
                    "in-transit workers did not stop; database left open")
        self.db.close()
        if err is not None:
            raise err
        self.check_errors()
