"""In-transit analysis: staged reductions from compute to lightweight HDep.

The paper's in-situ/in-transit layer (fig. 1): instead of dumping full
state for post-hoc processing, the compute flow stages snapshots to an
analysis flow that reduces them to purpose-specific lightweight objects
written at an independent cadence.

    compute --push--> StagingArea --pop--> InTransitEngine(ReducerDAG)
                                                  |
                                       HDep reduced contexts
                                                  |
                many viewers  <--LRU cache--   Catalog

  * :mod:`staging`  — double-buffered device→host hand-off with a bounded
    queue and explicit backpressure (``block``/``drop-oldest``/``subsample``).
  * :mod:`reducers` — composable reduction operators over AMR trees and
    train states, combined in a DAG.
  * :mod:`engine`   — worker pool consuming staged snapshots and writing
    reduced HDep objects at its own output frequency.
  * :mod:`catalog`  — the read side: cached queries for many concurrent
    viewers.
"""
from .catalog import Catalog                                   # noqa: F401
from .engine import InTransitEngine                            # noqa: F401
from .reducers import (LevelHistogramReducer, LODCutReducer,   # noqa: F401
                       ProjectionReducer, Reducer, ReducerDAG,
                       SliceReducer, SpectraReducer, TensorNormReducer)
from .staging import POLICIES, Snapshot, StagingArea           # noqa: F401
