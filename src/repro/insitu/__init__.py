"""In-transit analysis: staged reductions from compute to lightweight HDep.

The paper's in-situ/in-transit layer (fig. 1): instead of dumping full
state for post-hoc processing, the compute flow stages snapshots to an
analysis flow that reduces them to purpose-specific lightweight objects
written at an independent cadence.

    compute --push--> StagingArea(group g) --pop--> worker lane g
                           (one per contributor group)   |
                                      reduced domain g of the shared
                                           per-step HDep context
                                                  |
                many viewers  <--LRU cache--   Catalog (merge-at-read)

  * :mod:`staging`   — double-buffered device→host hand-off with a bounded
    queue and explicit backpressure (``block``/``drop-oldest``/``subsample``).
  * :mod:`partition` — contributor-group split of a staged step (Hilbert
    leaf assignment for AMR trees, name striping for tensors).
  * :mod:`reducers`  — composable reduction operators over AMR trees and
    train states, combined in a DAG; each declares its multi-domain
    merge strategy.
  * :mod:`lanes`     — the pluggable lane runtime: ``thread`` lanes
    (in-process workers) or ``process`` lanes (one OS process per
    contributor group over shared-memory staging).
  * :mod:`engine`    — per-group lanes consuming staged snapshots and
    writing reduced HDep domains at the engine's own output frequency.
  * :mod:`device`    — on-accelerator reduction: device-resident staging
    (``DeviceStagingArea``) plus a device-reducer registry over the
    Pallas rasterization kernels, so only *reduced* objects cross the
    device→host boundary (``InTransitEngine(device_reduce=True)``).
  * :mod:`mesh_reduce` — the sharded variant: each snapshot's leaf
    table is Hilbert-partitioned over a JAX device mesh, rasterized
    under ``shard_map`` and merged on device, so no device ever holds
    more than ~1/N of a snapshot (``device_reduce="mesh"``).
  * :mod:`catalog`   — the read side: cached, domain-merged queries for
    many concurrent viewers.
  * :mod:`serve`     — the continuous-batching serving core: in-flight
    identical queries coalesce onto one decode+merge (single-flight),
    region crops batch, admission control + per-client fairness bound
    overload, and ``fpdelta-pyramid`` levels stream coarse-first.
  * :mod:`server`    — the catalog as a service: many viewer *processes*
    share one reduction cache over HTTP (``RemoteCatalog`` client),
    routed through the serving engine.
"""
from .catalog import Catalog                                   # noqa: F401
from .engine import InTransitEngine                            # noqa: F401
from .lanes import (BACKENDS, LANE_POOL, LaneBackend,          # noqa: F401
                    register_backend, shutdown_pool)
from .partition import partition_snapshot                      # noqa: F401
from .reducers import (LevelHistogramReducer, LODCutReducer,   # noqa: F401
                       ProjectionReducer, Reducer, ReducerDAG,
                       SliceReducer, SpectraReducer, TensorNormReducer)
from .serve import (ProgressiveAssembler, ServeEngine,         # noqa: F401
                    ServeOverloaded, plan_progressive, staging_pressure)
from .server import CatalogBusy, CatalogServer, RemoteCatalog  # noqa: F401
from .staging import (POLICIES, ShmStagingArea, Snapshot,      # noqa: F401
                      StagingArea, StrideController)

_DEVICE_NAMES = ("DeviceStagingArea", "DeviceDAGRunner", "DeviceTree",
                 "register_device_impl", "device_impl_for")

_MESH_NAMES = ("MeshDAGRunner", "MeshRunStats", "MeshTable",
               "register_mesh_impl", "mesh_impl_for")


def __getattr__(name: str):
    # the device/mesh modules pull in jax at call time; keep the package
    # import light for the (host-only) CLI paths
    if name in _DEVICE_NAMES:
        from . import device
        return getattr(device, name)
    if name in _MESH_NAMES:
        from . import mesh_reduce
        return getattr(mesh_reduce, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
