"""Composable in-transit reduction operators (paper §4, in-situ flavor).

Each reducer turns a staged :class:`~repro.insitu.staging.Snapshot` into a
small dict of named arrays — the lightweight, purpose-specific objects the
paper argues should replace full-state dumps. Reducers declare upstream
dependencies by name, forming a DAG the engine executes once per staged
snapshot (e.g. an axis slice cut from a level-of-detail pyramid cut
instead of the full tree).

AMR reducers reproduce the exact post-hoc semantics of
:mod:`repro.hercule.analysis` (same rasterization), so an in-transit
slice is bitwise-comparable to the post-hoc one. Tensor reducers
(norm summaries, spectra) are JIT-compiled, cached per input shape.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.amr import AMRTree, subset_tree
from ..hercule import analysis
from .staging import Snapshot


def tree_of(arrays: dict[str, np.ndarray]) -> AMRTree:
    """Reconstruct an AMRTree from staged/reduced ``to_arrays`` output."""
    return AMRTree.from_arrays(arrays)


class Reducer:
    """Base reduction operator.

    ``name`` doubles as the reduced-object key in HDep (and in catalog
    cache keys), so it encodes the parameters and may not contain ``/``.
    ``deps`` names upstream reducers whose outputs are passed in
    ``upstream``. ``merge`` names the multi-domain merge strategy of this
    reducer's outputs (``hercule.api.ReducedKind.MERGES``); a reducer on
    a partitioned snapshot (``snap.n_domains > 1``) must contribute each
    owned element exactly once so per-domain outputs merge back to the
    single-domain answer.
    """

    name: str = "reducer"
    deps: tuple[str, ...] = ()
    kinds: tuple[str, ...] = ("amr",)   # snapshot kinds this reducer accepts
    merge: str | None = None            # multi-domain merge strategy
    #: ``reduce`` accepts jax device arrays directly (no host snapshot
    #: needed) — the device-reduce path (``insitu.device``) skips the
    #: full-snapshot fallback transfer for such reducers
    device_ready: bool = False

    #: instance attributes that never pickle (jitted closures); process
    #: lane backends ship reducers to spawned workers, which rebuild
    #: them via ``__post_init__``
    UNPICKLABLE: tuple[str, ...] = ()

    def __getstate__(self):
        state = dict(self.__dict__)
        for attr in self.UNPICKLABLE:
            state.pop(attr, None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        if self.UNPICKLABLE and hasattr(self, "__post_init__"):
            self.__post_init__()    # recompile the jitted closures

    def reduce(self, snap: Snapshot,
               upstream: dict[str, dict[str, np.ndarray]]
               ) -> dict[str, np.ndarray]:
        raise NotImplementedError

    def _source_tree(self, snap: Snapshot, upstream) -> AMRTree:
        src = getattr(self, "source", None)
        if src:
            return tree_of(upstream[src])
        return tree_of(snap.arrays)


# ------------------------------------------------------------ AMR reducers

@dataclasses.dataclass
class SliceReducer(Reducer):
    """Axis-aligned slice raster — identical to ``analysis.slice_image``."""

    field: str = "density"
    axis: int = 2
    position: float = 0.5
    resolution: int = 256
    source: str | None = None      # optional upstream tree (e.g. a LOD cut)

    merge = "tile"

    def __post_init__(self):
        self.name = (f"slice-{self.field}-ax{self.axis}-"
                     f"p{self.position:g}-r{self.resolution}")
        if self.source:
            self.name += f"-of-{self.source}"
        self.deps = (self.source,) if self.source else ()

    def reduce(self, snap, upstream):
        tree = self._source_tree(snap, upstream)
        img = analysis.slice_image(tree, self.field, axis=self.axis,
                                   position=self.position,
                                   resolution=self.resolution,
                                   owned_only=snap.n_domains > 1)
        return {"image": img}


@dataclasses.dataclass
class ProjectionReducer(Reducer):
    """Column density: integrate a field along one axis over all leaves."""

    field: str = "density"
    axis: int = 2
    resolution: int = 256
    source: str | None = None

    merge = "sum"

    def __post_init__(self):
        self.name = (f"proj-{self.field}-ax{self.axis}-r{self.resolution}")
        if self.source:
            self.name += f"-of-{self.source}"
        self.deps = (self.source,) if self.source else ()

    def reduce(self, snap, upstream):
        tree = self._source_tree(snap, upstream)
        res = self.resolution
        img = np.zeros((res, res))
        levels = tree.levels()
        v = tree.fields[self.field]
        leaves = np.flatnonzero(~tree.refine)
        if snap.n_domains > 1:      # partitioned: integrate owned cells once
            leaves = leaves[tree.owner[leaves]]
        ax_u, ax_v = [a for a in range(3) if a != self.axis]
        for l in range(tree.n_levels):
            sel = leaves[levels[leaves] == l]
            if sel.size == 0:
                continue
            size = 1.0 / (1 << l)
            c = tree.coords[sel]
            u0 = np.floor(c[:, ax_u] * size * res).astype(int)
            v0 = np.floor(c[:, ax_v] * size * res).astype(int)
            contrib = v[sel] * size           # field * path length
            px = max(1, int(round(size * res)))
            if px == 1:
                np.add.at(img, (u0, v0), contrib)
            else:
                for i in range(sel.size):
                    img[u0[i]:u0[i] + px, v0[i]:v0[i] + px] += contrib[i]
        return {"image": img}


@dataclasses.dataclass
class LevelHistogramReducer(Reducer):
    """Per-refinement-level histogram of a leaf field."""

    field: str = "density"
    bins: int = 32
    lo: float | None = None
    hi: float | None = None
    max_levels: int = 16

    merge = "hist"

    def __post_init__(self):
        self.name = f"hist-{self.field}-b{self.bins}"
        if self.lo is not None or self.hi is not None:
            lo = "auto" if self.lo is None else format(self.lo, "g")
            hi = "auto" if self.hi is None else format(self.hi, "g")
            self.name += f"-lo{lo}-hi{hi}"
        if self.max_levels != 16:
            self.name += f"-L{self.max_levels}"

    def reduce(self, snap, upstream):
        tree = self._source_tree(snap, upstream)
        v = tree.fields[self.field]
        leaf = ~tree.refine
        if snap.n_domains > 1:      # partitioned: count owned leaves once
            leaf &= tree.owner
        lo = float(v[leaf].min()) if self.lo is None else self.lo
        hi = float(v[leaf].max()) if self.hi is None else self.hi
        if hi <= lo:
            hi = lo + 1.0
        edges = np.linspace(lo, hi, self.bins + 1)
        hist = np.zeros((min(tree.n_levels, self.max_levels), self.bins),
                        np.int64)
        levels = tree.levels()
        for l in range(hist.shape[0]):
            sel = leaf & (levels == l)
            if sel.any():
                hist[l], _ = np.histogram(v[sel], bins=edges)
        return {"hist": hist, "edges": edges}


@dataclasses.dataclass
class LODCutReducer(Reducer):
    """Level-of-detail pyramid cut: the tree truncated at ``max_level``.

    Nodes deeper than ``max_level`` are dropped and their ancestors
    demoted to leaves (which already carry the intensive restriction of
    their sons) — a coarse but complete tree any viewer can render.
    """

    max_level: int = 4

    merge = "assemble"

    def __post_init__(self):
        self.name = f"lod{self.max_level}"

    def reduce(self, snap, upstream):
        tree = self._source_tree(snap, upstream)
        if tree.n_levels <= self.max_level + 1:
            return dict(tree.to_arrays())
        levels = tree.levels()
        keep = levels <= self.max_level
        force_leaf = np.flatnonzero(keep & (levels == self.max_level)
                                    & tree.refine)
        cut = subset_tree(tree, keep, force_leaf=force_leaf)
        return dict(cut.to_arrays())


# --------------------------------------------------------- tensor reducers

@dataclasses.dataclass
class TensorNormReducer(Reducer):
    """Per-tensor summary statistics (l2, rms, absmax, mean), jitted.

    ``jax.jit`` retraces (and caches) per input shape/dtype, so stable
    train-state shapes compile once on the first staged snapshot.
    """

    STAT_NAMES = ("l2", "rms", "absmax", "mean")

    merge = "concat"
    device_ready = True
    UNPICKLABLE = ("_stats",)

    def __post_init__(self):
        self.name = "tnorm"
        self.kinds = ("tensors",)
        import jax
        import jax.numpy as jnp

        def stats(x):
            x = x.astype(jnp.float32)
            return jnp.stack([jnp.linalg.norm(x.ravel()),
                              jnp.sqrt(jnp.mean(x * x)),
                              jnp.max(jnp.abs(x)),
                              jnp.mean(x)])
        self._stats = jax.jit(stats)

    def reduce(self, snap, upstream):
        names = sorted(snap.arrays)
        mat = np.stack([np.asarray(self._stats(snap.arrays[n]))
                        for n in names]) if names else np.zeros((0, 4), np.float32)
        return {"stats": mat.astype(np.float32),
                "names": np.array(names, dtype="U"),
                "stat_names": np.array(self.STAT_NAMES, dtype="U")}


@dataclasses.dataclass
class SpectraReducer(Reducer):
    """Top-k singular values of each matrix-shaped tensor, jitted."""

    k: int = 8

    merge = "union"
    device_ready = True
    UNPICKLABLE = ("_svd",)

    def __post_init__(self):
        self.name = f"spectra-k{self.k}"
        self.kinds = ("tensors",)
        import jax
        import jax.numpy as jnp

        def spectrum(x):
            return jnp.linalg.svd(x.astype(jnp.float32), compute_uv=False)
        self._svd = jax.jit(spectrum)

    def reduce(self, snap, upstream):
        out = {}
        for name in sorted(snap.arrays):
            arr = snap.arrays[name]
            if arr.ndim != 2 or min(arr.shape) < 2:
                continue
            s = np.asarray(self._svd(arr))[:self.k]
            out[name.replace("/", ".")] = s.astype(np.float32)
        return out


# ----------------------------------------------------------------- the DAG

class ReducerDAG:
    """Topologically ordered reducer set, executed per staged snapshot."""

    def __init__(self, reducers: list[Reducer]):
        byname = {}
        for r in reducers:
            assert "/" not in r.name, f"reducer name {r.name!r} contains '/'"
            if r.name in byname:
                raise ValueError(f"duplicate reducer name {r.name!r}")
            byname[r.name] = r
        for r in reducers:
            for d in r.deps:
                if d not in byname:
                    raise ValueError(
                        f"reducer {r.name!r} depends on unknown {d!r}")
        # Kahn topo-sort
        order, ready = [], [r for r in reducers if not r.deps]
        placed = {r.name for r in ready}
        pending = [r for r in reducers if r.deps]
        while ready:
            order.extend(ready)
            nxt = [r for r in pending
                   if all(d in placed for d in r.deps)]
            pending = [r for r in pending if r not in nxt]
            placed |= {r.name for r in nxt}
            ready = nxt
        if pending:
            raise ValueError(
                f"reducer dependency cycle: {[r.name for r in pending]}")
        self.order = order

    def __iter__(self):
        return iter(self.order)

    def names(self) -> list[str]:
        return [r.name for r in self.order]

    def run(self, snap: Snapshot) -> dict[str, dict[str, np.ndarray]]:
        """Execute every reducer applicable to the snapshot's kind."""
        outputs: dict[str, dict[str, np.ndarray]] = {}
        for r in self.order:
            if snap.kind not in r.kinds:
                continue
            if any(d not in outputs for d in r.deps):
                continue   # upstream skipped (kind mismatch)
            out = r.reduce(snap, outputs)
            if out:
                outputs[r.name] = out
        return outputs
